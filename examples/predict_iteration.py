"""Predict a model's training-iteration time under each allreduce algorithm.

Picks a named workload scenario (a registered model config + fabric +
batch geometry), compiles its gradients into DDP-style buckets, replays the
staggered bucket traffic through the packet-level simulator once per
algorithm, and prints predicted iteration time, the exposed-communication
fraction, and the speedup over the host-based ring baseline — the question
the workload subsystem exists to answer: "how much faster does this *model*
train under Canary?"

    PYTHONPATH=src python examples/predict_iteration.py
    PYTHONPATH=src python examples/predict_iteration.py whisper/three_tier

Pass ``--congested`` (default) or ``--idle`` to toggle background traffic;
any registered scenario name works (see ``list_scenarios()``).
"""
import sys

sys.path.insert(0, "src")

from repro.core.canary import Algo
from repro.core.workload import get_scenario, list_scenarios, predict_scenario


def main(argv) -> None:
    args = [a for a in argv if not a.startswith("--")]
    name = args[0] if args else "deepseek-moe/fat_tree"
    congestion = "--idle" not in argv
    s = get_scenario(name)
    print(f"scenario {name}: {s.arch} ({s.variant}) on {s.topology}, "
          f"dp={s.dp_hosts} seq={s.seq} batch={s.global_batch} "
          f"buckets<=~{s.bucket_bytes >> 10}KiB "
          f"congestion={'on' if congestion else 'off'}")
    if s.description:
        print(f"  ({s.description})")
    print()
    preds = {}
    for algo, nt, label in ((Algo.RING, 1, "ring"),
                            (Algo.STATIC_TREE, 1, "static1"),
                            (Algo.CANARY, 1, "canary")):
        preds[label] = predict_scenario(name, algo=algo, n_trees=nt,
                                        congestion=congestion)
    base = preds["ring"].iteration_ns
    print(f"{'algo':>8} {'iter_us':>9} {'compute_us':>11} {'exposed':>8} "
          f"{'buckets':>7} {'vs_ring':>8} {'exact':>6}")
    for label, p in preds.items():
        print(f"{label:>8} {p.iteration_ns / 1e3:>9.1f} "
              f"{p.compute_ns / 1e3:>11.1f} {p.exposed_comm_frac:>8.1%} "
              f"{len(p.buckets):>7} {base / p.iteration_ns:>8.2f}x "
              f"{str(p.correct):>6}")
    print(f"\ndp gradient bytes/iteration: "
          f"{preds['canary'].plan.total_grad_bytes} "
          f"(expert-sharded: {preds['canary'].plan.expert_grad_bytes})")
    print(f"known scenarios: {', '.join(list_scenarios())}")
    if not all(p.correct for p in preds.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main(sys.argv[1:])
