"""Quickstart: train a reduced Llama-3.2 on synthetic data for 200 steps.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end: config registry -> init ->
train_step -> trainer loop. Loss should drop from ~ln(V) to well below it
(the synthetic stream is learnable position-hash structure + memorization).
"""
import sys

sys.path.insert(0, "src")

from repro.data import DataConfig
from repro.models import get_config
from repro.optim import AdamWConfig, cosine_with_warmup
from repro.train import TrainConfig, Trainer, TrainerConfig


def main() -> None:
    cfg = get_config("llama3.2-1b", "smoke")
    steps = 200
    tc = TrainConfig(
        model=cfg,
        optimizer=AdamWConfig(lr=3e-3, schedule=cosine_with_warmup(
            3e-3, warmup_steps=10, total_steps=steps)),
    )
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=64)
    trainer = Trainer(TrainerConfig(train=tc, data=data, steps=steps,
                                    log_every=25))
    hist = trainer.run()
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training failed to learn"
    print("quickstart OK")


if __name__ == "__main__":
    main()
