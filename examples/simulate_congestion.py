"""Reproduce the paper's core claim (Figs. 2/7) in one run: under background
congestion, Canary's dynamic trees beat static reduction trees, which can
even lose to the host-based ring.

    PYTHONPATH=src python examples/simulate_congestion.py [--paper-scale]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.canary import (Algo, compare_algorithms, paper_config,
                               scaled_config)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true",
                    help="full 1024-host network + 4MiB (slow)")
    args = ap.parse_args()
    if args.paper_scale:
        cfg, hosts, size = paper_config(seed=3), 512, 4 * 2 ** 20
    else:
        cfg, hosts, size = scaled_config(8, seed=3), 32, 2 ** 20

    for cong in (False, True):
        print(f"\n=== congestion={cong} ({hosts} hosts, {size >> 10} KiB) ===")
        res = compare_algorithms(cfg, hosts, size, congestion=cong, reps=2)
        for name, r in res.items():
            print(f"  {name:10s} goodput {r.goodput_gbps_mean:6.1f} Gbps  "
                  f"(runtime {r.runtime_us_mean:8.1f} us, "
                  f"correct={r.correct})")
        canary = res["canary"].goodput_gbps_mean
        st1 = res["static_1"].goodput_gbps_mean
        if cong:
            print(f"  -> Canary vs 1 static tree under congestion: "
                  f"{canary / st1:.2f}x")


if __name__ == "__main__":
    main()
