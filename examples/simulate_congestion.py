"""Reproduce the paper's core claim (Figs. 2/7) in one run: under background
congestion, Canary's dynamic trees beat static reduction trees, which can
even lose to the host-based ring. Then re-run Canary with the trace recorder
(`SimConfig.trace=True`) and show the dynamic trees the congested fabric
actually formed — deepest tree, timeout-flush counts, compiled schedule.

    PYTHONPATH=src python examples/simulate_congestion.py [--paper-scale]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core.canary import (Algo, AllreduceJob, Simulator,
                               compare_algorithms, paper_config,
                               scaled_config)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true",
                    help="full 1024-host network + 4MiB (slow)")
    args = ap.parse_args()
    if args.paper_scale:
        cfg, hosts, size = paper_config(seed=3), 512, 4 * 2 ** 20
    else:
        cfg, hosts, size = scaled_config(8, seed=3), 32, 2 ** 20

    for cong in (False, True):
        print(f"\n=== congestion={cong} ({hosts} hosts, {size >> 10} KiB) ===")
        res = compare_algorithms(cfg, hosts, size, congestion=cong, reps=2)
        for name, r in res.items():
            print(f"  {name:10s} goodput {r.goodput_gbps_mean:6.1f} Gbps  "
                  f"(runtime {r.runtime_us_mean:8.1f} us, "
                  f"correct={r.correct})")
        canary = res["canary"].goodput_gbps_mean
        st1 = res["static_1"].goodput_gbps_mean
        if cong:
            print(f"  -> Canary vs 1 static tree under congestion: "
                  f"{canary / st1:.2f}x")

    show_dynamic_trees(cfg, hosts, size)


def show_dynamic_trees(cfg, hosts: int, size: int) -> None:
    """One traced Canary run under congestion: what trees actually formed?"""
    print(f"\n=== dynamic trees under congestion (trace recorder) ===")
    tcfg = dataclasses.replace(cfg, trace=True, timeout_ns=500.0)
    noise = list(range(hosts, min(tcfg.num_hosts, 2 * hosts)))
    sim = Simulator(tcfg, [AllreduceJob(app=0,
                                        participants=list(range(hosts)),
                                        data_bytes=size)],
                    algo=Algo.CANARY, noise_hosts=noise)
    result = sim.run()
    tr = sim.trace
    print(f"  trace: {len(tr.block_keys())} completed blocks, "
          f"{len(tr.nodes)} nodes, timeout_flushes={tr.timeout_flushes} "
          f"complete_flushes={tr.complete_flushes} "
          f"collisions={tr.collisions} stragglers={tr.stragglers}")
    trees = [tr.block_tree(a, b) for a, b in tr.block_keys()]
    deepest = tr.deepest_tree()
    timeout_blocks = sum(1 for t in trees if t.timeout_flushes() > 0)
    print(f"  blocks with >=1 timeout flush: {timeout_blocks}/{len(trees)}")
    print(f"  deepest dynamic tree: {deepest.summary()}")
    from repro.core.trace import compile_block
    sched = compile_block(deepest)
    print(f"  compiled schedule:    {sched.summary()}")
    print(f"  simulated time {result.duration_ns / 1e3:.1f} us, "
          f"correct={result.correct}")


if __name__ == "__main__":
    main()
