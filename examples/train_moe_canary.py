"""Train a reduced DeepSeekMoE with the Canary gradient allreduce over an
8-way data-parallel mesh (8 simulated CPU devices), comparing grad-sync
strategies: XLA auto vs ring vs Canary dynamic trees vs fixed-point Canary.

    python examples/train_moe_canary.py      # (sets its own XLA_FLAGS)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import DataConfig
from repro.models import get_config
from repro.optim import AdamWConfig
from repro.parallel.context import ParallelContext, parallel_context
from repro.train import TrainConfig, Trainer, TrainerConfig


def run(grad_sync: str, steps: int = 20) -> list:
    cfg = get_config("deepseek-moe-16b", "smoke")
    mesh = jax.make_mesh((8, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tc = TrainConfig(model=cfg, optimizer=AdamWConfig(lr=5e-3),
                     grad_sync=grad_sync, canary_blocks=8)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=16, seq_len=32)
    ctx = ParallelContext(mesh=mesh, data_axes=("data",), model_axis="model")
    with parallel_context(ctx):
        trainer = Trainer(TrainerConfig(train=tc, data=data, steps=steps,
                                        log_every=0), mesh=mesh)
        history = trainer.run()
    return [h["loss"] for h in history]


def main() -> None:
    results = {}
    for mode in ("auto", "ring", "canary", "canary_fp"):
        losses = run(mode)
        results[mode] = losses
        print(f"{mode:10s} loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    # every strategy implements the same mathematical allreduce: loss curves
    # must agree closely (fixed-point within quantization error)
    ref = np.array(results["auto"])
    for mode in ("ring", "canary"):
        np.testing.assert_allclose(np.array(results[mode]), ref, rtol=2e-2,
                                   atol=2e-2)
    np.testing.assert_allclose(np.array(results["canary_fp"]), ref, rtol=5e-2,
                               atol=5e-2)
    print("all grad-sync strategies converge identically — OK")


if __name__ == "__main__":
    main()
