"""Tour of the telemetry subsystem on the headline congested cell.

    PYTHONPATH=src python examples/telemetry_tour.py [--trace-out trace.json]

One congested fat-tree run (half the hosts allreduce under CANARY, the other
half blast background traffic, sender-side noise so descriptor windows
actually time out) with ``SimConfig(telemetry=True)``, then a walk through
what the hub observed:

* probe time series — per-link queue backlog vs time, descriptor-table
  occupancy vs the paper's §3.2.2 analytic bound, DCQCN-style counters;
* block-lifecycle spans — pump -> switch merges -> flush -> broadcast ->
  leader-complete, with latency percentiles from the span histogram;
* descriptor aggregation windows — timeout vs complete flushes;
* optional Perfetto export: pass ``--trace-out`` and load the file in
  https://ui.perfetto.dev to scrub through the run visually.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.telemetry import (run_headline_cell, validate_perfetto,
                                  write_perfetto)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8,
                    help="fabric scale (default 8 = 64 hosts)")
    ap.add_argument("--data-bytes", type=int, default=1 << 20)
    ap.add_argument("--trace-out", default=None,
                    help="write Perfetto trace-event JSON here")
    args = ap.parse_args()

    print(f"=== headline cell: congested fat-tree, scale={args.scale}, "
          f"{args.data_bytes >> 10} KiB ===")
    sim = run_headline_cell(scale=args.scale, data_bytes=args.data_bytes)
    res = sim.telemetry_result
    tel = sim.telemetry
    print(res.summary())

    print("\n--- probes (time series) ---")
    s = tel.summary_dict()
    print(f"  {int(s['probes'])} probes, {int(s['series'])} series, "
          f"{int(s['samples'])} samples "
          f"({int(s['samples_dropped'])} dropped)")
    print(f"  peak link backlog: {s['max_link_backlog_bytes'] / 1024:.1f} KiB")
    print(f"  descriptor high-water: {int(s['desc_high_water'])} "
          f"(analytic Little's-law bound: "
          f"{s['occupancy_model_descriptors']:.1f}; exact cross-check: "
          f"max_descriptors_per_switch={res.max_descriptors_per_switch})")

    print("\n--- spans (block lifecycle + aggregation windows) ---")
    print(f"  {int(s['spans'])} spans, {int(s['instants'])} instant events")
    print(f"  blocks: {int(s['blocks/started'])} started, "
          f"{int(s['blocks/completed'])} completed")
    print(f"  descriptor flushes: {int(s['desc/flush_timeout'])} timeout, "
          f"{int(s['desc/flush_complete'])} complete "
          f"(the congested regime flushes on the §3.1.1 best-effort timer)")
    lat = tel.registry.hists.get("block/latency_ns")
    if lat is not None:
        print(f"  block latency: mean {lat.mean / 1e3:.1f} us, "
              f"min {lat.min / 1e3:.1f}, max {lat.max / 1e3:.1f} "
              f"over {lat.count} blocks")
    win = tel.registry.hists.get("desc/window_ns")
    if win is not None:
        print(f"  aggregation window: mean {win.mean:.0f} ns "
              f"(cfg timeout_ns={sim.cfg.timeout_ns:.0f})")

    if args.trace_out:
        doc = write_perfetto(tel, args.trace_out)
        errs = validate_perfetto(doc)
        assert not errs, errs[:3]
        print(f"\nwrote {args.trace_out} "
              f"({len(doc['traceEvents'])} trace events)")
        print("open https://ui.perfetto.dev and drag the file in: spans "
              "under 'apps'/'switches', counter tracks per link and host")


if __name__ == "__main__":
    main()
