"""Batched serving demo: prefill + greedy decode with a KV cache on a reduced
Qwen2, plus a Mamba-2 (SSM state cache) and a sliding-window long-context
variant.

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.models import get_config
from repro.serving import Engine, ServeConfig


def demo(arch: str, sliding_window: int = 0) -> None:
    cfg = get_config(arch, "smoke")
    if sliding_window:
        cfg = cfg.long_context_variant(sliding_window)
    engine = Engine(ServeConfig(model=cfg, batch=4, max_len=128))
    prompts = jax.random.randint(jax.random.PRNGKey(0), (4, 12), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    tokens, stats = engine.generate(prompts, new_tokens=24)
    label = cfg.name
    print(f"{label:24s} out={tokens.shape} "
          f"decode={stats['decode_tok_per_s']:7.1f} tok/s "
          f"prefill={stats['prefill_s']*1e3:6.0f} ms")
    assert tokens.shape == (4, 24)


def main() -> None:
    demo("qwen2-7b")
    demo("mamba2-130m")
    demo("llama3.2-1b", sliding_window=16)
    print("serving OK")


if __name__ == "__main__":
    main()
