"""Multi-tenant fleet demo: 3 tenants with mixed priorities share one fabric
under enforced switch-memory quotas (§3.2.2).

* ``training`` (weight 6) — a priority tenant running one allreduce per
  training iteration (periodic arrivals).
* ``batch``    (weight 1) — Poisson-submitted batch jobs.
* ``scavenger`` (weight 0.02) — squeezed below one job's descriptor demand,
  so admission control degrades its jobs to the §3.3 host-based path.

Prints per-job JCT + slowdown vs an uncontended run, per-tenant aggregates,
and Jain's fairness index across tenants.

    PYTHONPATH=src python examples/fleet_demo.py
"""
import random
import sys

sys.path.insert(0, "src")

from repro.core.canary import Algo, TenantSpec, scaled_config
from repro.core.fleet import (FleetDriver, FleetScenario, make_jobs,
                              periodic_arrivals, poisson_arrivals)


def main() -> None:
    cfg = scaled_config(4, seed=7)   # 16 hosts, full bisection
    rng = random.Random(7)
    training = TenantSpec(0, weight=6.0, name="training")
    batch = TenantSpec(1, weight=1.0, name="batch")
    scavenger = TenantSpec(2, weight=0.02, name="scavenger")
    jobs = (
        make_jobs(training, periodic_arrivals(3, 30_000.0), range(16), 8,
                  65536, rng=rng, app_base=0) +
        make_jobs(batch, poisson_arrivals(2, 25_000.0, rng=rng), range(16),
                  6, 32768, rng=rng, app_base=100, fixed_placement=False) +
        make_jobs(scavenger, poisson_arrivals(2, 25_000.0, rng=rng),
                  range(16), 6, 32768, rng=rng, app_base=200)
    )
    scenario = FleetScenario(cfg=cfg, tenants=[training, batch, scavenger],
                             jobs=jobs, algo=Algo.CANARY,
                             quota_policy="weighted")
    fr = FleetDriver(scenario).run()

    names = {0: "training", 1: "batch", 2: "scavenger"}
    print(f"admission: {fr.admission.summary()}")
    print(f"fleet:     {fr.summary()}\n")
    print(f"{'job':>8} {'tenant':>10} {'submit_us':>10} {'jct_us':>8} "
          f"{'slowdown':>8} {'admitted':>8} {'fallback':>8}")
    for r in fr.jobs:
        print(f"app{r.app:<5} {names[r.tenant]:>10} "
              f"{r.submit_ns / 1e3:>10.1f} {r.jct_ns / 1e3:>8.1f} "
              f"{r.slowdown:>8.2f} {str(r.admitted):>8} "
              f"{r.fallback_blocks:>8}")
    print()
    for t, d in sorted(fr.per_tenant.items()):
        print(f"tenant {names[t]:>10}: jobs={d['jobs']} "
              f"mean_jct={d['mean_jct_ns'] / 1e3:.1f}us "
              f"mean_slowdown={d['mean_slowdown']:.2f} "
              f"degraded={d['degraded_jobs']} "
              f"fallback_blocks={d['fallback_blocks']}")
    print(f"\nJain fairness across tenants: {fr.jain_fairness:.3f}")
    print(f"all reductions exact: {fr.correct}")
    if not fr.correct:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
