"""Run an instrumented cell and export its telemetry (Perfetto + series).

Usage::

    PYTHONPATH=src python scripts/export_telemetry.py \
        --out trace.json --csv series.csv

Runs the headline congested fat-tree cell (half the hosts allreduce under
CANARY, the other half generate background congestion, sender-side noise so
descriptor timeout flushes actually occur) with the telemetry hub enabled,
then writes:

* ``--out``  — Perfetto / Chrome trace-event JSON. Open it in
  https://ui.perfetto.dev: block-lifecycle spans under the *apps* process,
  descriptor aggregation windows under *switches*, transport instants under
  *hosts*, and every probe series as a counter track.
* ``--csv``  — flat ``series,t_ns,value`` rows for pandas/gnuplot.
* ``--series-json`` — the same series as one JSON object (with hi/lo).
* ``--dump`` — full-fidelity telemetry dump (spans, instants, series,
  metadata, truncation) — the input format of ``scripts/diagnose.py``.

The emitted trace is schema-checked (``validate_perfetto``) before the
script exits 0 — CI runs this as the telemetry smoke step.
"""
from __future__ import annotations

import argparse
import sys

from repro.core.telemetry import (run_headline_cell, validate_perfetto,
                                  write_dump, write_perfetto,
                                  write_series_csv, write_series_json)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=int, default=8,
                    help="fabric scale (scaled_config leaves/spines; "
                         "default 8 = 64 hosts)")
    ap.add_argument("--data-bytes", type=int, default=1 << 20,
                    help="allreduce payload per host (default 1 MiB)")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--probe-ns", type=float, default=None,
                    help="override the probe cadence (sim ns)")
    ap.add_argument("--out", default="telemetry_trace.json",
                    help="Perfetto trace-event JSON path")
    ap.add_argument("--csv", default=None, help="flat series CSV path")
    ap.add_argument("--series-json", default=None,
                    help="series-as-JSON path (includes per-series hi/lo)")
    ap.add_argument("--dump", default=None,
                    help="full-fidelity dump path (scripts/diagnose.py "
                         "input)")
    args = ap.parse_args(argv)

    overrides = {}
    if args.probe_ns is not None:
        overrides["telemetry_probe_ns"] = args.probe_ns
    sim = run_headline_cell(scale=args.scale, data_bytes=args.data_bytes,
                            seed=args.seed, **overrides)
    res = sim.telemetry_result
    print(res.summary())
    for k, v in sorted(res.telemetry_summary.items()):
        print(f"  {k} = {v}")

    doc = write_perfetto(sim.telemetry, args.out)
    errs = validate_perfetto(doc)
    if errs:
        print(f"INVALID trace ({len(errs)} violations):", file=sys.stderr)
        for e in errs[:10]:
            print(f"  {e}", file=sys.stderr)
        raise SystemExit(1)
    print(f"wrote {args.out} ({len(doc['traceEvents'])} trace events) "
          f"-> load in https://ui.perfetto.dev")
    if args.csv:
        n = write_series_csv(sim.telemetry, args.csv)
        print(f"wrote {args.csv} ({n} samples)")
    if args.series_json:
        n = write_series_json(sim.telemetry, args.series_json)
        print(f"wrote {args.series_json} ({n} samples)")
    if args.dump:
        doc = write_dump(sim.telemetry, args.dump)
        print(f"wrote {args.dump} ({len(doc['spans'])} spans, "
              f"{len(doc['instants'])} instants, "
              f"{len(doc['series'])} series) "
              f"-> diagnose with scripts/diagnose.py --dump {args.dump}")


if __name__ == "__main__":
    main()
