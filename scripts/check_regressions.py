"""Perf-regression gate: compare benchmark artifacts against baselines.

Usage::

    # after `python -m benchmarks.perf` / `python -m benchmarks.run`
    PYTHONPATH=src python scripts/check_regressions.py

    # explicit locations
    PYTHONPATH=src python scripts/check_regressions.py \
        --baselines benchmarks/regression_baselines.json --dir .

Reads the committed baseline file (``benchmarks/regression_baselines.json``)
and checks every constraint against the named result JSONs
(``PERF_RESULTS.json``, ``BENCH_RESULTS.json``, ...). Exits non-zero on any
breach — CI runs this as the regression-gate step on the FAST bench
artifacts.

Baseline schema (per file)::

    {"files": {
       "PERF_RESULTS.json": {
          "profile_key": "fast",          # doc[profile_key] picks fast/full
          "any":  {"<dotted.path>": CONSTRAINT, ...},   # both profiles
          "fast": {...},                                 # doc[key] truthy
          "full": {...}                                  # doc[key] falsy
       }}}

``<dotted.path>`` navigates nested dicts (path components may contain ``/``
— only ``.`` separates). CONSTRAINT is one object with any of:

* ``{"min": x}`` / ``{"max": x}`` — bound a numeric cell. Use for metrics
  that survive machine variance: speedup *ratios* (A/B in one process),
  overhead budgets, and loose pathology ceilings on wall-clock.
* ``{"ref": x, "rel_tol": t}`` — ``|v - ref| <= t * max(|ref|, eps)``.
  With ``rel_tol: 0`` this pins determinism-backed values exactly (event
  counts: the simulator is deterministic, so FAST-profile counts are
  machine-independent; update the baseline deliberately when a PR changes
  protocol behavior).
* ``{"equals": v}`` — exact equality (booleans, strings).
* ``{"empty": true}`` — the cell must be an empty list/dict.
* ``"reason": "..."`` — ignored; documents why the cell is gated.

A file listed in the baselines but absent on disk is skipped with a notice
(so the gate runs on whatever subset of artifacts a step produced); pass
``--require-all`` to make absence itself a failure. A *path* missing inside
a present file is always a breach — the artifact schema regressed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Tuple

_EPS = 1e-12


def _lookup(doc, dotted: str):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(dotted)
        cur = cur[part]
    return cur


def check_constraint(value, spec: dict) -> Tuple[bool, str]:
    """Evaluate one constraint; returns (ok, human description)."""
    desc = []
    ok = True
    if "min" in spec:
        desc.append(f">= {spec['min']}")
        ok &= isinstance(value, (int, float)) and value >= spec["min"]
    if "max" in spec:
        desc.append(f"<= {spec['max']}")
        ok &= isinstance(value, (int, float)) and value <= spec["max"]
    if "ref" in spec:
        tol = float(spec.get("rel_tol", 0.0))
        desc.append(f"= {spec['ref']} ±{tol * 100:g}%")
        ok &= isinstance(value, (int, float)) and \
            abs(value - spec["ref"]) <= tol * max(abs(spec["ref"]), _EPS)
    if "equals" in spec:
        desc.append(f"== {spec['equals']!r}")
        ok &= value == spec["equals"]
    if "empty" in spec:
        desc.append("empty")
        ok &= hasattr(value, "__len__") and len(value) == 0
    return ok, " and ".join(desc) or "(no constraint)"


def check_file(path: str, rules: dict) -> List[Tuple[str, str, str, bool]]:
    """Check one artifact; returns rows (path, value, constraint, ok)."""
    with open(path) as f:
        doc = json.load(f)
    profiles = {"any"}
    key = rules.get("profile_key")
    if key is not None:
        profiles.add("fast" if doc.get(key) else "full")
    rows = []
    for profile in ("any", "fast", "full"):
        if profile not in profiles:
            continue
        for dotted, spec in sorted(rules.get(profile, {}).items()):
            try:
                value = _lookup(doc, dotted)
            except KeyError:
                rows.append((dotted, "<missing>",
                             "path must exist in artifact", False))
                continue
            ok, desc = check_constraint(value, spec)
            shown = value if not isinstance(value, float) \
                else f"{value:.6g}"
            rows.append((dotted, str(shown), desc, ok))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baselines",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "benchmarks",
                                         "regression_baselines.json"))
    ap.add_argument("--dir", default=".",
                    help="directory holding the result JSONs (default: .)")
    ap.add_argument("--require-all", action="store_true",
                    help="fail if any baselined artifact file is absent")
    ap.add_argument("files", nargs="*",
                    help="check only these artifact names (default: every "
                         "file named in the baselines)")
    args = ap.parse_args(argv)

    with open(args.baselines) as f:
        base = json.load(f)
    files = base.get("files", {})
    if args.files:
        unknown = [f for f in args.files if f not in files]
        if unknown:
            print(f"no baselines for: {unknown}", file=sys.stderr)
            raise SystemExit(2)
        files = {k: files[k] for k in args.files}

    breaches = 0
    checked = 0
    for name, rules in sorted(files.items()):
        path = os.path.join(args.dir, name)
        if not os.path.exists(path):
            if args.require_all:
                print(f"MISSING {name}: artifact not found")
                breaches += 1
            else:
                print(f"skip {name}: not present")
            continue
        print(f"{name}:")
        for dotted, shown, desc, ok in check_file(path, rules):
            checked += 1
            mark = "ok  " if ok else "FAIL"
            print(f"  {mark} {dotted} = {shown}  (want {desc})")
            if not ok:
                breaches += 1
    print(f"{checked} cells checked, {breaches} breach(es)")
    if breaches:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
