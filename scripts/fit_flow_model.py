"""Fit the flow model's calibration constants against packet-engine sweeps.

Usage::

    PYTHONPATH=src python scripts/fit_flow_model.py ref1.json ref2.json ...

Each input is a ``benchmarks/sweep.py`` output document produced by the
*packet* backend (any mix of topologies/scales — the fit pools them). For
every (topology, algorithm family) present, the script grid-searches the
:class:`repro.core.flow.calibrate.FamilyParams` constants that minimize the
worst relative runtime error over that family's cells, prints the fitted
table in copy-pastable form plus per-cell residuals, and exits non-zero if
the best fit still leaves a cell beyond ``--tol``.

This is the *refit* path referred to in ``calibrate.py`` — the constants it
prints are reviewed and pinned there by hand, never applied automatically.
"""
from __future__ import annotations

import argparse
import itertools
import json
import sys

from repro.core.flow.calibrate import CALIBRATION, FamilyParams
from repro.core.flow.model import lower_item, solve_cell


def _cells_from_doc(doc: dict):
    """Pair the document's work items with its measured runtimes. Documents
    written before work items were embedded fall back to re-expanding the
    suite — only valid when the BENCH_* env matches the original run."""
    items = doc.get("items")
    if items is None:
        from benchmarks.sweep import expand_suite
        items = expand_suite(doc["suite"], doc["topology"], doc["reps"])
    measured = {(c["label"], c["rep"]): c["runtime_us"]
                for c in doc["results"]}
    out = []
    for it in items:
        key = (it["label"], it["rep"])
        if key in measured:
            out.append((it, measured[key]))
    return out


def _family(item) -> str:
    return item["algo"]


def _topology_kind(item) -> str:
    return item["cfg"]["topology"]


def _eval(cells, params: FamilyParams):
    """Max and per-cell relative runtime error under ``params``."""
    errs = []
    for item, meas_us in cells:
        CALIBRATION[(_topology_kind(item), _family(item))] = params
        cell = lower_item(item)
        t_ns, _ = solve_cell(cell)
        errs.append(((item["label"], item["rep"], item["data_bytes"]),
                     (t_ns / 1e3 - meas_us) / meas_us, t_ns / 1e3, meas_us))
    return errs


# message sizes at or below this are smoke-scale cells: they are gated by
# validate.FAST_TOLERANCE, not the mid-scale acceptance bound
SMOKE_MAX_BYTES = 128 * 1024


def _tol_for(nbytes: int, tol: float, smoke_tol: float) -> float:
    return smoke_tol if nbytes <= SMOKE_MAX_BYTES else tol


def _agg_err(errs, per_label: bool, tol: float, smoke_tol: float):
    """Objective: worst *tolerance-normalized* |relative error| on
    per-(label, scale)-mean runtimes — exactly the contract ``validate.py``
    enforces: smoke-scale cells get the loose FAST bound, and a label whose
    packet reps spread further apart than its own tolerance is exempt (a
    self-inconsistent reference is noise, not a standard). <= 1.0 passes.

    ``per_label=False`` drops to raw worst per-cell error (debug)."""
    if not per_label:
        return max(abs(e[1]) for e in errs)
    by_label = {}
    for (label, _rep, nbytes), _e, pred, meas in errs:
        by_label.setdefault((label, nbytes), [[], []])
        by_label[(label, nbytes)][0].append(pred)
        by_label[(label, nbytes)][1].append(meas)
    worst = 0.0
    for (label, nbytes), (preds, meass) in by_label.items():
        tol_s = _tol_for(nbytes, tol, smoke_tol)
        if max(meass) / min(meass) - 1.0 > tol_s:
            continue        # reference unstable at this label/scale
        p, m = sum(preds) / len(preds), sum(meass) / len(meass)
        worst = max(worst, abs(p - m) / m / tol_s)
    return worst


GRIDS = {
    "canary": dict(
        kappa=[0.6, 0.8, 1.0],
        floor=[0.04, 0.05, 0.06, 0.08, 0.10],
        mu=[1.0, 1.2, 1.4, 1.6, 1.8],
        nu=[0.5, 1.0, 1.5, 2.0],
        sigma=[0.0, 0.5, 1.0, 1.5, 2.0],
        mu_ntree=[0.0],
        pool=[1.0]),
    "static_tree": dict(
        kappa=[0.9, 1.0, 1.1, 1.2, 1.35],
        floor=[0.04, 0.05, 0.055, 0.06, 0.08],
        mu=[1.4, 1.8, 2.0, 2.4],
        nu=[0.0, 1.0],
        sigma=[0.0],
        mu_ntree=[0.0, 0.4, 0.8],
        pool=[1.0, 0.97, 0.95, 0.93, 0.9, 0.85]),
}


def fit_family(cells, family: str, per_label: bool, tol: float,
               smoke_tol: float):
    grid = GRIDS.get(family, GRIDS["static_tree"])
    names = list(grid)
    best, best_err = None, float("inf")
    for combo in itertools.product(*(grid[n] for n in names)):
        params = FamilyParams(**dict(zip(names, combo)))
        err = _agg_err(_eval(cells, params), per_label, tol, smoke_tol)
        if err < best_err:
            best, best_err = params, err
    return best, best_err


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("refs", nargs="+", help="packet sweep JSON documents")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="acceptance bound for mid-scale label means "
                         "(validate.MID_TOLERANCE)")
    ap.add_argument("--smoke-tol", type=float, default=0.60,
                    help="bound for smoke-scale (<=128 KiB) label means "
                         "(validate.FAST_TOLERANCE)")
    ap.add_argument("--per-cell", action="store_true",
                    help="fit worst per-(cell,rep) error instead of "
                         "per-label means")
    args = ap.parse_args(argv)

    groups = {}
    for path in args.refs:
        doc = json.load(open(path))
        if doc.get("backend", "packet") != "packet":
            raise SystemExit(f"{path}: not a packet-backend document")
        for item, meas in _cells_from_doc(doc):
            groups.setdefault((_topology_kind(item), _family(item)),
                              []).append((item, meas))

    ok = True
    for (topo, family), cells in sorted(groups.items()):
        params, err = fit_family(cells, family, not args.per_cell,
                                 args.tol, args.smoke_tol)
        status = "OK " if err <= 1.0 else "FAIL"
        print(f"[{status}] ({topo!r}, {family!r}): worst normalized err "
              f"{err:.2f} (1.0 = at tolerance)  ->  {params}")
        for key, e, pred, meas in sorted(_eval(cells, params)):
            tol_s = _tol_for(key[2], args.tol, args.smoke_tol)
            flag = f"  <-- beyond {tol_s:.0%}" if abs(e) > tol_s else ""
            print(f"    {key[0]:24s} rep{key[1]} {key[2] // 1024:5d}KiB  "
                  f"pred={pred:9.1f}us meas={meas:9.1f}us  "
                  f"err={e * 100:+6.1f}%{flag}")
        ok &= err <= 1.0
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
