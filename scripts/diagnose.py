"""Diagnose a run: critical-path slowdown attribution + hotspot ranking.

Usage::

    # diagnose an exported telemetry dump (scripts/export_telemetry.py --dump)
    PYTHONPATH=src python scripts/diagnose.py --dump telemetry_dump.json

    # or run a live instrumented cell and diagnose it in one step
    PYTHONPATH=src python scripts/diagnose.py --scenario headline --scale 8

    # machine-readable output for CI / tooling
    PYTHONPATH=src python scripts/diagnose.py --scenario hot_link \
        --json diagnosis_report.json

Prints the human "why was this slow" report (ARCHITECTURE.md §Diagnosis):
per-cause share of the critical path under the closed taxonomy
(wire / queueing / timeout_flush / collision_bypass / retx_recovery /
dcqcn_pacing / pfc_pause / bcast_tail / fault_recovery / other,
conservation property-tested),
the top congestion hotspots by mean queueing delay, and per-app/per-tenant
breakdowns. ``--json`` additionally writes the full machine report.

``--expect-top CAUSE`` exits non-zero unless CAUSE is the top contributor —
the injected-bottleneck scenarios below use it as their acceptance check:

* ``headline``    — the congested headline cell (background traffic + noise)
* ``hot_link``    — single-spine fat tree: all cross-leaf traffic shares one
  known uplink (expected top cause: ``queueing``)
* ``collisions``  — ``table_size=1``: every concurrent block collides and
  bypasses (expected: ``collision_bypass``)
* ``loss_gbn``    — lossy wire under go-back-N (expected: ``retx_recovery``)
* ``dcqcn``       — aggressive ECN marking + slow rate recovery (expected:
  ``dcqcn_pacing``)
* ``fault``       — mid-run spine crash + recovery under go-back-N
  (expected: ``fault_recovery``)
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.telemetry import diagnose, load_dump, view_of

# injected-bottleneck scenario presets: each makes ONE cause dominant on
# purpose; tests/core/test_diagnosis.py pins that the diagnosis names it
SCENARIOS = {
    "headline": {"expect": None, "overrides": {}},
    # one spine: every cross-leaf packet serializes through leaf*->spine0,
    # and a long descriptor timeout keeps timeout_flush out of the picture
    "hot_link": {"expect": "queueing",
                 "overrides": {"num_spines": 1, "timeout_ns": 5e5,
                               "noise_prob": 0.0}},
    # a one-slot descriptor table: concurrent blocks always collide and
    # bypass to the leader (no background blast — the bottleneck is the
    # leader convoy itself; the default 1us descriptor timeout keeps the
    # slot churning so collisions stay the dominant mechanism); raise the
    # pkt-instant cap so the evidence instants actually get recorded
    "collisions": {"expect": "collision_bypass", "background": False,
                   "overrides": {"table_size": 1, "noise_prob": 0.0,
                                 "telemetry_max_pkt_instants": 200000,
                                 "telemetry_max_spans": 300000}},
    # iid wire loss under go-back-N: recovery stalls of gbn_timeout_ns
    # dominate the block spans
    "loss_gbn": {"expect": "retx_recovery",
                 "overrides": {"transport": "gbn", "drop_prob": 2e-3,
                               "noise_prob": 0.0, "timeout_ns": 5e5}},
    # DCQCN with hair-trigger ECN marking, deep cuts and glacial recovery:
    # hosts spend the run paced far below line rate
    "dcqcn": {"expect": "dcqcn_pacing",
              "overrides": {"transport": "dcqcn", "noise_prob": 0.0,
                            "timeout_ns": 5e5,
                            "ecn_kmin_bytes": 4096,
                            "ecn_kmax_bytes": 16384,
                            "ecn_pmax": 1.0}},
    # mid-run spine crash + recovery (repro.core.faults): blocks in flight
    # stall on the dead switch until the heal, so the fault window dominates
    # the critical path. The crashed spine is chosen per scale in
    # run_scenario (the middle spine, gid scale + scale//2).
    "fault": {"expect": "fault_recovery",
              "overrides": {"transport": "gbn", "retx_timeout_ns": 5e4,
                            "noise_prob": 0.0}},
}


def run_scenario(name: str, scale: int, data_bytes: int, seed: int):
    from repro.core.telemetry import run_headline_cell
    spec = SCENARIOS[name]
    overrides = dict(spec["overrides"])
    if name == "fault":
        # the spine gid depends on the fabric scale, so the schedule cannot
        # be a static override: crash the middle spine mid-run, heal late
        overrides["faults"] = [{"kind": "switch_crash",
                                "target": scale + scale // 2,
                                "at_ns": 5000.0, "heal_ns": 45000.0}]
    return run_headline_cell(scale=scale, data_bytes=data_bytes, seed=seed,
                             background=spec.get("background", True),
                             **overrides)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--dump", default=None,
                     help="telemetry dump JSON "
                          "(scripts/export_telemetry.py --dump)")
    src.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                     help="run a live instrumented cell and diagnose it")
    ap.add_argument("--scale", type=int, default=8,
                    help="fabric scale for --scenario (default 8)")
    ap.add_argument("--data-bytes", type=int, default=1 << 20)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--top-links", type=int, default=10,
                    help="hotspot links to report (default 10)")
    ap.add_argument("--json", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--expect-top", default=None,
                    help="exit 1 unless this cause is the top contributor "
                         "(default for a --scenario: its injected cause)")
    args = ap.parse_args(argv)

    if args.dump:
        view = load_dump(args.dump)
        expect = args.expect_top
    else:
        scenario = args.scenario or "headline"
        sim = run_scenario(scenario, args.scale, args.data_bytes, args.seed)
        print(sim.telemetry_result.summary())
        view = view_of(sim.telemetry)
        expect = args.expect_top or SCENARIOS[scenario]["expect"]

    diag = diagnose(view, top_links=args.top_links)
    print(diag.to_text())

    if args.json:
        with open(args.json, "w") as f:
            json.dump(diag.to_json(), f, indent=1)
        print(f"wrote {args.json}")

    if expect:
        top = diag.top_cause()
        if top != expect:
            print(f"FAIL: expected top cause {expect!r}, diagnosed {top!r}",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: top cause is {top!r} as expected")


if __name__ == "__main__":
    main()
