"""Fig. 9: allreduce runtime vs message size (20% hosts allreduce, 80%
congestion). Small messages expose the timeout latency; large messages are
bandwidth-dominated."""
from __future__ import annotations

from repro.core.canary import Algo, run_allreduce

from .common import FAST, PAPER, bench_cfg, bench_hosts, emit, timed


def main(reps: int = 1) -> None:
    cfg = bench_cfg()
    n = bench_hosts(0.20)
    kib = 1024
    sizes = (1 * kib, 64 * kib) if FAST else \
        (1 * kib, 16 * kib, 256 * kib, 1024 * kib) + \
        ((4096 * kib,) if PAPER else ())
    for cong in (False, True):
        for size in sizes:
            for algo, nt, label in ((Algo.RING, 1, "ring"),
                                    (Algo.STATIC_TREE, 4, "static4"),
                                    (Algo.CANARY, 1, "canary")):
                r, us = timed(run_allreduce, cfg, algo, n, size, n_trees=nt,
                              congestion=cong, reps=reps)
                emit(f"fig9/{label}/{size//kib}KiB/cong={int(cong)}", us,
                     f"runtime_us={r.runtime_us_mean:.1f};"
                     f"correct={r.correct}")


if __name__ == "__main__":
    main()
