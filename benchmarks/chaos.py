"""Chaos suite: survivability under mid-run fault schedules.

Grid: fault rate x algorithm x fabric x transport. Each fabric gets three
schedules built from its own geometry (targets depend on switch gids):

* ``none``  — no faults: the per-cell baseline for slowdown ratios
* ``single``— one mid-run aggregation-switch crash + recovery
* ``storm`` — the crash plus a flapping uplink and a recoverable straggler

Every ``gbn`` cell runs under background congestion and asserts the
survivability invariant — the reduction stays *exact* under any fault
schedule. ``none``-transport cells run uncongested and measure instead of
assert: their ``correct`` flag and per-cause drop split land in the JSON
so losses are visible, never hidden (an algorithm with no loss detection
of its own simply ends incomplete).

The headline rows report graceful degradation: CANARY's faulted/clean
slowdown against STATIC_TREE's on the same schedule (ratio > 1 means the
dynamic trees degrade more gracefully than the static tree).

Writes ``CHAOS_RESULTS.json`` (override with ``BENCH_CHAOS_JSON``), gated
by ``scripts/check_regressions.py`` against
``benchmarks/regression_baselines.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

from repro.core.canary import Algo, run_allreduce, three_tier_config

from .common import FAST, PAPER, bench_cfg, emit, provenance, timed

ALGOS = ((Algo.CANARY, "canary"), (Algo.STATIC_TREE, "static1"),
         (Algo.RING, "ring"))
TRANSPORTS = ("none", "gbn")


def _fabrics():
    fat = bench_cfg(retx_timeout_ns=5e4)
    if FAST:
        tt = three_tier_config(seed=fat.seed, retx_timeout_ns=5e4)
    elif PAPER:
        tt = three_tier_config(num_pods=8, leaves_per_pod=4,
                               hosts_per_leaf=16, aggs_per_pod=4,
                               num_cores=16, seed=fat.seed,
                               retx_timeout_ns=5e4)
    else:
        tt = three_tier_config(hosts_per_leaf=8, seed=fat.seed,
                               retx_timeout_ns=5e4)
    return (("fat_tree", fat), ("three_tier", tt))


def _bench_bytes() -> int:
    if PAPER:
        return 2 ** 20
    return 64 * 2 ** 10 if FAST else 256 * 2 ** 10


def _static_root(cfg, n: int, size: int) -> int:
    """The switch the static tree actually aggregates through. Roots are
    drawn at job setup (Simulator construction), so a probe build — never
    run — reveals the exact gid the benchmark should crash. Crashing it is
    the survivability story: CANARY merely loses one of many spines, the
    static tree loses its root."""
    from repro.core.canary.algorithms import build_cell_simulator
    probe = build_cell_simulator(cfg, Algo.STATIC_TREE, n, size,
                                 congestion=True, rep=0)
    return probe.strategy.roots[0][0]


def _schedules(fabric: str, cfg, agg: int) -> Dict[str, List[dict]]:
    """Fault schedules sized to the fabric: crash the static tree's root
    switch (``agg``), flap a known uplink, park one participant."""
    uplink = "leaf0->spine0" if fabric == "fat_tree" else "leaf0->agg0"
    crash = {"kind": "switch_crash", "target": agg,
             "at_ns": 5000.0, "heal_ns": 20000.0}
    flap = {"kind": "link_flap", "target": uplink, "at_ns": 1000.0,
            "down_ns": 500.0, "period_ns": 4000.0, "cycles": 3}
    slow = {"kind": "host_slow", "target": 1, "at_ns": 500.0,
            "heal_ns": 10000.0}
    return {"none": [], "single": [crash], "storm": [crash, flap, slow]}


def _cell(cfg, algo, label, fabric, rate, faults, n, size, transport,
          cells: List[Dict[str, object]]) -> float:
    tcfg = dataclasses.replace(cfg, transport=transport, faults=faults)
    tag = f"chaos/{fabric}/{label}/{rate}/{transport}"
    # background congestion only under the reliable transport: gbn
    # guarantees every cell terminates. A bare-transport cell whose
    # algorithm has no loss detection (static tree, ring) can strand its
    # app forever after a fault drop, and congestion noise would then pump
    # events until the budget trips — uncongested, the queue drains and
    # the cell ends with the loss *measured* (correct=False in the JSON).
    r, us = timed(run_allreduce, tcfg, algo, n, size,
                  congestion=(transport == "gbn"), reps=1)
    sim_res = r.reps[0]
    if transport == "gbn":
        assert r.correct, (f"{tag}: the survivability invariant broke — "
                           f"gbn must stay exact under any fault schedule")
    survived = sim_res.survived
    recovery = sim_res.fault_recovery_ns
    cells.append(dict(
        fabric=fabric, algo=label, transport=transport, fault_rate=rate,
        hosts=n, data_bytes=size,
        runtime_us=round(r.runtime_us_mean, 3),
        goodput_gbps=round(r.goodput_gbps_mean, 3),
        correct=r.correct,
        survival_rate=(sum(survived.values()) / len(survived)
                       if survived else 1.0),
        max_recovery_us=round(max(recovery.values()) / 1e3, 3)
        if recovery else 0.0,
        fault_events=len(sim_res.fault_events),
        retransmissions=sim_res.retransmissions,
        drop_causes=sim_res.drop_causes,
    ))
    emit(tag, us, f"runtime_us={r.runtime_us_mean:.1f};correct={r.correct}")
    return r.runtime_us_mean


def main() -> None:
    size = _bench_bytes()
    cells: List[Dict[str, object]] = []
    headline: List[Dict[str, object]] = []
    skipped: List[Dict[str, object]] = []

    for fabric, cfg in _fabrics():
        n = max(2, cfg.num_hosts // 2)
        schedules = _schedules(fabric, cfg, _static_root(cfg, n, size))
        runtimes: Dict[tuple, float] = {}
        for rate, faults in schedules.items():
            for transport in TRANSPORTS:
                for algo, label in ALGOS:
                    if (label, transport, fabric) == \
                            ("ring", "gbn", "three_tier"):
                        # per-flow go-back-N over the ring's long host
                        # chains on 4-hop folded-Clos paths costs tens of
                        # seconds per cell at any size (pre-existing, not
                        # fault-related) — skipped, and said so
                        skipped.append(dict(
                            fabric=fabric, algo=label, transport=transport,
                            fault_rate=rate,
                            reason="ring+gbn on three_tier is "
                                   "prohibitively slow at bench scale"))
                        continue
                    runtimes[(label, rate, transport)] = _cell(
                        cfg, algo, label, fabric, rate, faults, n, size,
                        transport, cells)
        # graceful degradation: faulted/clean slowdown, CANARY vs the
        # static tree, per schedule, under the reliable transport
        for rate in ("single", "storm"):
            canary_sd = (runtimes[("canary", rate, "gbn")]
                         / runtimes[("canary", "none", "gbn")])
            static_sd = (runtimes[("static1", rate, "gbn")]
                         / runtimes[("static1", "none", "gbn")])
            headline.append(dict(
                fabric=fabric, fault_rate=rate, transport="gbn",
                canary_slowdown=round(canary_sd, 4),
                static_slowdown=round(static_sd, 4),
                degradation_ratio=round(static_sd / canary_sd, 4)))
            emit(f"chaos/headline/{fabric}/{rate}", 0.0,
                 f"canary_slowdown={canary_sd:.2f};"
                 f"static_slowdown={static_sd:.2f}")

    # gate-friendly rollup: check_regressions.py navigates dicts, not lists
    gbn = [c for c in cells if c["transport"] == "gbn"]
    summary = dict(
        gbn_cells=len(gbn),
        gbn_all_correct=all(c["correct"] for c in gbn),
        gbn_min_survival_rate=min(c["survival_rate"] for c in gbn),
        min_degradation_ratio=min(h["degradation_ratio"] for h in headline),
        headline_rows=len(headline))
    doc = dict(cells=cells, headline=headline, skipped=skipped,
               summary=summary,
               profile=("paper" if PAPER else "fast" if FAST else "default"),
               provenance=provenance())
    path = os.environ.get("BENCH_CHAOS_JSON", "CHAOS_RESULTS.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
