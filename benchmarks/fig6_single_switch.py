"""Fig. 6: single-switch aggregation goodput (Tofino prototype calibration).

Two measurements stand in for the testbed:
* the simulator's single-leaf scenario (two hosts inject, the leaf
  aggregates, calibrated to forward at line rate with 128 B payloads), and
* the Pallas packet-accumulate kernel's software-switch throughput
  (packets/s -> Gbps at the paper's 128 B useful payload).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.canary import Algo, AllreduceJob, SimConfig, Simulator
from repro.kernels.ops import packet_accumulate_op

from .common import FAST, emit, timed


def sim_single_switch() -> None:
    # two hosts on one leaf; the paper measures leaf aggregation goodput
    cfg = SimConfig(num_leaves=2, hosts_per_leaf=2, num_spines=2,
                    payload_bytes=128, table_size=65536, seed=0)
    size = (256 if FAST else 4096) * 1024
    sim = Simulator(cfg, [AllreduceJob(0, [0, 1], size)], algo=Algo.CANARY)
    r, us = timed(sim.run)
    emit("fig6/sim_leaf_128B", us,
         f"goodput_gbps={list(r.goodput_gbps.values())[0]:.1f};"
         f"correct={r.correct}")


def kernel_switch() -> None:
    n, d, slots = (1024, 32, 256) if FAST else (4096, 32, 1024)
    ids = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, slots)
    pay = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    out = packet_accumulate_op(ids, pay, slots)  # compile
    out.block_until_ready()
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        out = packet_accumulate_op(ids, pay, slots)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    payload_bytes = n * d * 4
    gbps = payload_bytes * 8 / dt / 1e9
    emit("fig6/kernel_accumulate", dt * 1e6,
         f"sw_switch_gbps={gbps:.2f};pkts={n};payload=128B")


def main() -> None:
    sim_single_switch()
    kernel_switch()


if __name__ == "__main__":
    main()
