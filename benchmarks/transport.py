"""Transport-policy suite: CANARY vs STATIC_TREE vs RING across congestion
intensities and loss rates, with the transport layer on and off, on both
fabrics (fat_tree and three_tier).

Two axes:

* **congestion** — a fraction of hosts runs the allreduce while the rest
  blast random-uniform noise; each cell runs with ``transport="none"`` and
  ``transport="dcqcn"`` (ECN marking + CNP rate control + PFC).  The headline
  rows report the Canary-vs-static-tree speedup ratio with DCQCN on vs off.
* **loss** — ``drop_prob > 0`` with ``transport="none"`` (bare whole-block
  retx timers) and ``transport="gbn"`` (per-flow go-back-N).  Every cell
  asserts the reduction stayed exact.

Writes a machine-readable JSON document (default ``TRANSPORT_RESULTS.json``,
override with ``BENCH_TRANSPORT_JSON``) carrying per-cell transport telemetry
and per-cause drop counters alongside the usual provenance block.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

from repro.core.canary import Algo, run_allreduce, three_tier_config

from .common import (FAST, PAPER, bench_cfg, bench_size, emit, provenance,
                     timed)

CONGESTION_FRACS = (0.25, 0.5, 0.75)
DROP_PROBS = (0.01,) if FAST else (0.002, 0.01)
ALGOS = ((Algo.CANARY, "canary"), (Algo.STATIC_TREE, "static1"),
         (Algo.RING, "ring"))


def _fabrics():
    fat = bench_cfg()
    if FAST:
        tt = three_tier_config(seed=fat.seed)                  # 32 hosts
    elif PAPER:
        tt = three_tier_config(num_pods=8, leaves_per_pod=4,
                               hosts_per_leaf=16, aggs_per_pod=4,
                               num_cores=16, seed=fat.seed)    # 512 hosts
    else:
        tt = three_tier_config(hosts_per_leaf=8, seed=fat.seed)  # 64 hosts
    return (("fat_tree", fat), ("three_tier", tt))


def _bench_bytes() -> int:
    if PAPER:
        return bench_size()
    return 64 * 2 ** 10 if FAST else 256 * 2 ** 10


def _cell(cfg, algo, label, fabric, n, size, transport, *, congestion,
          cells: List[Dict[str, object]], tag: str,
          require_exact: bool = True) -> float:
    tcfg = dataclasses.replace(cfg, transport=transport)
    r, us = timed(run_allreduce, tcfg, algo, n, size, congestion=congestion,
                  reps=1)
    sim_res = r.reps[0]
    if require_exact:
        assert r.correct, (f"{tag}: inexact reduction under "
                           f"transport={transport!r} on {fabric}")
    cells.append(dict(
        axis=tag.split("/", 1)[0], fabric=fabric, algo=label,
        transport=transport, hosts=n, data_bytes=size,
        drop_prob=tcfg.drop_prob, congestion=congestion,
        runtime_us=round(r.runtime_us_mean, 3),
        goodput_gbps=round(r.goodput_gbps_mean, 3),
        correct=r.correct,
        retransmissions=sim_res.retransmissions,
        drop_causes=sim_res.drop_causes,
        transport_stats=sim_res.transport_stats,
    ))
    emit(tag, us,
         f"runtime_us={r.runtime_us_mean:.1f};correct={r.correct}")
    return r.runtime_us_mean


def main() -> None:
    size = _bench_bytes()
    cells: List[Dict[str, object]] = []
    headline: List[Dict[str, object]] = []

    for fabric, cfg in _fabrics():
        # ---- congestion axis: none vs dcqcn under background noise --------
        for frac in CONGESTION_FRACS:
            n = max(2, int(cfg.num_hosts * frac))
            runtimes: Dict[tuple, float] = {}
            for transport in ("none", "dcqcn"):
                for algo, label in ALGOS:
                    tag = (f"transport/{fabric}/{label}/frac{frac:.0%}"
                           f"/{transport}")
                    runtimes[(label, transport)] = _cell(
                        cfg, algo, label, fabric, n, size, transport,
                        congestion=True, cells=cells, tag=tag)
            for transport in ("none", "dcqcn"):
                speedup = (runtimes[("static1", transport)]
                           / runtimes[("canary", transport)])
                headline.append(dict(
                    fabric=fabric, congestion_frac=frac, transport=transport,
                    canary_vs_static_speedup=round(speedup, 4)))
                emit(f"transport/headline/{fabric}/frac{frac:.0%}"
                     f"/{transport}", 0.0,
                     f"canary_vs_static_speedup={speedup:.3f}")

        # ---- loss axis: none vs gbn under drop_prob > 0 -------------------
        # Under the bare transport only CANARY recovers from loss (its FAIL
        # protocol arms whole-block retx timers); RING and STATIC_TREE have
        # no loss recovery of their own, so exactness is only asserted where
        # it is guaranteed: canary always, everything once gbn is on.
        for drop in DROP_PROBS:
            lcfg = dataclasses.replace(cfg, drop_prob=drop)
            n = max(2, int(cfg.num_hosts * 0.5))
            for transport in ("none", "gbn"):
                for algo, label in ALGOS:
                    tag = (f"loss/{fabric}/{label}/drop{drop:g}"
                           f"/{transport}")
                    _cell(lcfg, algo, label, fabric, n, size, transport,
                          congestion=False, cells=cells, tag=tag,
                          require_exact=(transport == "gbn"
                                         or label == "canary"))

    doc = dict(cells=cells, headline=headline, provenance=provenance())
    path = os.environ.get("BENCH_TRANSPORT_JSON", "TRANSPORT_RESULTS.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
