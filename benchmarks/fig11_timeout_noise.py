"""Fig. 11: sensitivity to the aggregation timeout and sender-side OS noise
(each send delayed 1us with probability p), with and without congestion."""
from __future__ import annotations

import dataclasses

from repro.core.canary import Algo, run_allreduce

from .common import FAST, bench_cfg, bench_hosts, bench_size, emit, timed


def main(reps: int = 1) -> None:
    base = bench_cfg()
    n = bench_hosts(0.5)
    size = bench_size()
    timeouts = (1000.0,) if FAST else (1000.0, 2000.0, 3000.0)
    probs = (0.01,) if FAST else (0.0001, 0.01, 0.10)
    for cong in (False, True):
        # static-tree reference (noise applies to it too)
        r, us = timed(run_allreduce, base, Algo.STATIC_TREE, n, size,
                      n_trees=4, congestion=cong, reps=reps)
        emit(f"fig11/static4/cong={int(cong)}", us,
             f"goodput_gbps={r.goodput_gbps_mean:.1f}")
        for to in timeouts:
            for p in probs:
                cfg = dataclasses.replace(base, timeout_ns=to, noise_prob=p,
                                          noise_delay_ns=1000.0)
                r, us = timed(run_allreduce, cfg, Algo.CANARY, n, size,
                              congestion=cong, reps=reps)
                s = r.reps[0]
                emit(f"fig11/canary/t={to:.0f}ns/p={p}/cong={int(cong)}", us,
                     f"goodput_gbps={r.goodput_gbps_mean:.1f};"
                     f"stragglers={s.stragglers};correct={r.correct}")


if __name__ == "__main__":
    main()
