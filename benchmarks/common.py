"""Shared benchmark infrastructure.

Default profile is a proportionally scaled-down fat tree (64 hosts, 8x8x8,
full bisection, same 50% background-load geometry as the paper's 1024-host
network) so the whole suite runs on CPU in minutes. ``--paper-scale`` (or
BENCH_PAPER_SCALE=1) switches to the paper's exact 1024-host network;
BENCH_FAST=1 shrinks further for CI smoke.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List

from repro.core.canary import SimConfig, paper_config, scaled_config

PAPER = bool(int(os.environ.get("BENCH_PAPER_SCALE", "0")))
FAST = bool(int(os.environ.get("BENCH_FAST", "0")))


def bench_cfg(**overrides) -> SimConfig:
    if PAPER:
        return paper_config(**overrides)
    if FAST:
        return scaled_config(4, **overrides)
    return scaled_config(8, **overrides)


def bench_hosts(fraction: float) -> int:
    cfg = bench_cfg()
    return max(2, int(cfg.num_hosts * fraction))


def bench_size() -> int:
    if PAPER:
        return 4 * 2 ** 20          # the paper's 4 MiB
    if FAST:
        return 128 * 2 ** 10
    return 2 ** 20                  # 1 MiB at 1/16 scale


def provenance() -> Dict[str, object]:
    """Environment fingerprint recorded in every benchmark JSON document, so
    ``BENCH_*.json`` / ``sweep_*.json`` trajectories are comparable across
    machines and env-knob settings (a FAST run and a paper-scale run must
    never be mistaken for each other)."""
    import platform
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    return dict(
        bench_fast=FAST,
        bench_paper_scale=PAPER,
        sweep_reps=os.environ.get("SWEEP_REPS"),
        git_sha=sha,
        python=platform.python_version(),
        platform=platform.platform(),
        cpu_count=os.cpu_count(),
    )


ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
