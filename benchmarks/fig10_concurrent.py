"""Fig. 10: multiple concurrent allreduces (multi-tenancy, §3.4/§5.2.4):
average per-app goodput and link utilization as tenant count grows. The
descriptor table is statically partitioned across apps, as the paper does
for its static-tree baselines and Canary alike in this experiment."""
from __future__ import annotations

import dataclasses
import statistics

from repro.core.canary import Algo, run_allreduce

from .common import FAST, bench_cfg, bench_size, emit, timed


def main(reps: int = 1) -> None:
    cfg = dataclasses.replace(bench_cfg(), partition_table=True)
    total = cfg.num_hosts  # all hosts participate across the tenants
    size = bench_size()
    counts = (2, 4) if FAST else (1, 2, 4, 8, 16)
    for apps in counts:
        for algo, nt, label in ((Algo.RING, 1, "ring"),
                                (Algo.STATIC_TREE, 1, "static1"),
                                (Algo.STATIC_TREE, 4, "static4"),
                                (Algo.CANARY, 1, "canary")):
            r, us = timed(run_allreduce, cfg, algo, total, size, n_trees=nt,
                          congestion=False, num_apps=apps, reps=reps)
            emit(f"fig10/{label}/apps={apps}", us,
                 f"goodput_gbps={r.goodput_gbps_mean:.1f};"
                 f"util_avg={statistics.mean(r.link_utilization):.3f};"
                 f"correct={r.correct}")


if __name__ == "__main__":
    main()
