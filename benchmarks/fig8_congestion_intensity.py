"""Fig. 8: goodput vs fraction of hosts running the allreduce (the rest
generate congestion)."""
from __future__ import annotations

from repro.core.canary import Algo, run_allreduce

from .common import FAST, bench_cfg, bench_hosts, bench_size, emit, timed


def main(reps: int = 1) -> None:
    cfg = bench_cfg()
    size = bench_size()
    fracs = (0.25, 0.75) if FAST else (0.05, 0.25, 0.5, 0.75)
    for frac in fracs:
        n = bench_hosts(frac)
        for algo, nt, label in ((Algo.RING, 1, "ring"),
                                (Algo.STATIC_TREE, 1, "static1"),
                                (Algo.STATIC_TREE, 4, "static4"),
                                (Algo.CANARY, 1, "canary")):
            r, us = timed(run_allreduce, cfg, algo, n, size, n_trees=nt,
                          congestion=True, reps=reps)
            emit(f"fig8/{label}/hosts{frac:.0%}", us,
                 f"goodput_gbps={r.goodput_gbps_mean:.1f};correct={r.correct}")


if __name__ == "__main__":
    main()
