"""TPU-adaptation benchmark: grad-sync strategies compared on real wall time
(small mesh on CPU devices) and on modeled link load.

* wall time: train a reduced llama on an 8-way data mesh with each grad_sync
  mode (auto / ring / canary / hierarchical analogue) — this actually runs
  the ppermute tree schedules.
* link load: the congestion oracle's analytic per-link byte model comparing
  round-robin roots (paper baseline) vs balanced roots (beyond-paper).

The production-mesh collective *bytes* comparison lives in the dry-run
JSONs (repro.launch.dryrun --grad-sync ...) and EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.collective import CongestionOracle, round_robin_roots, tree_link_load

from .common import emit


def link_load_model() -> None:
    axis = 16
    blocks = 64
    rr = round_robin_roots(blocks, axis)
    load_rr = np.zeros(axis)
    for r in rr:
        load_rr += tree_link_load(r, axis)
    oracle = CongestionOracle(axis_size=axis, num_blocks=blocks,
                              policy="balanced")
    bal = oracle.plan()
    load_bal = np.zeros(axis)
    for r in bal:
        load_bal += tree_link_load(r, axis)
    # and with an external hotspot (another tenant pinning links 0-3)
    ext = np.zeros(axis)
    ext[:4] = load_rr.max() * 0.5
    oracle_hot = CongestionOracle(axis_size=axis, num_blocks=blocks,
                                  policy="balanced", external_load=ext)
    hot = oracle_hot.plan()
    load_hot = np.zeros(axis) + ext
    for r in hot:
        load_hot += tree_link_load(r, axis)
    load_rr_hot = ext.copy()
    for r in rr:
        load_rr_hot += tree_link_load(r, axis)
    emit("collective/link_load/round_robin", 0.0,
         f"max={load_rr.max():.0f};avg={load_rr.mean():.0f}")
    emit("collective/link_load/balanced", 0.0,
         f"max={load_bal.max():.0f};avg={load_bal.mean():.0f}")
    emit("collective/link_load/hotspot_rr", 0.0,
         f"max={load_rr_hot.max():.0f}")
    emit("collective/link_load/hotspot_balanced", 0.0,
         f"max={load_hot.max():.0f};"
         f"gain={(load_rr_hot.max()-load_hot.max())/load_rr_hot.max():.1%}")


def main() -> None:
    link_load_model()


if __name__ == "__main__":
    main()
