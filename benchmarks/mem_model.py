"""§3.2.2: switch memory occupancy — analytic model vs simulation."""
from __future__ import annotations

import dataclasses

from repro.core.canary import (Algo, AllreduceJob, Simulator, paper_example)
from repro.core.canary.memory_model import model_for

from .common import bench_cfg, bench_hosts, bench_size, emit, timed


def main() -> None:
    m = paper_example()
    emit("mem_model/paper_example", 0.0,
         f"occupancy_kib={m.occupancy_kib:.1f};expected~175KiB")
    cfg = bench_cfg()
    model = model_for(cfg, diameter=2)
    for size_mult in (1, 4):
        size = bench_size() * size_mult
        sim = Simulator(cfg, [AllreduceJob(0, list(range(bench_hosts(0.5))),
                                           size)], algo=Algo.CANARY)
        r, us = timed(sim.run)
        emit(f"mem_model/sim_size_x{size_mult}", us,
             f"max_desc_bytes={r.max_descriptor_bytes};"
             f"model_bound_bytes={model.occupancy_bytes:.0f};"
             f"within_2x_bound="
             f"{r.max_descriptor_bytes <= 2 * model.occupancy_bytes}")


if __name__ == "__main__":
    main()
