"""Roofline report: reads the dry-run JSONs (experiments/dryrun/) and prints
the three-term roofline per (arch x shape x mesh) — deliverable (g)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def load_all(out_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main() -> None:
    rows = load_all()
    if not rows:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun` first")
        return
    for r in rows:
        roof = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("grad_sync", "auto") != "auto":
            name += f"/{r['grad_sync']}"
        emit(name, r["compile_s"] * 1e6,
             f"compute_s={roof['compute_s']:.4f};"
             f"memory_s={roof['memory_s']:.4f};"
             f"collective_s={roof['collective_s']:.4f};"
             f"dominant={roof['dominant']};"
             f"useful={roof['useful_flops_ratio']:.2f};"
             f"mem_gib={r['memory']['total_bytes']/2**30:.2f}")


if __name__ == "__main__":
    main()
