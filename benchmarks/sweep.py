"""Parallel sweep runner: fan simulation configs/seeds across CPU cores.

Figure suites are embarrassingly parallel — every (algorithm, congestion,
seed) cell is an independent ``Simulator`` run — but the per-figure scripts
run them serially, which is what makes the paper-scale (1024-host) sweeps
intractable on one core. This runner expands a named sweep into a work list,
executes it on a ``multiprocessing`` pool, and writes machine-readable JSON
(per-cell results + per-label aggregates + wall-clock/speedup accounting).

Usage::

    PYTHONPATH=src python -m benchmarks.sweep --suite fig7 --procs 8 \
        --out sweep_fig7.json
    PYTHONPATH=src python -m benchmarks.sweep --suite fig7 --procs 0   # serial

Suites honour the same env knobs as the rest of the benchmark suite
(``BENCH_FAST=1``, ``BENCH_PAPER_SCALE=1``). ``--topology three_tier`` runs
the same sweep on the 3-tier folded Clos.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import multiprocessing as mp
import os
import statistics
import sys
import time
from typing import Dict, List


def _default_procs() -> int:
    return max(1, (os.cpu_count() or 2) - 1)


# --------------------------------------------------------------------------
# Work items (must be picklable: plain dicts in, plain dicts out)
# --------------------------------------------------------------------------
def _base_cfg(topology: str):
    from repro.core.canary import three_tier_config

    from .common import bench_cfg
    if topology == "three_tier":
        return three_tier_config(num_pods=4, leaves_per_pod=2,
                                 hosts_per_leaf=8, aggs_per_pod=2, num_cores=4)
    if topology != "fat_tree":
        raise SystemExit(f"unknown topology {topology!r} "
                         "(have: fat_tree, three_tier)")
    return bench_cfg()


def expand_suite(suite: str, topology: str, reps: int) -> List[dict]:
    """Expand a named sweep into independent work-item dicts."""
    from .common import bench_size
    cfg = _base_cfg(topology)
    n = max(2, int(cfg.num_hosts * 0.5))  # 50% participants, like bench_hosts
    size = bench_size()
    items: List[dict] = []
    if suite == "fig7":
        # static 1/2/4/8 trees vs canary, with and without congestion
        cells = [("static1", "static_tree", 1), ("static2", "static_tree", 2),
                 ("static4", "static_tree", 4), ("static8", "static_tree", 8),
                 ("canary", "canary", 1)]
        for cong in (False, True):
            for label, algo, nt in cells:
                for rep in range(reps):
                    items.append(dict(label=f"{label}/cong={int(cong)}",
                                      algo=algo, n_trees=nt, congestion=cong,
                                      num_hosts=n, data_bytes=size, rep=rep))
    elif suite == "fig8":
        # goodput vs fraction of hosts running the allreduce, the rest
        # generating congestion (same axis as benchmarks/fig8_*.py)
        for frac in (0.05, 0.25, 0.5, 0.75):
            nf = max(2, int(cfg.num_hosts * frac))
            for algo in ("static_tree", "canary"):
                for rep in range(reps):
                    items.append(dict(label=f"{algo}/hosts={int(frac * 100)}%",
                                      algo=algo, n_trees=1, congestion=True,
                                      num_hosts=nf, data_bytes=size, rep=rep))
    elif suite == "lb":
        # load-balancing policy sensitivity under congestion
        for lb in ("ecmp", "adaptive", "per_packet"):
            for rep in range(reps):
                items.append(dict(label=f"canary/lb={lb}", algo="canary",
                                  n_trees=1, congestion=True, lb=lb,
                                  num_hosts=n, data_bytes=size, rep=rep))
    else:
        raise SystemExit(f"unknown sweep suite {suite!r} (have: fig7, fig8, lb)")
    for it in items:
        it["topology"] = topology
        it["cfg"] = dataclasses.asdict(cfg)
    return items


def run_item(item: dict) -> dict:
    """Execute one sweep cell (runs in a worker process)."""
    from repro.core.canary import Algo, SimConfig, run_allreduce
    cfg = SimConfig(**item["cfg"])
    if "lb" in item:
        cfg = dataclasses.replace(cfg, lb=item["lb"])
    t0 = time.perf_counter()
    # rep0 makes sweep cell r identical to rep r of a serial
    # run_allreduce(reps=R) call — one rep per work item, so the pool
    # load-balances cells, not whole experiments
    res = run_allreduce(cfg, Algo(item["algo"]), item["num_hosts"],
                        item["data_bytes"], n_trees=item["n_trees"],
                        congestion=item["congestion"], reps=1,
                        rep0=item["rep"])
    wall = time.perf_counter() - t0
    return dict(label=item["label"], rep=item["rep"],
                goodput_gbps=res.goodput_gbps_mean,
                runtime_us=res.runtime_us_mean,
                avg_utilization=res.avg_utilization,
                correct=res.correct,
                events=res.reps[0].events,
                wall_s=wall)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------
def run_sweep(suite: str, topology: str = "fat_tree", reps: int = 2,
              procs: int = 0) -> dict:
    """Run a sweep; ``procs=0`` means serial (in-process), ``procs>=1`` uses a
    worker pool. Returns the JSON-ready result document."""
    items = expand_suite(suite, topology, reps)
    t0 = time.perf_counter()
    if procs and procs > 1:
        ctx = mp.get_context("fork" if sys.platform == "linux" else "spawn")
        with ctx.Pool(processes=procs) as pool:
            cells = pool.map(run_item, items, chunksize=1)
    else:
        cells = [run_item(it) for it in items]
    wall = time.perf_counter() - t0
    by_label: Dict[str, List[dict]] = {}
    for c in cells:
        by_label.setdefault(c["label"], []).append(c)
    aggregates = {
        label: dict(
            goodput_gbps_mean=statistics.mean(c["goodput_gbps"] for c in cs),
            runtime_us_mean=statistics.mean(c["runtime_us"] for c in cs),
            correct=all(c["correct"] for c in cs),
            reps=len(cs),
        )
        for label, cs in sorted(by_label.items())
    }
    cpu_s = sum(c["wall_s"] for c in cells)
    return dict(
        suite=suite, topology=topology, reps=reps, procs=procs,
        cells=len(cells), wall_s=wall, cpu_s=cpu_s,
        speedup=(cpu_s / wall) if wall > 0 else 0.0,
        correct=all(c["correct"] for c in cells),
        aggregates=aggregates,
        results=cells,
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suite", default="fig7", help="fig7 | fig8 | lb")
    ap.add_argument("--topology", default="fat_tree",
                    help="fat_tree | three_tier")
    ap.add_argument("--reps", type=int,
                    default=int(os.environ.get("SWEEP_REPS", "2")))
    ap.add_argument("--procs", type=int, default=_default_procs(),
                    help="worker processes (0/1 = serial)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)
    doc = run_sweep(args.suite, args.topology, args.reps, args.procs)
    out = args.out or f"sweep_{args.suite}_{args.topology}.json"
    with open(out, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"# {doc['cells']} cells in {doc['wall_s']:.1f}s wall "
          f"({doc['cpu_s']:.1f}s cpu, {doc['speedup']:.1f}x speedup, "
          f"procs={args.procs}) correct={doc['correct']} -> {out}",
          file=sys.stderr)
    from .common import emit
    for label, agg in doc["aggregates"].items():
        # emit() also records the row for run.py's BENCH_RESULTS.json
        emit(f"sweep/{args.suite}/{label}", agg["runtime_us_mean"],
             f"goodput_gbps={agg['goodput_gbps_mean']:.1f};"
             f"correct={agg['correct']}")


if __name__ == "__main__":
    main()
