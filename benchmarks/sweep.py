"""Parallel sweep runner: fan simulation configs/seeds across CPU cores.

Figure suites are embarrassingly parallel — every (algorithm, congestion,
seed) cell is an independent ``Simulator`` run — but the per-figure scripts
run them serially, which is what makes the paper-scale (1024-host) sweeps
intractable on one core. This runner expands a named sweep into a work list,
executes it on a ``multiprocessing`` pool, and writes machine-readable JSON
(per-cell results + per-label aggregates + wall-clock/speedup accounting).

``--backend`` selects the executor (``repro.core.canary.BACKENDS``):

* ``packet`` (default) — the exact discrete-event engine, one worker
  process per cell.
* ``flow`` — the flow-level model (``repro.core.flow``): the whole matrix
  is lowered and solved as one batched JAX call in-process; ``--procs`` is
  ignored. With ``--speedup-probe N`` (default on) the first N cells are
  also run through the packet engine for a like-for-like wall-clock
  comparison, recorded under ``speedup_probe`` in the JSON.

Usage::

    PYTHONPATH=src python -m benchmarks.sweep --suite fig7 --procs 8 \
        --out sweep_fig7.json
    PYTHONPATH=src python -m benchmarks.sweep --suite fig7 --procs 0   # serial
    PYTHONPATH=src python -m benchmarks.sweep --suite fig7 \
        --topology fat_tree_1024 --backend flow   # paper scale, seconds

Suites honour the same env knobs as the rest of the benchmark suite
(``BENCH_FAST=1``, ``BENCH_PAPER_SCALE=1``). ``--topology three_tier`` runs
the same sweep on the 3-tier folded Clos; any ``PAPER_SCALES`` name
(``fat_tree_1024`` ... ``three_tier_4096``) selects a paper-scale fabric.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import multiprocessing as mp
import os
import statistics
import sys
import time
from typing import Dict, List


def _default_procs() -> int:
    return max(1, (os.cpu_count() or 2) - 1)


# --------------------------------------------------------------------------
# Work items (must be picklable: plain dicts in, plain dicts out)
# --------------------------------------------------------------------------
def _base_cfg(topology: str):
    from repro.core.canary import (PAPER_SCALES, paper_scale_config,
                                   three_tier_config)

    from .common import bench_cfg
    if topology in PAPER_SCALES:
        return paper_scale_config(topology)
    if topology == "three_tier":
        return three_tier_config(num_pods=4, leaves_per_pod=2,
                                 hosts_per_leaf=8, aggs_per_pod=2, num_cores=4)
    if topology != "fat_tree":
        raise SystemExit(f"unknown topology {topology!r} (have: fat_tree, "
                         f"three_tier, {', '.join(sorted(PAPER_SCALES))})")
    return bench_cfg()


def expand_suite(suite: str, topology: str, reps: int) -> List[dict]:
    """Expand a named sweep into independent work-item dicts."""
    from .common import bench_size
    cfg = _base_cfg(topology)
    n = max(2, int(cfg.num_hosts * 0.5))  # 50% participants, like bench_hosts
    size = bench_size()
    items: List[dict] = []
    if suite == "fig7":
        # static 1/2/4/8 trees vs canary, with and without congestion
        cells = [("static1", "static_tree", 1), ("static2", "static_tree", 2),
                 ("static4", "static_tree", 4), ("static8", "static_tree", 8),
                 ("canary", "canary", 1)]
        for cong in (False, True):
            for label, algo, nt in cells:
                for rep in range(reps):
                    items.append(dict(label=f"{label}/cong={int(cong)}",
                                      algo=algo, n_trees=nt, congestion=cong,
                                      num_hosts=n, data_bytes=size, rep=rep))
    elif suite == "fig8":
        # goodput vs fraction of hosts running the allreduce, the rest
        # generating congestion (same axis as benchmarks/fig8_*.py)
        for frac in (0.05, 0.25, 0.5, 0.75):
            nf = max(2, int(cfg.num_hosts * frac))
            for algo in ("static_tree", "canary"):
                for rep in range(reps):
                    items.append(dict(label=f"{algo}/hosts={int(frac * 100)}%",
                                      algo=algo, n_trees=1, congestion=True,
                                      num_hosts=nf, data_bytes=size, rep=rep))
    elif suite == "lb":
        # load-balancing policy sensitivity under congestion
        for lb in ("ecmp", "adaptive", "per_packet"):
            for rep in range(reps):
                items.append(dict(label=f"canary/lb={lb}", algo="canary",
                                  n_trees=1, congestion=True, lb=lb,
                                  num_hosts=n, data_bytes=size, rep=rep))
    else:
        raise SystemExit(f"unknown sweep suite {suite!r} (have: fig7, fig8, lb)")
    for it in items:
        it["topology"] = topology
        it["cfg"] = dataclasses.asdict(cfg)
    return items


def run_item(item: dict) -> dict:
    """Execute one packet-engine sweep cell (runs in a worker process)."""
    from repro.core.canary.backends import PacketBackend
    return PacketBackend().run_cell(item)


def _progress(done: int, total: int, t0: float) -> None:
    rate = done / max(1e-9, time.perf_counter() - t0)
    eta = (total - done) / rate if rate > 0 else float("inf")
    print(f"\r# sweep {done}/{total} cells "
          f"({rate:.2f} cells/s, eta {eta:.0f}s)",
          end="" if done < total else "\n", file=sys.stderr, flush=True)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------
def _run_items_packet(items: List[dict], procs: int) -> List[dict]:
    """Packet-engine execution: worker pool (or in-process when procs<=1).

    ``imap_unordered`` keeps every worker busy and lets us emit progress as
    cells land; results are re-keyed back to submission order afterwards, so
    the result set is identical to a serial run (the equality contract in
    tests/benchmarks/test_sweep.py).
    """
    t0 = time.perf_counter()
    if procs and procs > 1:
        indexed = list(enumerate(items))
        ctx = mp.get_context("fork" if sys.platform == "linux" else "spawn")
        cells: List[dict] = [None] * len(items)  # type: ignore[list-item]
        with ctx.Pool(processes=procs) as pool:
            done = 0
            for idx, cell in pool.imap_unordered(_run_indexed, indexed,
                                                 chunksize=1):
                cells[idx] = cell
                done += 1
                _progress(done, len(items), t0)
        return cells
    out = []
    for i, it in enumerate(items):
        out.append(run_item(it))
        _progress(i + 1, len(items), t0)
    return out


def _run_indexed(pair):
    idx, item = pair
    return idx, run_item(item)


def _speedup_probe(items: List[dict], flow_cells: List[dict],
                   probe_n: int) -> dict:
    """Like-for-like flow vs packet wall-clock on the first ``probe_n``
    cells of this very grid, plus an extrapolation of what the packet
    engine would cost for the full matrix (per-cell packet cost scales with
    simulated time x hosts; we scale by measured probe cost)."""
    probe = items[:probe_n]
    t0 = time.perf_counter()
    packet_cells = [run_item(it) for it in probe]
    packet_wall = time.perf_counter() - t0
    flow_wall = sum(c["wall_s"] for c in flow_cells)
    # packet cost of the unprobed cells, extrapolated from the probed ones
    # via predicted runtimes (events ~ simulated ns at fixed topology)
    probe_pred = sum(c["runtime_us"] for c in flow_cells[:probe_n])
    total_pred = sum(c["runtime_us"] for c in flow_cells)
    scale = total_pred / probe_pred if probe_pred > 0 else float("nan")
    packet_extrapolated = packet_wall * scale
    return dict(
        probe_cells=probe_n,
        packet_wall_s=packet_wall,
        packet_events=sum(c["events"] for c in packet_cells),
        flow_wall_s=flow_wall,
        packet_extrapolated_s=packet_extrapolated,
        speedup_probe_only=packet_wall / max(1e-9, sum(
            c["wall_s"] for c in flow_cells[:probe_n])),
        speedup_full_matrix=packet_extrapolated / max(1e-9, flow_wall),
    )


def provenance() -> dict:
    from .common import provenance as _prov
    return _prov()


def trace_first_cell(items: List[dict], path: str) -> dict:
    """Re-run the sweep's first cell in-process with the telemetry hub live
    and dump the Perfetto trace to ``path`` (the pool workers' results cross
    a pickle boundary, so the hub object itself never leaves them)."""
    from repro.core.canary import Algo
    from repro.core.canary.algorithms import build_cell_simulator
    from repro.core.canary.backends import item_config
    from repro.core.telemetry import validate_perfetto, write_perfetto
    it = items[0]
    cfg = dataclasses.replace(item_config(it), telemetry=True)
    sim = build_cell_simulator(cfg, Algo(it["algo"]), it["num_hosts"],
                               it["data_bytes"], n_trees=it["n_trees"],
                               congestion=it["congestion"], rep=it["rep"])
    sim.run()
    doc = write_perfetto(sim.telemetry, path)
    errs = validate_perfetto(doc)
    if errs:
        raise SystemExit(f"invalid trace for cell {it['label']!r}: {errs[:3]}")
    print(f"# traced cell {it['label']!r} -> {path} "
          f"({len(doc['traceEvents'])} events)", file=sys.stderr, flush=True)
    return doc


def run_sweep(suite: str, topology: str = "fat_tree", reps: int = 2,
              procs: int = 0, backend: str = "packet",
              speedup_probe: int = 0, telemetry: bool = False) -> dict:
    """Run a sweep; ``procs=0`` means serial (in-process), ``procs>=1`` uses a
    worker pool (packet backend only — the flow backend batches in-process).
    Returns the JSON-ready result document."""
    items = expand_suite(suite, topology, reps)
    if telemetry:
        if backend != "packet":
            raise SystemExit("--telemetry needs the packet backend "
                             "(the flow model has nothing to observe)")
        for it in items:
            it["cfg"]["telemetry"] = True
    t0 = time.perf_counter()
    if backend == "packet":
        cells = _run_items_packet(items, procs)
        extra = {}
    else:
        from repro.core.canary import get_backend
        bk = get_backend(backend)
        cells = bk.run_cells(items)
        extra = {"jit_traces": cells[0].get("jit_traces") if cells else 0}
        if speedup_probe > 0:
            extra["speedup_probe"] = _speedup_probe(
                items, cells, min(speedup_probe, len(items)))
    wall = time.perf_counter() - t0
    by_label: Dict[str, List[dict]] = {}
    for c in sorted(cells, key=lambda c: (c["label"], c["rep"])):
        by_label.setdefault(c["label"], []).append(c)
    aggregates = {
        label: dict(
            goodput_gbps_mean=statistics.mean(c["goodput_gbps"] for c in cs),
            runtime_us_mean=statistics.mean(c["runtime_us"] for c in cs),
            correct=all(c["correct"] for c in cs),
            reps=len(cs),
        )
        for label, cs in sorted(by_label.items())
    }
    cpu_s = sum(c["wall_s"] for c in cells)
    return dict(
        suite=suite, topology=topology, reps=reps, procs=procs,
        backend=backend,
        cells=len(cells), wall_s=wall, cpu_s=cpu_s,
        speedup=(cpu_s / wall) if wall > 0 else 0.0,
        correct=all(c["correct"] for c in cells),
        provenance=provenance(),
        aggregates=aggregates,
        results=cells,
        items=items,
        **extra,
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suite", default="fig7", help="fig7 | fig8 | lb")
    ap.add_argument("--topology", default="fat_tree",
                    help="fat_tree | three_tier | a PAPER_SCALES name "
                         "(fat_tree_1024 ... three_tier_4096)")
    ap.add_argument("--backend", default="packet",
                    help="packet (exact, default) | flow (batched model)")
    ap.add_argument("--reps", type=int,
                    default=int(os.environ.get("SWEEP_REPS", "2")))
    ap.add_argument("--procs", type=int, default=_default_procs(),
                    help="worker processes (0/1 = serial; packet only)")
    ap.add_argument("--speedup-probe", type=int, default=4,
                    help="flow backend: run N cells through the packet "
                         "engine too and record the wall-clock comparison "
                         "(0 disables)")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the telemetry hub in every cell (packet "
                         "backend only); per-cell summaries land in the "
                         "result JSON under 'telemetry'")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="re-run the first cell in-process with telemetry "
                         "and write its Perfetto trace-event JSON here")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)
    doc = run_sweep(args.suite, args.topology, args.reps, args.procs,
                    backend=args.backend,
                    speedup_probe=args.speedup_probe
                    if args.backend != "packet" else 0,
                    telemetry=args.telemetry)
    if args.trace_out:
        trace_first_cell(doc["items"], args.trace_out)
    suffix = "" if args.backend == "packet" else f"_{args.backend}"
    out = args.out or f"sweep_{args.suite}_{args.topology}{suffix}.json"
    with open(out, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"# {doc['cells']} cells in {doc['wall_s']:.1f}s wall "
          f"({doc['cpu_s']:.1f}s cpu, {doc['speedup']:.1f}x speedup, "
          f"backend={args.backend}, procs={args.procs}) "
          f"correct={doc['correct']} -> {out}",
          file=sys.stderr)
    if "speedup_probe" in doc:
        sp = doc["speedup_probe"]
        print(f"# flow vs packet: {sp['speedup_probe_only']:.0f}x on "
              f"{sp['probe_cells']} probed cells, "
              f"{sp['speedup_full_matrix']:.0f}x extrapolated full-matrix "
              f"({sp['packet_extrapolated_s']:.0f}s packet vs "
              f"{sp['flow_wall_s']:.2f}s flow)", file=sys.stderr)
    from .common import emit
    for label, agg in doc["aggregates"].items():
        # emit() also records the row for run.py's BENCH_RESULTS.json
        emit(f"sweep/{args.suite}/{label}", agg["runtime_us_mean"],
             f"goodput_gbps={agg['goodput_gbps_mean']:.1f};"
             f"correct={agg['correct']}")


if __name__ == "__main__":
    main()
