"""Fig. 7: 50% of hosts run the allreduce, 50% generate congestion —
goodput for 1/2/4/8 static trees vs Canary, plus link-utilization stats."""
from __future__ import annotations

import statistics

from repro.core.canary import Algo, run_allreduce

from .common import bench_cfg, bench_hosts, bench_size, emit, timed


def _util_stats(utils) -> str:
    idle = sum(1 for u in utils if u < 0.05) / len(utils)
    hot = sum(1 for u in utils if u > 0.8) / len(utils)
    return (f"util_avg={statistics.mean(utils):.3f};idle={idle:.2f};"
            f"hot={hot:.2f}")


def main(reps: int = 2) -> None:
    cfg = bench_cfg()
    n = bench_hosts(0.5)
    size = bench_size()
    for cong in (False, True):
        for algo, nt, label in ((Algo.STATIC_TREE, 1, "static1"),
                                (Algo.STATIC_TREE, 2, "static2"),
                                (Algo.STATIC_TREE, 4, "static4"),
                                (Algo.STATIC_TREE, 8, "static8"),
                                (Algo.CANARY, 1, "canary")):
            r, us = timed(run_allreduce, cfg, algo, n, size, n_trees=nt,
                          congestion=cong, reps=reps)
            emit(f"fig7/{label}/cong={int(cong)}", us,
                 f"goodput_gbps={r.goodput_gbps_mean:.1f};"
                 f"{_util_stats(r.link_utilization)};correct={r.correct}")


if __name__ == "__main__":
    main()
