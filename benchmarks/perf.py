"""Perf suite: packet-rate microbenches + macro cells for the hot path.

The discrete-event core is the binding constraint on every downstream
subsystem (trace, fleet, workload prediction all multiply packet-level
runs), so this suite tracks *events per second* through the engine —
the one number the whole repo scales with — plus wall time for the
macro scenarios users actually run.

Cells
-----
* ``micro/*`` — single ``Simulator`` runs where we own the event loop and
  report events/sec: the headline ``micro/canary_noise`` packet-rate cell
  (CANARY + 50% background congestion, the paper's §5.2 regime), a
  timer-heavy CANARY cell (descriptor timers dominate heap volume), the
  STATIC_TREE and RING baselines, and CANARY on the 3-tier fabric.
* ``macro/*`` — end-to-end scenarios: a fig7-style sweep, a 3-tenant fleet
  demo, a workload-compiler smoke, and the ring-on-three_tier workload
  cell that used to be skipped as "~100x slower to simulate".

Baseline contract
-----------------
Every micro cell runs TWICE per invocation: once on the live engine and
once on ``benchmarks/baseline_core`` — a frozen, vendored copy of the
pre-optimization hot path — interleaved in the same process. The reported
speedup is therefore a like-for-like ratio, robust to machine noise, and
the acceptance contract ("events/sec vs the pre-PR engine") stays
verifiable on any hardware. Both absolute rates land in
``PERF_RESULTS.json`` (``PERF_JSON=`` to move it). The two engines must
also agree on the *event count* of every cell — a mismatch fails the
suite, because it would mean the optimized engine changed behaviour.

``benchmarks/perf_baseline.json`` additionally pins the rates measured on
the reference container when the overhaul landed, for historical tracking
(``--capture-baseline`` re-pins it).

Profiling
---------
``PYTHONPATH=src python -m benchmarks.perf --profile`` cProfiles the
headline micro cell and prints the top functions by cumulative time, so a
perf regression is diagnosable from the bench output alone.

Environment: BENCH_FAST=1 shrinks every cell for CI smoke (the JSON also
records which profile ran — fast and full numbers are not comparable).
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               scaled_config, three_tier_config)

from . import common
from .common import FAST, emit

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "perf_baseline.json")
# Acceptance floor for the headline packet-rate cell vs the pre-PR engine.
TARGET_SPEEDUP = 3.0
MICRO_REPS = 3  # deterministic sims: best-of-N wall time for stable rates


# ---------------------------------------------------------------- micro cells
def _micro_sim(name: str, mod=None):
    """Build one micro-cell Simulator. Fresh instance per run (a Simulator
    is single-shot); deterministic given the pinned seeds. ``mod`` selects
    the engine: the live canary package (default) or the frozen
    ``benchmarks.baseline_core`` copy of the pre-PR hot path."""
    if mod is None:
        import repro.core.canary as mod
    scale = 4 if FAST else 8
    data = (128 << 10) if FAST else (1 << 20)
    if name == "canary_noise":
        # the headline packet-rate cell: §5.2 geometry, half the hosts
        # allreduce, the other half stream background congestion
        cfg = mod.scaled_config(scale, seed=3)
        n = cfg.num_hosts
        return mod.Simulator(cfg,
                             [mod.AllreduceJob(0, list(range(n // 2)), data)],
                             algo=mod.Algo.CANARY,
                             noise_hosts=list(range(n // 2, n)))
    if name == "canary_timers":
        # all hosts participate, no noise: descriptor timers dominate the
        # heap (the lazy-cancellation regime)
        cfg = mod.scaled_config(scale, seed=5, timeout_ns=400.0)
        n = cfg.num_hosts
        return mod.Simulator(cfg, [mod.AllreduceJob(0, list(range(n)), data)],
                             algo=mod.Algo.CANARY)
    if name == "static_tree_noise":
        cfg = mod.scaled_config(scale, seed=7)
        n = cfg.num_hosts
        return mod.Simulator(cfg,
                             [mod.AllreduceJob(0, list(range(n // 2)), data)],
                             algo=mod.Algo.STATIC_TREE, n_trees=4,
                             noise_hosts=list(range(n // 2, n)))
    if name == "ring_noise":
        cfg = mod.scaled_config(scale, seed=9)
        n = cfg.num_hosts
        return mod.Simulator(cfg, [mod.AllreduceJob(0, list(range(n // 2)),
                                                    data // 4)],
                             algo=mod.Algo.RING,
                             noise_hosts=list(range(n // 2, n)))
    if name == "three_tier_canary":
        cfg = mod.three_tier_config(num_pods=4, leaves_per_pod=2,
                                    hosts_per_leaf=4 if FAST else 8,
                                    aggs_per_pod=2, num_cores=4, seed=11)
        n = cfg.num_hosts
        return mod.Simulator(cfg,
                             [mod.AllreduceJob(0, list(range(n // 2)), data)],
                             algo=mod.Algo.CANARY,
                             noise_hosts=list(range(n // 2, n)))
    raise KeyError(name)


MICRO_CELLS = ("canary_noise", "canary_timers", "static_tree_noise",
               "ring_noise", "three_tier_canary")
HEADLINE = "micro/canary_noise"
# Documented ceiling for telemetry-on overhead at the default probe cadence
# (ARCHITECTURE.md §Telemetry). Off costs one pointer compare per hook site,
# which the interleaved A/B below cannot even resolve.
TELEMETRY_BUDGET = 0.05


def _time_once(name: str, mod=None) -> Dict[str, float]:
    import gc
    sim = _micro_sim(name, mod)
    # fairness: collect the previous run's garbage outside the timed window
    # (the live engine pauses cyclic GC while running; without this the
    # *next* timed run would pay its deferred collection)
    gc.collect()
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    assert res.correct, f"micro cell {name}: reduction not exact"
    return {"wall_s": wall, "events": float(res.events),
            "events_per_sec": res.events / wall}


def _run_micro(name: str) -> Dict[str, Dict[str, float]]:
    """Interleaved A/B: live engine vs the frozen pre-PR baseline copy.

    Best-of-N for each side, alternating runs so both engines see the same
    machine conditions; asserts both engines dispatch the same event count
    (behavioural equivalence, not just same results)."""
    from . import baseline_core
    live: Optional[Dict[str, float]] = None
    base: Optional[Dict[str, float]] = None
    for _ in range(MICRO_REPS):
        row = _time_once(name)
        if live is None or row["wall_s"] < live["wall_s"]:
            live = row
        brow = _time_once(name, baseline_core)
        if base is None or brow["wall_s"] < base["wall_s"]:
            base = brow
    assert live is not None and base is not None
    if live["events"] != base["events"]:
        raise AssertionError(
            f"micro cell {name}: optimized engine dispatched "
            f"{live['events']:.0f} events, pre-PR baseline "
            f"{base['events']:.0f} — behavioural divergence")
    return {"live": live, "baseline": base,
            "speedup": live["events_per_sec"] / base["events_per_sec"]}


def _headline_sim(telemetry: bool) -> Simulator:
    """The headline micro cell's exact geometry, telemetry switchable —
    must stay in lockstep with ``_micro_sim("canary_noise")``."""
    scale = 4 if FAST else 8
    data = (128 << 10) if FAST else (1 << 20)
    cfg = scaled_config(scale, seed=3, telemetry=telemetry)
    n = cfg.num_hosts
    return Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), data)],
                     algo=Algo.CANARY, noise_hosts=list(range(n // 2, n)))


TELEMETRY_AB_REPS = 63 if FAST else 15  # pairs; resolving a 5% budget needs
#                         many more samples than the throughput cells
#                         (MICRO_REPS) — and at FAST scale the runs are
#                         cheap enough to multiply the sample count, which
#                         is exactly where the shorter runs need it. The
#                         sweep is deliberately long enough (minutes, not
#                         seconds) to SPAN the slow machine-regime drift a
#                         shared box exhibits, so the median-of-pairs lands
#                         on the regime-typical ratio instead of whichever
#                         regime a short sweep happened to start in
TELEMETRY_AB_RUNS_PER_ARM = 3  # back-to-back runs per arm sample, the arm
#                                taking the MINIMUM: a single headline run
#                                is short enough (~40 ms at FAST scale)
#                                that one scheduler burst inside one arm
#                                swings that pair's ratio by 10%+. Timing
#                                noise on a shared box is additive-positive
#                                (steal, interrupts, frequency dips), so
#                                the min of K runs is the best estimate of
#                                the undisturbed run — a sum would average
#                                every burst back in at 1/K instead of
#                                discarding it


def _run_telemetry_ab() -> Dict[str, object]:
    """Interleaved A/B of the headline cell with the telemetry hub off vs on
    (default probe cadence), both on the live engine. Pins the observability
    cost: the golden ``events`` counts must agree (probe ticks dispatch
    outside it) and the on-side overhead must stay within
    ``TELEMETRY_BUDGET``.

    The overhead estimator is the **median of per-pair CPU-time ratios**
    (``time.process_time``): each off/on pair runs back-to-back so both
    arms see the same machine regime, per-pair ratios cancel the slow
    frequency/contention drift that makes wall clock (and even
    cross-minute CPU-time minima) swing by more than the budget being
    resolved on a shared box, the median rejects the occasional pair
    where a noise burst lands inside exactly one arm, and the arm order
    alternates pair-to-pair so any systematic first-run advantage (turbo
    decay, cache warm-up) cancels instead of biasing one arm. Each arm
    sample is the MINIMUM CPU time of ``TELEMETRY_AB_RUNS_PER_ARM``
    back-to-back runs — noise is additive-positive, so the min discards a
    burst outright where a sum would average it back in at 1/K. The
    min-of-N rows are kept for the absolute throughput numbers."""
    import gc
    import statistics
    best: Dict[bool, Optional[Dict[str, float]]] = {False: None, True: None}
    ratios: List[float] = []
    for rep in range(TELEMETRY_AB_REPS):
        pair: Dict[bool, float] = {}
        for tel in ((False, True) if rep % 2 == 0 else (True, False)):
            arm_cpu = float("inf")
            for _ in range(TELEMETRY_AB_RUNS_PER_ARM):
                sim = _headline_sim(tel)
                # GC fully off for the timed window (run() sees it disabled
                # and leaves it so): the engine defers a whole run's worth
                # of allocation debt, and letting the threshold-triggered
                # collection land inside exactly one arm of a pair is the
                # single largest noise term this estimator has to fight —
                # a full gen-2 pass is the same order as the budget being
                # resolved. The engine allocates no reference cycles, so
                # plain refcounting reclaims everything; the explicit
                # collect below just resets the counters outside the clock.
                gc.collect()
                gc.disable()
                c0 = time.process_time()
                t0 = time.perf_counter()
                res = sim.run()
                wall = time.perf_counter() - t0
                cpu = time.process_time() - c0
                gc.enable()
                assert res.correct, "telemetry A/B cell: reduction not exact"
                if cpu < arm_cpu:
                    arm_cpu = cpu
                row = {"wall_s": wall, "cpu_s": cpu,
                       "events": float(res.events),
                       "probes": res.telemetry_summary.get("probes", 0.0)}
                if best[tel] is None or cpu < best[tel]["cpu_s"]:
                    best[tel] = row
            pair[tel] = arm_cpu
        ratios.append(pair[True] / pair[False] - 1.0)
    off, on = best[False], best[True]
    assert off is not None and on is not None
    if off["events"] != on["events"]:
        raise AssertionError(
            f"telemetry changed the golden event count: off "
            f"{off['events']:.0f}, on {on['events']:.0f}")
    overhead = statistics.median(ratios)
    return {"off": off, "on": on, "overhead": overhead,
            "overhead_min_ratio": on["cpu_s"] / off["cpu_s"] - 1.0,
            "pairs": len(ratios), "runs_per_arm": TELEMETRY_AB_RUNS_PER_ARM,
            "budget": TELEMETRY_BUDGET,
            "within_budget": overhead <= TELEMETRY_BUDGET}


# ---------------------------------------------------------------- macro cells
def _macro_fig7() -> Tuple[float, str]:
    from . import fig7_static_vs_canary
    t0 = time.perf_counter()
    fig7_static_vs_canary.main(reps=1)
    return time.perf_counter() - t0, "fig7 sweep (reps=1)"


def _macro_fleet_demo() -> Tuple[float, str]:
    """The 3-tenant mixed-priority fleet of ``examples/fleet_demo.py``."""
    import random

    from repro.core.canary import TenantSpec
    from repro.core.fleet import (FleetDriver, FleetScenario, make_jobs,
                                  periodic_arrivals, poisson_arrivals)
    cfg = scaled_config(4, seed=7)
    rng = random.Random(7)
    tenants = [TenantSpec(0, weight=6.0, name="training"),
               TenantSpec(1, weight=1.0, name="batch"),
               TenantSpec(2, weight=0.02, name="scavenger")]
    jobs = (
        make_jobs(tenants[0], periodic_arrivals(3, 30_000.0), range(16), 8,
                  65536, rng=rng, app_base=0) +
        make_jobs(tenants[1], poisson_arrivals(2, 25_000.0, rng=rng),
                  range(16), 6, 32768, rng=rng, app_base=100,
                  fixed_placement=False) +
        make_jobs(tenants[2], poisson_arrivals(2, 25_000.0, rng=rng),
                  range(16), 6, 32768, rng=rng, app_base=200)
    )
    scenario = FleetScenario(cfg=cfg, tenants=tenants, jobs=jobs,
                             algo=Algo.CANARY, quota_policy="weighted")
    t0 = time.perf_counter()
    fr = FleetDriver(scenario).run()
    wall = time.perf_counter() - t0
    assert fr.correct, "fleet demo macro cell: reduction not exact"
    return wall, f"jobs={len(fr.jobs)};jain={fr.jain_fairness:.3f}"


def _macro_workload_smoke() -> Tuple[float, str]:
    from repro.core.workload import predict_scenario
    t0 = time.perf_counter()
    p = predict_scenario("deepseek-moe/fat_tree", algo=Algo.CANARY,
                         congestion=True, bytes_scale=0.03)
    wall = time.perf_counter() - t0
    assert p.correct, "workload smoke macro cell: reduction not exact"
    return wall, f"iter_us={p.iteration_ns / 1e3:.1f}"


def _macro_ring_three_tier() -> Tuple[float, str]:
    """The cell `benchmarks/workload.py` used to skip: host-based ring on a
    congested three_tier. FAST shrinks the wire bytes; the full profile runs
    it at the workload suite's full scale."""
    from repro.core.workload import predict_scenario
    kw = dict(bytes_scale=0.03) if FAST else {}
    t0 = time.perf_counter()
    p = predict_scenario("llama3-dense/three_tier", algo=Algo.RING,
                         congestion=True, **kw)
    wall = time.perf_counter() - t0
    assert p.correct, "ring three_tier macro cell: reduction not exact"
    return wall, f"iter_us={p.iteration_ns / 1e3:.1f}"


MACRO_CELLS: Dict[str, Callable[[], Tuple[float, str]]] = {
    "fig7_sweep": _macro_fig7,
    "fleet_demo": _macro_fleet_demo,
    "workload_smoke": _macro_workload_smoke,
    "ring_three_tier": _macro_ring_three_tier,
}


# ------------------------------------------------------------------- plumbing
def _load_baseline() -> Optional[dict]:
    if not os.path.exists(BASELINE_PATH):
        return None
    with open(BASELINE_PATH) as fh:
        return json.load(fh)


def _profile_key() -> str:
    return "fast" if FAST else "full"


def run_cells() -> Dict[str, Dict]:
    cells: Dict[str, Dict] = {}
    # the telemetry A/B resolves a few-percent budget out of sub-second
    # runs, so it goes FIRST: after the micro/macro cells have churned tens
    # of millions of allocations through the heap, the on-arm's extra
    # allocations read systematically worse than they do in the fresh
    # process a user (or the budget's original calibration) measures in
    tel = _run_telemetry_ab()
    cells["telemetry/headline_ab"] = tel
    emit("perf/telemetry/headline_ab", tel["on"]["wall_s"] * 1e6,
         f"overhead={tel['overhead'] * 100:.1f}%;"
         f"budget={TELEMETRY_BUDGET * 100:.0f}%;"
         f"within_budget={tel['within_budget']};"
         f"probes={int(tel['on']['probes'])}")
    for name in MICRO_CELLS:
        row = _run_micro(name)
        cells[f"micro/{name}"] = row
        emit(f"perf/micro/{name}", row["live"]["wall_s"] * 1e6,
             f"events={int(row['live']['events'])};"
             f"events_per_sec={row['live']['events_per_sec']:,.0f};"
             f"pre_pr={row['baseline']['events_per_sec']:,.0f};"
             f"speedup={row['speedup']:.2f}x")
    for name, fn in MACRO_CELLS.items():
        wall, derived = fn()
        cells[f"macro/{name}"] = {"wall_s": wall}
        emit(f"perf/macro/{name}", wall * 1e6, derived)
    return cells


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if "--profile" in argv:
        profile_headline()
        return
    cells = run_cells()
    headline_row = cells[HEADLINE]
    headline = {
        "cell": HEADLINE,
        "events_per_sec": headline_row["live"]["events_per_sec"],
        "baseline_events_per_sec":
            headline_row["baseline"]["events_per_sec"],
        "speedup": headline_row["speedup"],
        "target_speedup": TARGET_SPEEDUP,
        "meets_target": headline_row["speedup"] >= TARGET_SPEEDUP,
        # the acceptance regime is the full profile; FAST shrinks cells for
        # CI smoke, where the engine's heap-depth advantages barely engage
        "acceptance_profile": not FAST,
    }
    emit("perf/headline/speedup", 0.0,
         f"{headline['speedup']:.2f}x vs pre-PR engine "
         f"(target {TARGET_SPEEDUP:.1f}x, "
         f"meets_target={headline['meets_target']})")
    pinned = (_load_baseline() or {}).get(_profile_key(), {})
    doc = {
        "suite": "perf", "fast": FAST,
        "cells": cells,
        "headline": headline,
        "speedup_vs_pre_pr": {n: cells[n]["speedup"]
                              for n in cells if "speedup" in cells[n]},
        "telemetry_overhead": cells["telemetry/headline_ab"],
        "pinned_reference_rates": pinned,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "provenance": common.provenance(),
    }
    path = os.environ.get("PERF_JSON", "PERF_RESULTS.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}", file=sys.stderr, flush=True)
    if "--capture-baseline" in argv:
        base_doc = _load_baseline() or {}
        base_doc["note"] = (
            "reference-container rates at the time the hot-path overhaul "
            "landed (live + vendored pre-PR engine); the speedup contract "
            "itself is measured live against benchmarks/baseline_core")
        base_doc[_profile_key()] = cells
        base_doc["python"] = platform.python_version()
        with open(BASELINE_PATH, "w") as fh:
            json.dump(base_doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {BASELINE_PATH}", file=sys.stderr, flush=True)


def profile_headline(top: int = 35) -> None:
    """cProfile the headline micro cell; print top functions by cumtime."""
    import cProfile
    import pstats
    sim = _micro_sim(HEADLINE.split("/", 1)[1])
    pr = cProfile.Profile()
    pr.enable()
    res = sim.run()
    pr.disable()
    print(f"# {HEADLINE}: events={res.events} correct={res.correct}")
    pstats.Stats(pr).sort_stats("cumulative").print_stats(top)


if __name__ == "__main__":
    main()
