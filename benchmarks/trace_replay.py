"""Trace -> compile -> replay: the sim-to-tensor bridge, measured.

Records a CANARY run under background congestion, compiles every block's
dynamic tree into a round-based schedule, and replays one block's data as a
real JAX program (float32 and bit-deterministic int32 fixed point). Emits:

* the simulated allreduce time next to the compiled schedule's depth /
  message count / bytes (how well schedule shape predicts simulated cost),
* recorder overhead (traced vs untraced wall-clock of the same run),
* replay wall-clock per block and the fixed-point determinism check result.

Writes ``TRACE_REPLAY.json`` (``TRACE_JSON=`` to move) so CI can archive the
schedule-shape trajectory; doubles as the CI smoke for the whole subsystem.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.canary import Algo, AllreduceJob, Simulator

from .common import FAST, bench_cfg, bench_hosts, emit, timed


def _jobs(n_hosts, size):
    return [AllreduceJob(app=0, participants=list(range(n_hosts)),
                         data_bytes=size)]


def main() -> None:
    size = (64 if FAST else 256) * 1024
    n_hosts = bench_hosts(0.25)
    base = bench_cfg(seed=3, timeout_ns=500.0)
    noise = list(range(n_hosts, min(base.num_hosts, 2 * n_hosts)))

    # -- record (and measure recorder overhead against an untraced run) -----
    untraced = Simulator(base, _jobs(n_hosts, size), algo=Algo.CANARY,
                         noise_hosts=noise)
    r0, us_plain = timed(untraced.run)
    cfg = dataclasses.replace(base, trace=True)
    sim = Simulator(cfg, _jobs(n_hosts, size), algo=Algo.CANARY,
                    noise_hosts=noise)
    result, us_traced = timed(sim.run)
    assert result.correct and result.duration_ns == r0.duration_ns, \
        "tracing changed the simulation"
    overhead = (us_traced / us_plain - 1.0) * 100 if us_plain > 0 else 0.0
    emit("trace/record", us_traced,
         f"overhead_pct={overhead:.0f};nodes={len(sim.trace.nodes)}")

    # -- compile ------------------------------------------------------------
    from repro.core.trace import compile_app, schedule_report
    schedules, us_compile = timed(compile_app, sim.trace, 0)
    rep = schedule_report(schedules, cfg.payload_bytes)
    emit("trace/compile", us_compile,
         f"blocks={rep['blocks']};depth_max={rep['depth_max']};"
         f"messages={rep['messages']}")
    # schedule shape vs simulated time: the headline comparison
    emit("trace/sim_vs_schedule", result.duration_ns / 1e3,
         f"sim_us={result.duration_ns / 1e3:.1f};"
         f"depth_mean={rep['depth_mean']:.2f};"
         f"bytes_moved={rep['bytes_moved']};"
         f"timeout_flushes={rep['timeout_flushes']}")

    # -- replay -------------------------------------------------------------
    import jax
    from repro.core.trace import fixed_point_replay, reference_allreduce
    P = len(schedules[0].hosts)
    B = min(len(schedules), 2 if FAST else 8)
    D = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (P, B, D))
    (out, q), us_replay = timed(fixed_point_replay, schedules[:B], x, bits=20)
    ref = np.asarray(reference_allreduce(x.reshape(P, -1))).reshape(x.shape)
    err = float(np.max(np.abs(np.asarray(out) - ref)))
    # replay a second, differently-seeded trace and check bit-identity
    cfg2 = dataclasses.replace(cfg, seed=cfg.seed + 1, timeout_ns=100.0)
    sim2 = Simulator(cfg2, _jobs(n_hosts, size), algo=Algo.CANARY,
                     noise_hosts=noise)
    assert sim2.run().correct
    schedules2 = compile_app(sim2.trace, 0)
    _, q2 = fixed_point_replay(schedules2[:B], x, bits=20)
    identical = bool((np.asarray(q) == np.asarray(q2)).all())
    emit("trace/replay_fixed_point", us_replay / B,
         f"blocks={B};max_err={err:.2e};bit_identical={identical}")
    if not identical:
        raise AssertionError("fixed-point replay diverged across tree shapes")

    doc = {
        "sim_duration_us": result.duration_ns / 1e3,
        "schedule": rep,
        "recorder_overhead_pct": round(overhead, 1),
        "replay_us_per_block": round(us_replay / B, 1),
        "fixed_point_max_err": err,
        "fixed_point_bit_identical": identical,
    }
    path = os.environ.get("TRACE_JSON", "TRACE_REPLAY.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")


if __name__ == "__main__":
    main()
