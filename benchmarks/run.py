"""Benchmark suite entry point — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows and, at the end, writes a
machine-readable JSON document (per-suite wall-clock timings + every CSV row)
so the bench trajectory can be tracked across PRs. Environment knobs:
BENCH_FAST=1 (CI smoke), BENCH_PAPER_SCALE=1 (the paper's 1024-host network
and 4 MiB messages — slow), BENCH_ONLY=fig7 (comma-list filter),
BENCH_JSON=path (JSON output location, default BENCH_RESULTS.json).

``--backend flow`` (or SWEEP_BACKEND=flow) routes the sweep suite through
the flow-level model (``repro.core.flow``) instead of the packet engine —
the only way the paper-scale fabrics are tractable as a bench suite.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _telemetry_cell(trace_out, diagnose_out=None, run_diagnosis=False) -> None:
    """--telemetry: the instrumented headline cell (see ISSUE/ARCHITECTURE:
    congested fat-tree, CANARY, background noise) + optional Perfetto dump.
    With --diagnose, also prints the critical-path attribution report
    (ARCHITECTURE.md §Diagnosis) and optionally writes the machine JSON."""
    from repro.core.telemetry import (run_headline_cell, validate_perfetto,
                                      write_perfetto)
    fast = os.environ.get("BENCH_FAST")
    sim = run_headline_cell(scale=4 if fast else 8,
                            data_bytes=(1 << 17) if fast else (1 << 20))
    res = sim.telemetry_result
    print(res.summary())
    for k, v in sorted(res.telemetry_summary.items()):
        print(f"telemetry,{k},{v}")
    if trace_out:
        doc = write_perfetto(sim.telemetry, trace_out)
        errs = validate_perfetto(doc)
        if errs:
            raise SystemExit(f"invalid trace: {errs[:3]}")
        print(f"# wrote {trace_out} ({len(doc['traceEvents'])} events)",
              file=sys.stderr, flush=True)
    if run_diagnosis or diagnose_out:
        from repro.core.telemetry import diagnose, view_of
        diag = diagnose(view_of(sim.telemetry))
        print(diag.to_text())
        if diagnose_out:
            with open(diagnose_out, "w") as fh:
                json.dump(diag.to_json(), fh, indent=1)
            print(f"# wrote {diagnose_out}", file=sys.stderr, flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend",
                    default=os.environ.get("SWEEP_BACKEND", "packet"),
                    help="sweep suite executor: packet (default) | flow")
    ap.add_argument("--telemetry", action="store_true",
                    help="run the headline congested cell with the telemetry "
                         "hub enabled and print its summary digest")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="with --telemetry: write the Perfetto trace-event "
                         "JSON here (load in ui.perfetto.dev)")
    ap.add_argument("--diagnose", action="store_true",
                    help="with --telemetry: print the critical-path cause "
                         "attribution + hotspot report for the cell")
    ap.add_argument("--diagnose-out", metavar="PATH", default=None,
                    help="with --diagnose: write the machine-readable "
                         "diagnosis report JSON here")
    args = ap.parse_args(argv)
    if args.telemetry or args.trace_out or args.diagnose or args.diagnose_out:
        _telemetry_cell(args.trace_out, diagnose_out=args.diagnose_out,
                        run_diagnosis=args.diagnose)
        return
    from . import (collective_bench, common, fig2_overview, fig6_single_switch,
                   fig7_static_vs_canary, fig8_congestion_intensity,
                   fig9_message_sizes, fig10_concurrent, fig11_timeout_noise,
                   fleet, mem_model, perf, roofline, sweep, trace_replay,
                   transport, workload)
    suites = {
        "perf": lambda: perf.main([]),
        "fig2": fig2_overview.main,
        "fig6": fig6_single_switch.main,
        "fig7": fig7_static_vs_canary.main,
        "fig8": fig8_congestion_intensity.main,
        "fig9": fig9_message_sizes.main,
        "fig10": fig10_concurrent.main,
        "fig11": fig11_timeout_noise.main,
        "mem_model": mem_model.main,
        "collective": collective_bench.main,
        "roofline": roofline.main,
        "trace": trace_replay.main,
        "fleet": fleet.main,
        "workload": workload.main,
        "transport": transport.main,
        "sweep": lambda: sweep.main(["--suite", "fig7", "--reps", "1",
                                     "--backend", args.backend,
                                     "--out", os.environ.get(
                                         "SWEEP_JSON", "sweep_fig7.json")]),
    }
    only = os.environ.get("BENCH_ONLY")
    if only:
        keep = set(only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}
    else:
        # the perf suite (A/B vs the vendored pre-PR engine) and the
        # transport suite each have their own CI step and entry point
        # (python -m benchmarks.perf / benchmarks.transport); opt in to the
        # aggregate run with BENCH_ONLY=perf,transport,...
        suites.pop("perf", None)
        suites.pop("transport", None)
    print("name,us_per_call,derived")
    failures = []
    timings = {}
    for name, fn in suites.items():
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        timings[name] = round(time.time() - t0, 3)
        print(f"# {name} done in {timings[name]:.1f}s", file=sys.stderr,
              flush=True)
    doc = {
        "suite_seconds": timings,
        "failed_suites": failures,
        "rows": [dict(zip(("name", "us_per_call", "derived"),
                          row.split(",", 2))) for row in common.ROWS],
        "env": {k: os.environ.get(k) for k in
                ("BENCH_FAST", "BENCH_PAPER_SCALE", "BENCH_ONLY")},
        "provenance": common.provenance(),
    }
    json_path = os.environ.get("BENCH_JSON", "BENCH_RESULTS.json")
    with open(json_path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {json_path}", file=sys.stderr, flush=True)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
