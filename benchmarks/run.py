"""Benchmark suite entry point — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows. Environment knobs:
BENCH_FAST=1 (CI smoke), BENCH_PAPER_SCALE=1 (the paper's 1024-host network
and 4 MiB messages — slow), BENCH_ONLY=fig7 (comma-list filter).
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    from . import (collective_bench, fig2_overview, fig6_single_switch,
                   fig7_static_vs_canary, fig8_congestion_intensity,
                   fig9_message_sizes, fig10_concurrent, fig11_timeout_noise,
                   mem_model, roofline)
    suites = {
        "fig2": fig2_overview.main,
        "fig6": fig6_single_switch.main,
        "fig7": fig7_static_vs_canary.main,
        "fig8": fig8_congestion_intensity.main,
        "fig9": fig9_message_sizes.main,
        "fig10": fig10_concurrent.main,
        "fig11": fig11_timeout_noise.main,
        "mem_model": mem_model.main,
        "collective": collective_bench.main,
        "roofline": roofline.main,
    }
    only = os.environ.get("BENCH_ONLY")
    if only:
        keep = set(only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites.items():
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
