"""Fleet suite: multi-tenant allreduce with open-loop arrivals and enforced
switch-memory quotas (§3.2.2/§3.4, plus the Flare/Segal multi-tenancy
direction).

Sweeps tenant count x arrival rate x quota policy x algorithm
(CANARY / STATIC_TREE / RING) on both registered fabrics (``fat_tree`` and
``three_tier``) and reports the per-job QoS currency multi-tenant designs
are compared on: mean JCT, mean slowdown vs an uncontended run, Jain's
fairness index across tenants, and degradation counts. Every cell also
asserts exactness — a fleet run is a correctness proof, not just a timing.

Writes ``FLEET_RESULTS.json`` (``FLEET_JSON=`` to move it); registered as
the ``fleet`` suite in ``benchmarks/run.py``. ``--diagnose`` re-runs one
representative congested cell with the telemetry hub enabled and attaches
its critical-path cause attribution + per-tenant hotspot ranking
(ARCHITECTURE.md §Diagnosis) to the JSON under ``"diagnosis"``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import random
import sys
from typing import List

from repro.core.canary import Algo, TenantSpec, three_tier_config

from .common import FAST, bench_cfg, emit, timed


def _topologies():
    yield "fat_tree", bench_cfg()
    if FAST:
        yield "three_tier", three_tier_config(hosts_per_leaf=4)
    else:
        yield "three_tier", three_tier_config(num_pods=4, leaves_per_pod=2,
                                              hosts_per_leaf=8,
                                              aggs_per_pod=2, num_cores=4)


def _tenants(n: int) -> List[TenantSpec]:
    """Mixed priorities: tenant 0 gets a 6x share, the last tenant is
    squeezed below one job's slot demand, the rest share equally."""
    specs = [TenantSpec(0, weight=6.0, name="priority")]
    specs += [TenantSpec(t, weight=1.0) for t in range(1, n - 1)]
    specs.append(TenantSpec(n - 1, weight=0.02, name="constrained"))
    return specs


def _scenario(cfg, tenants, mean_interarrival_ns: float, algo: Algo,
              policy: str, seed: int):
    from repro.core.fleet import FleetScenario, make_jobs, poisson_arrivals
    rng = random.Random(seed)
    jobs_per_tenant = 1 if FAST else 2
    hosts_per_job = max(4, cfg.num_hosts // (2 * len(tenants)))
    data = 16384 if FAST else 131072
    jobs = []
    for t in tenants:
        arr = poisson_arrivals(jobs_per_tenant, mean_interarrival_ns, rng=rng)
        jobs += make_jobs(t, arr, range(cfg.num_hosts), hosts_per_job, data,
                          rng=rng, app_base=t.tenant * 100)
    return FleetScenario(cfg=cfg, tenants=tenants, jobs=jobs, algo=algo,
                         quota_policy=policy)


def _diagnose_cell():
    """One representative congested cell (fat_tree, CANARY, weighted quotas)
    re-run with telemetry spans on; returns the diagnosis report dict."""
    from repro.core.fleet import FleetDriver
    topo, cfg = next(_topologies())
    cfg = dataclasses.replace(cfg, telemetry=True, telemetry_spans=True)
    scenario = _scenario(cfg, _tenants(4), 20_000.0, Algo.CANARY,
                         "weighted", seed=1)
    fr = FleetDriver(scenario).run()
    print(fr.diagnosis.to_text())
    doc = fr.diagnosis.to_json()
    doc["cell"] = f"fleet/{topo}/canary/tenants=4/rate=20us/quota=weighted"
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--diagnose", action="store_true",
                    help="attach the telemetry-backed diagnosis of one "
                         "representative cell to the results JSON")
    # benchmarks.run invokes main() with no argv; never read sys.argv there
    args = ap.parse_args(argv or [])
    from repro.core.fleet import FleetDriver
    tenant_counts = (4,) if FAST else (4, 8)
    rates_ns = (20_000.0,) if FAST else (20_000.0, 5_000.0)
    policies = ("none", "weighted")
    algos = ((Algo.CANARY, "canary"), (Algo.STATIC_TREE, "static1"),
             (Algo.RING, "ring"))
    cells = []
    for topo, cfg in _topologies():
        for n_tenants in tenant_counts:
            for rate in rates_ns:
                for policy in policies:
                    for algo, label in algos:
                        scenario = _scenario(cfg, _tenants(n_tenants), rate,
                                             algo, policy, seed=1)
                        fr, us = timed(FleetDriver(scenario).run)
                        sd = f"{fr.mean_slowdown:.2f}" \
                            if fr.mean_slowdown is not None else "nan"
                        name = (f"fleet/{topo}/{label}/tenants={n_tenants}/"
                                f"rate={int(rate/1000)}us/quota={policy}")
                        emit(name, us,
                             f"mean_jct_us={fr.mean_jct_ns/1e3:.1f};"
                             f"slowdown={sd};jain={fr.jain_fairness:.3f};"
                             f"degraded={fr.degraded_jobs};"
                             f"correct={fr.correct}")
                        cells.append({
                            "topology": topo, "algo": label,
                            "tenants": n_tenants,
                            "mean_interarrival_ns": rate,
                            "quota_policy": policy,
                            "jobs": len(fr.jobs),
                            "mean_jct_ns": fr.mean_jct_ns,
                            "p50_jct_ns": fr.p50_jct_ns,
                            "p99_jct_ns": fr.p99_jct_ns,
                            "max_jct_ns": fr.max_jct_ns,
                            "mean_slowdown": fr.mean_slowdown,
                            "jain_fairness": fr.jain_fairness,
                            "degraded_jobs": fr.degraded_jobs,
                            "deferred_jobs": fr.deferred_jobs,
                            "correct": fr.correct,
                            "per_tenant": {str(t): d for t, d in
                                           fr.per_tenant.items()},
                            "wall_us": us,
                        })
    doc = {"suite": "fleet", "fast": FAST, "cells": cells}
    if args.diagnose:
        doc["diagnosis"] = _diagnose_cell()
    path = os.environ.get("FLEET_JSON", "FLEET_RESULTS.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    bad = [c for c in cells if not c["correct"]]
    if bad:
        raise SystemExit(f"fleet suite: {len(bad)} incorrect cells: "
                         f"{[c['topology'] + '/' + c['algo'] for c in bad]}")


if __name__ == "__main__":
    main(sys.argv[1:])
