"""Workload suite: model-config-derived training iterations, end to end.

Three measurement groups, all built on ``repro.core.workload``:

* **cells** — every named scenario (dense llama3 / deepseek-moe / mamba2 /
  whisper x fat_tree / three_tier) x algorithm (CANARY / STATIC_TREE /
  RING) x congestion on/off: predicted iteration time, exposed-communication
  fraction, bucket count. Every cell asserts exactness.
* **bucket_sweep** (full mode) — the acceptance regime: deepseek-moe on a
  congested fat tree with full-scale wire bytes at two DDP bucket sizes,
  averaged over three placements. Shows the paper's Fig. 9 shape: CANARY's
  advantage appears once buckets are large enough to amortize dynamic-tree
  setup; at KiB-scale buckets STATIC_TREE can win. The JSON records the
  CANARY-vs-STATIC speedup per bucket size.
* **scaling** (full mode) — ``scaling_curves``: hosts x algorithm x
  congestion for the dense model, fixed placement per host count.

Writes ``WORKLOAD_RESULTS.json`` (``WORKLOAD_JSON=`` to move it);
registered as the ``workload`` suite in ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import os
import statistics
from typing import List

from repro.core.canary import Algo, scaled_config

from .common import FAST, emit, timed

ALGOS = ((Algo.CANARY, 1, "canary"), (Algo.STATIC_TREE, 1, "static1"),
         (Algo.RING, 1, "ring"))


def _scenario_cells() -> List[dict]:
    from repro.core.workload import list_scenarios, predict_scenario
    if FAST:
        names = ("deepseek-moe/fat_tree", "llama3-dense/three_tier")
        algos = ALGOS[:2]
        congestion_levels = (True,)
        overrides = dict(bytes_scale=0.03)
    else:
        names = tuple(list_scenarios())
        algos = ALGOS
        congestion_levels = (False, True)
        overrides = {}
        # ring-on-three_tier cells are back at full scale: the host-based
        # ring under 3-tier congestion simulates ~100x more traffic-time
        # than CANARY, but the hot-path overhaul (benchmarks/perf.py) made
        # full-scale cells affordable; each cell's wall_us lands in the JSON.
    cells = []
    for name in names:
        for algo, nt, label in algos:
            for cong in congestion_levels:
                (p, us) = timed(predict_scenario, name, algo=algo,
                                n_trees=nt, congestion=cong, **overrides)
                emit(f"workload/{name}/{label}/cong={int(cong)}", us,
                     f"iter_us={p.iteration_ns / 1e3:.1f};"
                     f"exposed={p.exposed_comm_frac:.3f};"
                     f"buckets={len(p.buckets)};correct={p.correct}")
                cells.append({
                    "scenario": name, "model": p.model, "algo": label,
                    "congestion": cong,
                    "iteration_ns": p.iteration_ns,
                    "compute_ns": p.compute_ns,
                    "comm_last_finish_ns": p.comm_last_finish_ns,
                    "exposed_comm_frac": p.exposed_comm_frac,
                    "buckets": len(p.buckets),
                    "dp_grad_bytes": p.plan.total_grad_bytes,
                    "expert_grad_bytes": p.plan.expert_grad_bytes,
                    "correct": p.correct, "wall_us": us,
                })
    return cells


def _bucket_sweep() -> List[dict]:
    """Acceptance regime: full wire scale, congested, mean of 3 placements."""
    from repro.core.workload import predict_scenario
    rows = []
    for bucket_bytes in (1 << 17, 1 << 20):
        iters = {}
        for algo, nt, label in ALGOS[:2]:
            preds = []
            for seed in (0, 1, 2):
                p = predict_scenario(
                    "deepseek-moe/fat_tree", algo=algo, n_trees=nt,
                    congestion=True, sim_cfg=scaled_config(4, seed=seed),
                    bucket_bytes=bucket_bytes, bytes_scale=1.0)
                assert p.correct
                preds.append(p)
            iters[label] = statistics.mean(p.iteration_ns for p in preds)
            rows.append({
                "bucket_bytes": bucket_bytes, "algo": label,
                "mean_iteration_ns": iters[label],
                "mean_exposed_comm_frac": statistics.mean(
                    p.exposed_comm_frac for p in preds),
                "seeds": [0, 1, 2],
            })
        speedup = iters["static1"] / iters["canary"]
        emit(f"workload/bucket_sweep/{bucket_bytes >> 10}KiB", 0.0,
             f"canary_iter_us={iters['canary'] / 1e3:.1f};"
             f"static_iter_us={iters['static1'] / 1e3:.1f};"
             f"canary_speedup={speedup:.3f}")
        rows.append({"bucket_bytes": bucket_bytes,
                     "canary_vs_static_speedup": speedup})
    return rows


def _scaling() -> List[dict]:
    from repro.core.workload import get_model_config, scaling_curves
    model = get_model_config("llama3.2-1b", "smoke")
    rows = scaling_curves(model, scaled_config(4, seed=5),
                          hosts_list=(4, 8, 12),
                          bytes_scale=0.125, bucket_bytes=1 << 17)
    for r in rows:
        emit(f"workload/scaling/hosts={r['hosts']}/{r['algo']}/"
             f"cong={int(r['congestion'])}", 0.0,
             f"iter_us={r['iteration_ns'] / 1e3:.1f};"
             f"exposed={r['exposed_comm_frac']:.3f};"
             f"correct={r['correct']}")
    return rows


def main() -> None:
    cells = _scenario_cells()
    doc = {"suite": "workload", "fast": FAST, "cells": cells}
    if not FAST:
        doc["bucket_sweep"] = _bucket_sweep()
        doc["scaling"] = _scaling()
    path = os.environ.get("WORKLOAD_JSON", "WORKLOAD_RESULTS.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    bad = [c for c in cells if not c["correct"]]
    bad += [r for r in doc.get("scaling", ()) if not r["correct"]]
    if bad:
        raise SystemExit(f"workload suite: {len(bad)} incorrect cells")


if __name__ == "__main__":
    main()
