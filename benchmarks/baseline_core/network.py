"""Two-level fat-tree network model (§5.2) — the paper's topology.

Topology (paper defaults): 32 leaf switches with 64 ports each (32 down to
hosts, 32 up — one to each spine), 32 spine switches with 32 ports (one per
leaf). 100 Gb/s everywhere, 300 ns per hop.

This is the ``fat_tree`` implementation of the :class:`~.topology.Topology`
protocol (see ``topology.py`` for the protocol and the registry, and
``ARCHITECTURE.md`` for the layer map). Routing — including the
congestion-aware up-port selection the paper assumes as its substrate (§2.1)
— lives here; the switch dataplane and host protocol layers never touch a
link directly.

Node addressing
---------------
* hosts:   ``0 .. num_hosts-1``; host ``h`` hangs off leaf ``h // hosts_per_leaf``.
* switches (global index): leaves ``0 .. L-1``, spines ``L .. L+S-1``.

Port numbering (matches the children-bitmap semantics of §4.2)
---------------------------------------------------------------
* leaf ``l``:  port ``p < hosts_per_leaf``  -> host ``l*hosts_per_leaf + p`` (down)
               port ``hosts_per_leaf + s``  -> spine ``s``                  (up)
* spine ``s``: port ``l``                   -> leaf ``l``                   (down)
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional

from .topology import Link, Topology, pick_min_backlog, register_topology
from .types import Packet, PacketKind, SimConfig

__all__ = ["FatTree", "Link"]


@register_topology("fat_tree")
class FatTree(Topology):
    """Topology + routing. Switch indices are global (leaves then spines)."""

    def __init__(self, cfg: SimConfig):
        cfg.validate()
        self.cfg = cfg
        self.L = cfg.num_leaves
        self.S = cfg.num_spines
        self.H = cfg.hosts_per_leaf
        self.num_hosts = cfg.num_hosts
        self.num_switches = self.L + self.S
        bpn, lat, cap = cfg.bytes_per_ns, cfg.hop_latency_ns, cfg.buffer_bytes

        def mk() -> Link:
            return Link(bpn, lat, cap)

        # host <-> leaf
        self.host_up = [mk() for _ in range(cfg.num_hosts)]    # host -> leaf
        self.host_down = [mk() for _ in range(cfg.num_hosts)]  # leaf -> host
        # leaf <-> spine (full bipartite)
        self.leaf_up = [[mk() for _ in range(self.S)] for _ in range(self.L)]
        self.leaf_down = [[mk() for _ in range(self.S)] for _ in range(self.L)]
        # flowlet tables: (leaf, flow key) -> committed spine [37]
        self.flowlets: dict = {}

    # ---- helpers -----------------------------------------------------------
    @classmethod
    def config_num_switches(cls, cfg: SimConfig) -> int:
        return cfg.num_leaves + cfg.num_spines

    def leaf_of(self, host: int) -> int:
        return host // self.H

    def is_leaf(self, sw: int) -> bool:
        return sw < self.L

    def spine_index(self, sw: int) -> int:
        return sw - self.L

    def is_up_port(self, sw: int, port: int) -> bool:
        return self.is_leaf(sw) and port >= self.H

    # Port maps (see module docstring).
    def leaf_port_of_host(self, host: int) -> int:
        return host % self.H

    def leaf_port_of_spine(self, spine: int) -> int:
        return self.H + spine

    def spine_port_of_leaf(self, leaf: int) -> int:
        return leaf

    # ---- LB: pick the up-port (spine) for a packet leaving ``leaf`` --------
    def pick_spine(self, leaf: int, now: float, flow_hash: int,
                   rng: Optional[random.Random] = None,
                   dest_leaf: int = -1, policy: Optional[str] = None) -> int:
        """Congestion-aware up-port selection (§2.1, §5.2).

        The paper's premise is an existing congestion-aware load-balancing
        substrate (CONGA [37], DRILL [41], ...). CONGA-style schemes measure
        *path* congestion, so when the destination leaf is known the metric
        is the up-link backlog **plus** the spine->dest-leaf down-link
        backlog (the ``remote`` leg); purely local schemes would leave
        destination-side hotspots invisible. The policy arithmetic itself is
        the shared :func:`~.topology.pick_min_backlog`, so the two fabrics
        can never drift apart.
        """
        cfg = self.cfg
        default = flow_hash % self.S
        lb = policy if policy is not None else cfg.lb
        remote = self.leaf_down[dest_leaf] \
            if cfg.path_aware_lb and dest_leaf >= 0 and dest_leaf != leaf \
            else None
        return pick_min_backlog(self.leaf_up[leaf], default, now, str(lb),
                                cfg.lb_threshold * cfg.buffer_bytes, remote)

    def pick_spine_flowlet(self, leaf: int, now: float, flow_hash: int,
                           flow_key: object, rng=None,
                           dest_leaf: int = -1,
                           policy: Optional[str] = None) -> int:
        """Flowlet-sticky variant: decide once per flow key, then stick [37]."""
        key = (leaf, flow_key)
        cached = self.flowlets.get(key)
        if cached is not None:
            return cached
        spine = self.pick_spine(leaf, now, flow_hash, rng, dest_leaf=dest_leaf,
                                policy=policy)
        self.flowlets[key] = spine
        return spine

    # ---- transmit (drop checks & byte accounting live in Topology.tx_*) ----
    def send_from_host(self, sim, host: int, pkt: Packet) -> float:
        return self.tx_to_switch(sim, self.host_up[host], pkt,
                                 self.leaf_of(host),
                                 self.leaf_port_of_host(host))

    def _send_leaf_up(self, sim, leaf: int, spine: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.leaf_up[leaf][spine], pkt, self.L + spine,
                          self.spine_port_of_leaf(leaf))

    def _send_spine_down(self, sim, spine: int, leaf: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.leaf_down[leaf][spine], pkt, leaf,
                          self.leaf_port_of_spine(spine))

    def _send_leaf_to_host(self, sim, host: int, pkt: Packet) -> None:
        self.tx_to_host(sim, self.host_down[host], pkt, host)

    # ---- routing -----------------------------------------------------------
    def forward_toward_host(self, sim, sw: int, pkt: Packet) -> None:
        if self.is_leaf(sw):
            if self.leaf_of(pkt.dest) == sw:
                self._send_leaf_to_host(sim, pkt.dest, pkt)
            else:
                # Default up-port: Topology.flow_hash — same-block partials
                # converge on one spine, blocks spread, retransmitted
                # generations re-route (§3.1.3/§3.3).
                kind = pkt.kind
                dleaf = self.leaf_of(pkt.dest)
                fh = self.flow_hash(pkt)
                # background congestion traffic rides its own policy (§2.1)
                policy = str(self.cfg.noise_lb) if kind == PacketKind.NOISE \
                    else None
                if self.cfg.flowlet_lb and kind in (PacketKind.NOISE,
                                                    PacketKind.RING):
                    # point-to-point traffic moves at flowlet granularity [37]
                    spine = self.pick_spine_flowlet(sw, sim.now, fh,
                                                    self.flowlet_key(pkt),
                                                    sim.rng, dest_leaf=dleaf,
                                                    policy=policy)
                else:
                    # NOTE: the seed monolith dropped ``policy`` here, so
                    # with flowlet_lb=False background noise silently rode
                    # cfg.lb instead of cfg.noise_lb. Passing it is an
                    # intentional (non-golden-covered) behaviour fix that
                    # keeps noise_lb semantics identical across fabrics.
                    spine = self.pick_spine(sw, sim.now, fh, sim.rng,
                                            dest_leaf=dleaf, policy=policy)
                self._send_leaf_up(sim, sw, spine, pkt)
        else:
            self._send_spine_down(sim, self.spine_index(sw),
                                  self.leaf_of(pkt.dest), pkt)

    def forward_toward_switch(self, sim, sw: int, pkt: Packet) -> None:
        target = pkt.dest_switch
        if self.is_leaf(sw):
            if self.is_leaf(target):
                fh = hash(target)
                spine = self.pick_spine(sw, sim.now, fh, sim.rng,
                                        dest_leaf=target)
                self._send_leaf_up(sim, sw, spine, pkt)
            else:
                self._send_leaf_up(sim, sw, self.spine_index(target), pkt)
        else:
            if self.is_leaf(target):
                self._send_spine_down(sim, self.spine_index(sw), target, pkt)
            else:
                # spine -> spine requires bouncing off any leaf; route via leaf 0
                self._send_spine_down(sim, self.spine_index(sw), 0, pkt)

    def out_port_send(self, sim, sw: int, port: int, pkt: Packet) -> None:
        if self.is_leaf(sw):
            if port < self.H:
                self._send_leaf_to_host(sim, sw * self.H + port, pkt)
            else:
                self._send_leaf_up(sim, sw, port - self.H, pkt)
        else:
            self._send_spine_down(sim, self.spine_index(sw), port, pkt)

    # ---- static-tree support ----------------------------------------------
    def root_candidates(self) -> List[int]:
        return [self.L + s for s in range(self.S)]

    def static_expected(self, parts: List[int], root: int) -> Dict[int, int]:
        plan: Dict[int, int] = {}
        for h in parts:
            leaf = self.leaf_of(h)
            plan[leaf] = plan.get(leaf, 0) + 1
        plan[root] = len(plan)
        return plan

    def static_send_up(self, sim, sw: int, root: int, pkt: Packet) -> None:
        self._send_leaf_up(sim, sw, self.spine_index(root), pkt)

    # ---- utilization accounting ---------------------------------------------
    def all_links(self) -> List[Link]:
        out: List[Link] = []
        out.extend(self.host_up)
        out.extend(self.host_down)
        for row in self.leaf_up:
            out.extend(row)
        for row in self.leaf_down:
            out.extend(row)
        return out
