"""Switch dataplane layer: per-switch soft state + aggregation strategies.

Two pieces live here (see ``ARCHITECTURE.md``):

* :class:`SwitchLayer` — the algorithm-independent dataplane every switch
  runs: failure state, descriptor tables, arrival dispatch (pass-through
  kinds, RESTORE routing, timer guards), and the tree-restoration fan-out.
* The **algorithm-strategy registry**: :class:`AggregationStrategy`
  subclasses implement how REDUCE/BCAST packets are processed in-network and
  how hosts generate their sends. ``CANARY`` and ``STATIC_TREE`` live here;
  host-based algorithms (``RING``, in ``hostproto.py``) register in the same
  registry and simply leave the switch hooks at their pass-through defaults.

Registering a new collective::

    @register_algorithm(Algo.MY_ALGO)
    class MyStrategy(AggregationStrategy):
        ...

No engine, topology or facade changes are needed — the facade looks the
algorithm up by ``Algo`` value at construction time.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Type

from .engine import EV_RETX, EV_TIMER
from .types import (Algo, Descriptor, Packet, PacketKind, id_app, id_block,
                    make_id)

# kinds the switch dataplane never inspects — pure forwarding
_PASSTHROUGH = (PacketKind.NOISE, PacketKind.RING, PacketKind.RETX_REQ,
                PacketKind.FAIL, PacketKind.UNICAST_DATA)


class SwitchLayer:
    """Algorithm-independent per-switch state + arrival dispatch."""

    def __init__(self, sim, num_switches: int):
        self.sim = sim
        self.tables: List[Dict[int, Descriptor]] = [dict() for _ in
                                                    range(num_switches)]
        self.slots: List[Dict[int, int]] = [dict() for _ in range(num_switches)]
        self.failed = [False] * num_switches
        self.desc_high = [0] * num_switches
        self.timer_seq = 0

    # ------------------------------------------------------------- dispatch
    def arrive(self, sw: int, in_port: int, pkt: Packet) -> None:
        sim = self.sim
        if self.failed[sw]:
            sim.dropped += 1
            return
        kind = pkt.kind
        if kind in _PASSTHROUGH:
            sim.net.forward_toward_host(sim, sw, pkt)
            return
        if kind == PacketKind.RESTORE:
            if pkt.dest_switch == sw:
                self.restore_at(sw, pkt)
            else:
                sim.net.forward_toward_switch(sim, sw, pkt)
            return
        if kind == PacketKind.REDUCE:
            sim.strategy.on_switch_reduce(sw, in_port, pkt)
        elif kind == PacketKind.BCAST:
            sim.strategy.on_switch_bcast(sw, pkt)

    def on_timer(self, sw: int, timer_seq: int, pid: int) -> None:
        desc = self.tables[sw].get(pid)
        if desc is not None and desc.timer_seq == timer_seq and \
                not desc.sent and not self.failed[sw]:
            self.sim.strategy.on_descriptor_timeout(sw, desc)

    def fail_switch(self, sw: int) -> None:
        self.failed[sw] = True

    # ------------------------------------------------------------- helpers
    def note_high_water(self, sw: int) -> None:
        if len(self.tables[sw]) > self.desc_high[sw]:
            self.desc_high[sw] = len(self.tables[sw])

    def dealloc(self, sw: int, desc: Descriptor) -> None:
        self.tables[sw].pop(desc.id, None)
        if self.slots[sw].get(desc.slot) == desc.id:
            self.slots[sw].pop(desc.slot, None)

    def restore_at(self, sw: int, pkt: Packet) -> None:
        """Tree restoration (§3.2.1): forward data out the stamped ports."""
        sim = self.sim
        bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pkt.id, value=pkt.value,
                    multicast=True, size_bytes=sim.cfg.mtu_bytes)
        if sim.trace is not None:
            sim.trace.on_bcast_fanout(sw, bc, pkt.restore_ports)
        for port in pkt.restore_ports:
            sim.net.out_port_send(sim, sw, port, bc)


# --------------------------------------------------------------------------
# Algorithm-strategy registry
# --------------------------------------------------------------------------
# Keyed by *string* value (Algo is a str-enum, so built-ins use their enum
# value) — new collectives register under any fresh key without having to
# extend the Algo enum first.
ALGORITHMS: Dict[str, Type["AggregationStrategy"]] = {}


def register_algorithm(algo):
    """Class decorator: bind a strategy to an :class:`Algo` value or any
    string key a new collective wants to go by."""

    def deco(cls: Type["AggregationStrategy"]) -> Type["AggregationStrategy"]:
        cls.algo = algo
        ALGORITHMS[str(algo)] = cls
        return cls

    return deco


def make_strategy(algo, sim) -> "AggregationStrategy":
    try:
        cls = ALGORITHMS[str(algo)]
    except KeyError:
        raise ValueError(f"no strategy registered for algorithm {algo!r}; "
                         f"registered: {sorted(ALGORITHMS)}") from None
    return cls(sim)


class AggregationStrategy:
    """How one collective algorithm uses the fabric.

    The defaults implement a *host-based* algorithm riding a cursor-less
    send queue: switches forward everything, hosts drive the protocol via
    :meth:`on_host_packet`. In-network algorithms override the switch hooks.
    """

    algo: Algo
    leader_skips_self = False  # CANARY: the leader keeps its contribution local
    uses_retx_timers = False   # CANARY: host-side loss detection (§3.3)
    # True when the strategy allocates per-switch descriptors — the resource
    # the fleet admission controller budgets (§3.2.2). Host-based strategies
    # (RING) keep the default and are always admitted without a quota.
    uses_switch_memory = False

    def __init__(self, sim):
        self.sim = sim

    # ---- job setup ---------------------------------------------------------
    def setup_job(self, app: int, job, parts: List[int]) -> None:
        """Default: every participant streams its blocks via a lazy cursor.

        Pumps are scheduled at ``sim.now`` — 0.0 for construction-time jobs,
        the arrival/admission time for open-loop (fleet) jobs.
        """
        sim = self.sim
        hp = sim.hostproto
        for h in parts:
            hp.hosts[h].send_cursor.append([app, 0])
            hp.schedule_pump(h, sim.now)

    # ---- host send generation ---------------------------------------------
    def next_host_packet(self, host: int) -> Optional[Packet]:
        """Produce this host's next allreduce send (monolith cursor walk)."""
        sim = self.sim
        hs = sim.hostproto.hosts[host]
        cfg = sim.cfg
        for cur in hs.send_cursor:
            app, nxt = cur
            B = sim.blocks[app]
            # admission-degraded apps ride the §3.3 host-based path whatever
            # the strategy: bypass packets straight to the leader, which
            # keeps its own contribution local and unicasts the result
            degraded = app in sim.bypass_apps
            if self.leader_skips_self or degraded:
                while nxt < B and sim.leader_of(app, nxt) == host:
                    nxt += 1  # the leader keeps its contribution local (§3.1.4)
            if nxt < B:
                cur[1] = nxt + 1
                pid = make_id(app, nxt, 0)
                size = cfg.header_bytes + 8 \
                    if sim.jobs[app].collective == "barrier" else cfg.mtu_bytes
                pkt = Packet(kind=PacketKind.REDUCE,
                             dest=sim.leader_of(app, nxt), id=pid, counter=1,
                             hosts=len(sim.leaders[app]),
                             value=sim.contribution_of(app, nxt, host),
                             bypass=degraded, size_bytes=size, src=host)
                if sim.trace is not None:
                    sim.trace.on_host_send(host, pkt)
                if self.uses_retx_timers or degraded:
                    # loss detection is part of the Canary protocol (§3.3);
                    # static-tree systems restart from scratch instead.
                    sim.engine.push(sim.now + cfg.retx_timeout_ns, EV_RETX,
                                    host, 0, (app, nxt, 0))
                return pkt
            cur[1] = nxt
        return None

    # ---- switch dataplane hooks --------------------------------------------
    def on_switch_reduce(self, sw: int, in_port: int, pkt: Packet) -> None:
        self.sim.net.forward_toward_host(self.sim, sw, pkt)

    def on_switch_bcast(self, sw: int, pkt: Packet) -> None:
        self.sim.net.forward_toward_host(self.sim, sw, pkt)

    def on_descriptor_timeout(self, sw: int, desc: Descriptor) -> None:
        pass

    # ---- host arrival hook --------------------------------------------------
    def on_host_packet(self, host: int, pkt: Packet) -> bool:
        """Return True when the strategy consumed the packet."""
        return False


@register_algorithm(Algo.CANARY)
class CanaryStrategy(AggregationStrategy):
    """Dynamic trees: timeout aggregation, collisions + restoration (§3)."""

    leader_skips_self = True
    uses_retx_timers = True
    uses_switch_memory = True

    # ---- descriptor slot hashing -------------------------------------------
    @staticmethod
    def _hash64(pid: int) -> int:
        # Fibonacci hashing; use the HIGH bits — block ids have zero low bits
        # (generation field), and power-of-two tables would otherwise see only
        # a tiny fraction of their slots.
        return ((pid * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> 24

    def slot_of(self, pid: int) -> int:
        sim = self.sim
        cfg = sim.cfg
        region = sim.slot_regions.get(id_app(pid))
        if region is not None:
            # enforced tenant quota (fleet admission, §3.2.2): this app's
            # descriptors can only ever occupy its tenant's slot region, so
            # a tenant's per-switch footprint is hard-bounded by its quota —
            # overflow within the region collides and bypasses (§3.2.1)
            # instead of stealing another tenant's slots.
            offset, size = region
            return offset + self._hash64(pid) % size
        if cfg.partition_table and len(sim.jobs) > 1:
            apps = len(sim.jobs)
            region_sz = max(1, cfg.table_size // apps)
            return (id_app(pid) % apps) * region_sz \
                + self._hash64(pid) % region_sz
        return self._hash64(pid) % cfg.table_size

    # ---- dataplane ----------------------------------------------------------
    def on_switch_reduce(self, sw: int, in_port: int, pkt: Packet) -> None:
        sim = self.sim
        if pkt.bypass:
            sim.net.forward_toward_host(sim, sw, pkt)
            return
        sl = sim.switch
        cfg = sim.cfg
        pid = pkt.id
        table = sl.tables[sw]
        desc = table.get(pid)
        if desc is not None:
            desc.children.add(in_port)
            desc.last_ns = sim.now
            if desc.sent:
                # straggler (§3.1.1): forward immediately, keep child recorded
                sim.stragglers += 1
                if sim.trace is not None:
                    sim.trace.on_straggler(sw, in_port, pkt)
                sim.net.forward_toward_host(sim, sw, pkt)
            else:
                desc.value += pkt.value
                desc.counter += pkt.counter
                if sim.trace is not None:
                    sim.trace.on_switch_merge(sw, desc, in_port, pkt)
                if desc.counter >= desc.hosts - 1:
                    self._fire_descriptor(sw, desc)  # all data received (§3.1.4)
            return
        slot = self.slot_of(pid)
        occupant = sl.slots[sw].get(slot)
        if occupant is not None:
            odesc = table.get(occupant)
            if odesc is None:
                sl.slots[sw].pop(slot, None)
                occupant = None
            elif sim.now - odesc.last_ns > cfg.gc_ns:
                # stale soft state (abandoned generation): garbage collect
                sl.dealloc(sw, odesc)
                occupant = None
        if occupant is not None:
            # collision (§3.2.1): stamp and bypass straight to the leader
            sim.collisions += 1
            if sim.trace is not None:
                sim.trace.on_collision(sw, in_port, pkt)
            pkt.switch_addr = sw
            pkt.port_stamp = in_port
            pkt.bypass = True
            sim.net.forward_toward_host(sim, sw, pkt)
            return
        desc = Descriptor(id=pid, slot=slot, value=pkt.value,
                          counter=pkt.counter, hosts=pkt.hosts,
                          children={in_port}, alloc_ns=sim.now,
                          last_ns=sim.now)
        table[pid] = desc
        sl.slots[sw][slot] = pid
        sl.note_high_water(sw)
        if sim.trace is not None:
            sim.trace.on_desc_alloc(sw, desc, in_port, pkt)
        if desc.counter >= desc.hosts - 1:
            self._fire_descriptor(sw, desc)
            return
        sl.timer_seq += 1
        desc.timer_seq = sl.timer_seq
        sim.engine.push(sim.now + cfg.timeout_ns, EV_TIMER, sw, sl.timer_seq,
                        pid)

    def _fire_descriptor(self, sw: int, desc: Descriptor,
                         reason: str = "complete") -> None:
        """Timeout (or early completion): forward the partial aggregate (§3.1.1)."""
        sim = self.sim
        desc.sent = True
        leader = sim.leader_of(id_app(desc.id), id_block(desc.id))
        out = Packet(kind=PacketKind.REDUCE, dest=leader, id=desc.id,
                     counter=desc.counter, hosts=desc.hosts, value=desc.value,
                     size_bytes=sim.cfg.mtu_bytes)
        if sim.trace is not None:
            sim.trace.on_desc_flush(sw, desc, out, reason)
        sim.net.forward_toward_host(sim, sw, out)

    def on_descriptor_timeout(self, sw: int, desc: Descriptor) -> None:
        self._fire_descriptor(sw, desc, reason="timeout")

    def on_switch_bcast(self, sw: int, pkt: Packet) -> None:
        sim = self.sim
        desc = sim.switch.tables[sw].get(pkt.id)
        if desc is None:
            # collision happened here during reduce: drop; the leader's
            # restoration packet re-attaches this subtree (§3.2.1)
            return
        if sim.trace is not None:
            sim.trace.on_bcast_fanout(sw, pkt, desc.children)
        for port in desc.children:
            sim.net.out_port_send(sim, sw, port, pkt)
        sim.switch.dealloc(sw, desc)


@register_algorithm(Algo.STATIC_TREE)
class StaticTreeStrategy(AggregationStrategy):
    """N statically-configured reduction trees (N=1 ~ SHARP/SwitchML/ATP;
    N=4 ~ PANAMA). Roots are drawn from the topology's root candidates; the
    per-switch expected-children plan comes from
    :meth:`~.topology.Topology.static_expected`, so the same strategy runs on
    any registered topology."""

    uses_switch_memory = True

    def __init__(self, sim):
        super().__init__(sim)
        self.roots: Dict[int, List[int]] = {}          # app -> tree roots
        self.plans: Dict[tuple, Dict[int, int]] = {}   # (app, root) -> plan

    def setup_job(self, app: int, job, parts: List[int]) -> None:
        sim = self.sim
        cands = sim.net.root_candidates()
        roots = [cands[sim.rng.randrange(len(cands))]
                 for _ in range(sim.n_trees)]
        self.roots[app] = roots
        for root in roots:
            if (app, root) not in self.plans:
                self.plans[(app, root)] = sim.net.static_expected(parts, root)
        super().setup_job(app, job, parts)

    def root_of(self, app: int, block: int) -> int:
        roots = self.roots[app]
        return roots[block % len(roots)]

    def on_switch_reduce(self, sw: int, in_port: int, pkt: Packet) -> None:
        sim = self.sim
        if pkt.bypass:
            # admission-degraded app (host-based fallback): never part of the
            # static plan — forward straight toward the leader host
            sim.net.forward_toward_host(sim, sw, pkt)
            return
        sl = sim.switch
        app = id_app(pkt.id)
        root = self.root_of(app, id_block(pkt.id))
        table = sl.tables[sw]
        desc = table.get(pkt.id)
        if desc is None:
            expected = self.plans[(app, root)][sw]
            desc = Descriptor(id=pkt.id, slot=-1, hosts=pkt.hosts,
                              expected=expected, alloc_ns=sim.now,
                              last_ns=sim.now)
            table[pkt.id] = desc
            sl.note_high_water(sw)
        desc.children.add(in_port)
        desc.value += pkt.value
        desc.counter += pkt.counter
        desc.last_ns = sim.now
        if sim.trace is not None:
            sim.trace.on_switch_merge(sw, desc, in_port, pkt)
        if len(desc.children) < desc.expected:
            return
        if sw != root:
            out = Packet(kind=PacketKind.REDUCE, dest=-1, id=pkt.id,
                         counter=desc.counter, hosts=pkt.hosts,
                         value=desc.value, size_bytes=sim.cfg.mtu_bytes)
            if sim.trace is not None:
                sim.trace.on_desc_flush(sw, desc, out, "complete")
            sim.net.static_send_up(sim, sw, root, out)
            desc.sent = True
        else:
            bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pkt.id,
                        value=desc.value, multicast=True,
                        size_bytes=sim.cfg.mtu_bytes)
            if sim.trace is not None:
                sim.trace.on_static_root_done(sw, desc)
                sim.trace.on_bcast_fanout(sw, bc, desc.children)
            for port in desc.children:
                sim.net.out_port_send(sim, sw, port, bc)
            table.pop(pkt.id, None)

    def on_switch_bcast(self, sw: int, pkt: Packet) -> None:
        sim = self.sim
        table = sim.switch.tables[sw]
        desc = table.get(pkt.id)
        if desc is None:
            return
        if sim.trace is not None:
            sim.trace.on_bcast_fanout(
                sw, pkt,
                [p for p in desc.children if not sim.net.is_up_port(sw, p)])
        for port in desc.children:
            if sim.net.is_up_port(sw, port):
                continue  # never broadcast back up the tree
            sim.net.out_port_send(sim, sw, port, pkt)
        table.pop(pkt.id, None)
