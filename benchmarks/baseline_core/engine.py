"""Discrete-event engine: clock, heap and event dispatch.

This is the bottom layer of the simulator stack (see ``ARCHITECTURE.md``):
it knows nothing about networks, switches or collectives — it orders
``(time, seq, kind, a, b, c)`` tuples and hands them to per-kind handlers.
The ``seq`` tiebreaker makes simultaneous events FIFO in push order, which is
what makes whole runs bit-reproducible for the golden-replay tests.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Tuple

# Event kinds (heap entries are (time, seq, kind, a, b, c) tuples).
EV_ARRIVE_SWITCH = 0  # a=global switch idx, b=in port, c=packet
EV_ARRIVE_HOST = 1    # a=host, c=packet
EV_TIMER = 2          # a=switch, b=timer_seq, c=packet id
EV_PUMP = 3           # a=host
EV_RETX = 4           # a=host, c=(app, block, gen)
EV_FAIL_SWITCH = 5    # a=switch
EV_LEADER_DONE = 6    # a=leader host, c=(app, block, total)
EV_JOB_ARRIVE = 7     # a=app (open-loop job arrival; fleet subsystem)

Handler = Callable[[int, int, object], None]


class EventLoop:
    """A monotonic event heap with a stable FIFO tiebreak."""

    __slots__ = ("heap", "now", "events", "_seq")

    def __init__(self) -> None:
        self.heap: List[Tuple[float, int, int, int, int, object]] = []
        self.now = 0.0
        self.events = 0
        self._seq = 0

    def push(self, t: float, kind: int, a: int, b: int, c: object) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (t, self._seq, kind, a, b, c))

    def run(self, handlers: Dict[int, Handler],
            done: Callable[[], bool], max_events: int) -> None:
        """Drain the heap, dispatching by event kind, until ``done()`` or empty.

        ``max_events`` is a livelock safety valve, counted over the whole
        loop's lifetime (the counter survives across ``run`` calls).
        """
        heap = self.heap
        while heap:
            if done():
                break
            t, _, kind, a, b, c = heapq.heappop(heap)
            self.now = t
            self.events += 1
            if self.events > max_events:
                raise RuntimeError("event budget exceeded — livelock?")
            handlers[kind](a, b, c)
