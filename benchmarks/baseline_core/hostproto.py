"""Host protocol layer: send pump, leader recovery, retransmission.

Everything a *host* NIC/CPU does lives here (see ``ARCHITECTURE.md``):

* :class:`HostProtocol` — per-host send queues and the pump (one in-flight
  packet per NIC, rescheduled at line rate), block-completion accounting, and
  the Canary leader role: final aggregation (§3.1.4), broadcast +
  tree-restoration kickoff (§3.2.1), loss recovery and generation management
  (§3.3).
* :class:`RingStrategy` — the host-based ring allreduce baseline. It is an
  :class:`~.switch.AggregationStrategy` like CANARY/STATIC_TREE, registered
  in the same registry; switches simply forward its packets (the base-class
  default), which is precisely what makes it "host-based".
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

from .engine import EV_LEADER_DONE, EV_PUMP, EV_RETX
from .switch import AggregationStrategy, register_algorithm
from .types import (Algo, GEN_BITS, Packet, PacketKind, id_app, id_block,
                    id_gen, make_id)

_MAX_GEN = (1 << GEN_BITS) - 1


class _HostState:
    __slots__ = ("queue", "pending", "pump_scheduled", "noise_peer",
                 "noise_remaining", "noise_msg_idx", "send_cursor")

    def __init__(self) -> None:
        self.queue: Deque[Packet] = deque()
        self.pending: Optional[Packet] = None
        self.pump_scheduled = False
        self.noise_peer = -1
        self.noise_remaining = 0
        self.noise_msg_idx = 0
        # lazy cursor over this host's allreduce contributions: [app, next_block]
        self.send_cursor: List[List[int]] = []


class _LeaderState:
    __slots__ = ("value", "counter", "gen", "restorations", "done",
                 "last_fail_ns", "pending_done")

    def __init__(self) -> None:
        self.value = 0
        self.counter = 0
        self.gen = 0
        self.restorations: List[Tuple[int, int]] = []
        self.done = False
        self.pending_done = False
        self.last_fail_ns = -1e18


class HostProtocol:
    """Per-host send machinery + the leader/reliability protocol."""

    def __init__(self, sim, num_hosts: int):
        self.sim = sim
        self.hosts = [_HostState() for _ in range(num_hosts)]
        self.host_gen: Dict[Tuple[int, int, int], int] = {}  # (host, app, block)
        self.leader_state: Dict[Tuple[int, int], _LeaderState] = {}
        self.completed_total: Dict[Tuple[int, int], int] = {}
        self.fallback_blocks: Set[Tuple[int, int]] = set()

    # ------------------------------------------------------------ send pump
    def schedule_pump(self, host: int, t: float) -> None:
        hs = self.hosts[host]
        if not hs.pump_scheduled:
            hs.pump_scheduled = True
            self.sim.engine.push(t, EV_PUMP, host, 0, None)

    def _next_host_packet(self, host: int) -> Optional[Packet]:
        sim = self.sim
        hs = self.hosts[host]
        if hs.queue:
            return hs.queue.popleft()
        pkt = sim.strategy.next_host_packet(host)
        if pkt is not None:
            return pkt
        return sim.workload.next_noise_packet(host, hs)

    def pump(self, host: int) -> None:
        sim = self.sim
        hs = self.hosts[host]
        if sim.all_done():
            return
        pkt = hs.pending
        hs.pending = None
        if pkt is None:
            pkt = self._next_host_packet(host)
            if pkt is None:
                return
            # §5.2.5 sender-side OS noise: delay this send with probability p.
            delay = sim.workload.sender_delay_ns(host)
            if delay is not None:
                hs.pending = pkt
                hs.pump_scheduled = True
                sim.engine.push(sim.now + delay, EV_PUMP, host, 0, None)
                return
        nic_free = sim.net.send_from_host(sim, host, pkt)
        hs.pump_scheduled = True
        sim.engine.push(nic_free, EV_PUMP, host, 0, None)

    # ----------------------------------------------------------- completion
    def complete_at_host(self, host: int, app: int, block: int,
                         value: int) -> None:
        sim = self.sim
        flags = sim.have.get((app, host))
        if flags is None or flags[block]:
            return
        flags[block] = 1
        if sim.trace is not None:
            sim.trace.on_host_complete(host, app, block)
        if value != sim.expected_total(app, block):
            sim.mismatches += 1
        sim.app_remaining[app] -= 1
        sim.completed_blocks += 1
        if sim.app_remaining[app] == 0:
            sim.job_finished(app)

    # ---------------------------------------------------------- leader role
    def leader_block_done(self, host: int, app: int, block: int,
                          total: int) -> None:
        sim = self.sim
        key = (app, block)
        st = self.leader_state.get(key)
        if st is None or st.done:
            return
        st.done = True
        self.completed_total[key] = total
        self.complete_at_host(host, app, block, total)
        if sim.jobs[app].collective == "reduce":
            return  # §6: a reduce skips the broadcast phase entirely
        pid = make_id(app, block, st.gen)
        cfg = sim.cfg
        if key in self.fallback_blocks or app in sim.bypass_apps:
            # host-based fallback (§3.3): no descriptors exist — unicast result
            for h in sim.leaders[app]:
                if h == host:
                    continue
                up = Packet(kind=PacketKind.UNICAST_DATA, dest=h, id=pid,
                            value=total, size_bytes=cfg.mtu_bytes, src=host)
                self.hosts[host].queue.append(up)
        else:
            # broadcast down the recorded tree (§3.1.2)
            bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pid, value=total,
                        multicast=True, size_bytes=cfg.mtu_bytes)
            self.hosts[host].queue.append(bc)
            # tree restoration for collided subtrees (§3.2.1)
            by_switch: Dict[int, List[int]] = {}
            for sw_addr, port in st.restorations:
                by_switch.setdefault(sw_addr, []).append(port)
            for sw_addr, ports in by_switch.items():
                sim.restorations += 1
                rp = Packet(kind=PacketKind.RESTORE, dest=-1, id=pid,
                            value=total, dest_switch=sw_addr,
                            restore_ports=tuple(set(ports)),
                            size_bytes=cfg.mtu_bytes)
                if sim.trace is not None:
                    sim.trace.on_restore(pid, sw_addr, rp.restore_ports)
                self.hosts[host].queue.append(rp)
        self.schedule_pump(host, sim.now)

    # --------------------------------------------------------- host arrival
    def arrive(self, host: int, pkt: Packet) -> None:
        sim = self.sim
        kind = pkt.kind
        if kind == PacketKind.NOISE:
            return
        if sim.strategy.on_host_packet(host, pkt):
            return
        app, block, gen = id_app(pkt.id), id_block(pkt.id), id_gen(pkt.id)
        if kind == PacketKind.REDUCE:
            if sim.leader_of(app, block) != host:
                return
            key = (app, block)
            st = self.leader_state.setdefault(key, _LeaderState())
            if st.done or st.pending_done or gen != st.gen:
                return  # stale generation or already reduced
            st.value += pkt.value
            st.counter += pkt.counter
            if sim.trace is not None:
                sim.trace.on_leader_merge(host, pkt)
            if pkt.switch_addr >= 0:
                st.restorations.append((pkt.switch_addr, pkt.port_stamp))
            if st.counter >= len(sim.leaders[app]) - 1:
                total = st.value + sim.contribution_of(app, block, host)
                st.pending_done = True
                if sim.trace is not None:
                    sim.trace.on_leader_complete(host, app, block, gen)
                # leader-side aggregation cost r (§3.2.2)
                sim.engine.push(sim.now + sim.cfg.leader_aggregate_ns,
                                EV_LEADER_DONE, host, 0, (app, block, total))
            return
        if kind in (PacketKind.BCAST, PacketKind.UNICAST_DATA):
            self.complete_at_host(host, app, block, pkt.value)
            return
        if kind == PacketKind.RETX_REQ:
            self.leader_handle_retx(host, app, block, pkt.src)
            return
        if kind == PacketKind.FAIL:
            self.host_handle_fail(host, pkt)
            return

    # ----------------------------------------------------------- reliability
    def leader_handle_retx(self, leader: int, app: int, block: int,
                           requester: int) -> None:
        sim = self.sim
        cfg = sim.cfg
        key = (app, block)
        total = self.completed_total.get(key)
        if total is not None:
            # loss was in the broadcast phase: retransmit reduced data (§3.3)
            up = Packet(kind=PacketKind.UNICAST_DATA, dest=requester,
                        id=make_id(app, block, 0), value=total,
                        size_bytes=cfg.mtu_bytes, src=leader)
            self.hosts[leader].queue.append(up)
            self.schedule_pump(leader, sim.now)
            return
        st = self.leader_state.setdefault(key, _LeaderState())
        if st.pending_done:
            return  # completion already in flight
        if sim.now - st.last_fail_ns < cfg.retx_timeout_ns / 2:
            return  # debounce: a failure round is already in flight
        st.last_fail_ns = sim.now
        newgen = min(st.gen + 1, _MAX_GEN)
        fallback = newgen >= cfg.max_generations
        if fallback and key not in self.fallback_blocks:
            sim.fallbacks += 1
            self.fallback_blocks.add(key)
            if app not in sim.bypass_apps:
                # admission-degraded apps were counted whole at activation
                sim.app_fallback_blocks[app] = \
                    sim.app_fallback_blocks.get(app, 0) + 1
        st.gen = newgen
        st.value = 0
        st.counter = 0
        st.restorations = []
        # "the leader broadcasts a failure message" (§3.3) — delivered unicast
        for h in sim.leaders[app]:
            if h == leader:
                continue
            fl = Packet(kind=PacketKind.FAIL, dest=h,
                        id=make_id(app, block, newgen),
                        counter=1 if fallback else 0,
                        size_bytes=cfg.header_bytes + 16, src=leader)
            self.hosts[leader].queue.append(fl)
        self.schedule_pump(leader, sim.now)

    def host_handle_fail(self, host: int, pkt: Packet) -> None:
        sim = self.sim
        cfg = sim.cfg
        app, block, gen = id_app(pkt.id), id_block(pkt.id), id_gen(pkt.id)
        hkey = (host, app, block)
        if self.host_gen.get(hkey, 0) >= gen:
            return
        flags = sim.have.get((app, host))
        if flags is not None and flags[block]:
            return
        self.host_gen[hkey] = gen
        sim.retransmissions += 1
        fallback = pkt.counter == 1 or app in sim.bypass_apps
        rp = Packet(kind=PacketKind.REDUCE, dest=sim.leader_of(app, block),
                    id=make_id(app, block, gen), counter=1,
                    hosts=len(sim.leaders[app]),
                    value=sim.contribution_of(app, block, host),
                    bypass=fallback, size_bytes=cfg.mtu_bytes, src=host)
        if sim.trace is not None:
            sim.trace.on_host_send(host, rp)
        self.hosts[host].queue.append(rp)
        sim.engine.push(sim.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                        (app, block, gen))
        self.schedule_pump(host, sim.now)

    def host_retx_check(self, host: int, app: int, block: int,
                        gen: int) -> None:
        sim = self.sim
        cfg = sim.cfg
        if sim.all_done():
            return
        flags = sim.have.get((app, host))
        if flags is None or flags[block]:
            return
        if self.host_gen.get((host, app, block), 0) > gen:
            return  # a newer generation is already in flight
        sim.retransmissions += 1
        req = Packet(kind=PacketKind.RETX_REQ, dest=sim.leader_of(app, block),
                     id=make_id(app, block, gen),
                     size_bytes=cfg.header_bytes + 16, src=host)
        self.hosts[host].queue.append(req)
        sim.engine.push(sim.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                        (app, block, gen))
        self.schedule_pump(host, sim.now)


# --------------------------------------------------------------------------
# Host-based ring allreduce — same registry as the in-network strategies
# --------------------------------------------------------------------------
class _RingState:
    """Per-app ring-allreduce bookkeeping."""

    __slots__ = ("order", "rank", "p", "chunk_vals", "recv_count", "steps",
                 "pkts_per_chunk", "chunk_bytes", "done_steps")

    def __init__(self, order: List[int], data_bytes: int, payload: int) -> None:
        self.order = order
        self.rank = {h: r for r, h in enumerate(order)}
        self.p = len(order)
        self.chunk_bytes = max(1, -(-data_bytes // self.p))
        self.pkts_per_chunk = max(1, -(-self.chunk_bytes // payload))
        self.steps = 2 * self.p - 2
        self.chunk_vals: List[List[int]] = []
        self.recv_count: List[Dict[int, int]] = []
        self.done_steps: List[int] = []


@register_algorithm(Algo.RING)
class RingStrategy(AggregationStrategy):
    """Bandwidth-optimal host-based ring: reduce-scatter + all-gather.

    Switches only forward (base-class defaults); the whole protocol runs in
    :meth:`on_host_packet` + the per-step send enqueues."""

    def __init__(self, sim):
        super().__init__(sim)
        self.ring: Dict[int, _RingState] = {}

    def setup_job(self, app: int, job, parts: List[int]) -> None:
        sim = self.sim
        from .simulator import contribution
        rs = _RingState(parts, job.data_bytes, sim.cfg.payload_bytes)
        rs.chunk_vals = [
            [contribution(app, c, parts[r]) for c in range(rs.p)]
            for r in range(rs.p)
        ]
        rs.recv_count = [dict() for _ in range(rs.p)]
        rs.done_steps = [0] * rs.p
        self.ring[app] = rs
        for h in parts:
            self._enqueue_send(app, h, step=0)

    def next_host_packet(self, host: int) -> Optional[Packet]:
        return None  # ring sends are queue-driven, not cursor-driven

    def on_host_packet(self, host: int, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.RING:
            return False
        self._receive(host, pkt)
        return True

    # ---- protocol ----------------------------------------------------------
    def _enqueue_send(self, app: int, host: int, step: int) -> None:
        sim = self.sim
        rs = self.ring[app]
        r = rs.rank[host]
        if step > rs.steps - 1:
            return
        c = (r - step) % rs.p
        dest = rs.order[(r + 1) % rs.p]
        val = rs.chunk_vals[r][c]
        cfg = sim.cfg
        remaining = rs.chunk_bytes
        for i in range(rs.pkts_per_chunk):
            take = min(cfg.payload_bytes, remaining)
            remaining -= take
            pkt = Packet(kind=PacketKind.RING, dest=dest, id=app,
                         value=val if i == rs.pkts_per_chunk - 1 else 0,
                         size_bytes=take + cfg.header_bytes, src=host,
                         chunk=c, step=step)
            sim.hostproto.hosts[host].queue.append(pkt)
        sim.hostproto.schedule_pump(host, sim.now)

    def _receive(self, host: int, pkt: Packet) -> None:
        app = pkt.id
        rs = self.ring[app]
        r = rs.rank[host]
        counts = rs.recv_count[r]
        got = counts.get(pkt.step, 0) + 1
        counts[pkt.step] = got
        if pkt.value:
            if pkt.step < rs.p - 1:
                rs.chunk_vals[r][pkt.chunk] += pkt.value  # reduce-scatter phase
            else:
                rs.chunk_vals[r][pkt.chunk] = pkt.value   # all-gather phase
        if got < rs.pkts_per_chunk:
            return
        counts.pop(pkt.step, None)
        rs.done_steps[r] += 1
        if pkt.step + 1 <= rs.steps - 1:
            self._enqueue_send(app, host, pkt.step + 1)
        # steps can *complete* out of order when paths differ; the host is
        # finished only once every step's chunk has fully arrived.
        if rs.done_steps[r] == rs.steps:
            self._finish_host(app, host)

    def _finish_host(self, app: int, host: int) -> None:
        sim = self.sim
        rs = self.ring[app]
        r = rs.rank[host]
        ok = all(rs.chunk_vals[r][c] == sim.expected_total(app, c)
                 for c in range(rs.p))
        if not ok:
            sim.mismatches += 1
        flags = sim.have[(app, host)]
        newly = 0
        for b in range(sim.blocks[app]):
            if not flags[b]:
                flags[b] = 1
                newly += 1
        sim.app_remaining[app] -= newly
        sim.completed_blocks += newly
        if sim.app_remaining[app] == 0:
            sim.job_finished(app)
