"""Packet-level discrete-event simulator facade for in-network allreduce (§5.2).

The simulator is layered (see ``ARCHITECTURE.md``); this module only wires
the layers together and exposes the stable public API:

* :mod:`~.engine`    — event heap, clock, dispatch.
* :mod:`~.topology`  — link fabric + routing (``fat_tree``/``three_tier``/...).
* :mod:`~.switch`    — switch dataplane + the algorithm-strategy registry
                       (``CANARY``, ``STATIC_TREE``; ``RING`` registers from
                       :mod:`~.hostproto`).
* :mod:`~.hostproto` — host send pump, leader role, loss recovery.
* :mod:`~.workloads` — background congestion + sender-noise models.

Every packet carries an exact integer payload; at the end of a run the
simulator asserts that every participant received the true sum for every
block, under any combination of congestion, stragglers, collisions, drops and
switch failures. A run is therefore both a performance measurement and an
end-to-end correctness proof of the protocol implementation.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Set, Tuple

from . import network as _network  # noqa: F401  (registers "fat_tree")
from .engine import (EV_ARRIVE_HOST, EV_ARRIVE_SWITCH, EV_FAIL_SWITCH,
                     EV_JOB_ARRIVE, EV_LEADER_DONE, EV_PUMP, EV_RETX,
                     EV_TIMER, EventLoop)
from .hostproto import HostProtocol
from .switch import SwitchLayer, make_strategy
from .topology import make_topology
from .types import Algo, AllreduceJob, Packet, SimConfig, SimResult
from .workloads import CongestionWorkload

_CONTRIB_MULT = 1000003


def contribution(app: int, block: int, host: int) -> int:
    """Deterministic integer contribution of ``host`` to ``(app, block)``."""
    return (host + 1) * _CONTRIB_MULT + 31 * block + 7919 * app


class Simulator:
    """One simulation run. Construct, then call :meth:`run` once."""

    def __init__(self, cfg: SimConfig, jobs: List[AllreduceJob],
                 algo: Algo = Algo.CANARY, n_trees: int = 1,
                 noise_hosts: Optional[List[int]] = None,
                 admission=None):
        cfg.validate()
        self.cfg = cfg
        self.jobs = {j.app: j for j in jobs}
        try:
            self.algo = Algo(algo)
        except ValueError:
            self.algo = str(algo)  # strategy registered under a custom key
        self.n_trees = n_trees
        self.net = make_topology(cfg)
        self.rng = random.Random(cfg.seed)
        self.engine = EventLoop()

        # opt-in aggregation-provenance recording (repro.core.trace). The
        # recorder is observation-only: every layer guards its hook calls
        # with ``sim.trace is not None`` and the hooks touch no protocol
        # state, so traced runs replay the goldens bit-for-bit.
        self.trace = None
        if cfg.trace:
            # vendored frozen copy: tracing needs the live repro package
            raise RuntimeError("baseline_core does not support trace=True")

        # layers (construction order matters: strategies touch hostproto)
        self.switch = SwitchLayer(self, self.net.num_switches)
        self.hostproto = HostProtocol(self, cfg.num_hosts)
        self.workload = CongestionWorkload(self, noise_hosts)
        self.strategy = make_strategy(self.algo, self)

        # multi-tenant fleet state (repro.core.fleet). With no admission
        # controller everything below stays empty and the dataplane behaves
        # exactly as before — the fleet layer is pay-for-what-you-use.
        self.admission = admission
        self.tenant_of: Dict[int, int] = {}            # app -> tenant
        self.slot_regions: Dict[int, Tuple[int, int]] = {}  # app -> (offset, size)
        self.bypass_apps: Set[int] = set()             # degraded: host-based §3.3 path
        self.job_submit_ns: Dict[int, float] = {}
        self.job_start_ns: Dict[int, float] = {}
        self.app_fallback_blocks: Dict[int, int] = {}
        if admission is not None:
            admission.attach(self)

        # completion tracking
        self.have: Dict[Tuple[int, int], bytearray] = {}
        self.app_remaining: Dict[int, int] = {}
        self.app_done_ns: Dict[int, float] = {}
        self.mismatches = 0

        # counters (mutated by the layers)
        self.stragglers = 0
        self.collisions = 0
        self.restorations = 0
        self.retransmissions = 0
        self.fallbacks = 0
        self.dropped = 0
        self.completed_blocks = 0

        # per-job precomputation
        self.blocks: Dict[int, int] = {}
        self.leaders: Dict[int, List[int]] = {}
        self.partset: Dict[int, Set[int]] = {}
        self.contrib_sum_base: Dict[int, Tuple[int, int]] = {}
        self._setup_jobs()

    # ------------------------------------------------------------------ setup
    def _setup_jobs(self) -> None:
        cfg = self.cfg
        for app, job in self.jobs.items():
            parts = sorted(job.participants)
            if len(set(parts)) != len(parts):
                raise ValueError(f"duplicate participants in app {app}")
            B = job.num_blocks(cfg.payload_bytes)
            self.blocks[app] = B
            self.partset[app] = set(parts)
            self.leaders[app] = parts
            self.tenant_of[app] = job.tenant if job.tenant >= 0 else app
            s1 = sum(h + 1 for h in parts)
            self.contrib_sum_base[app] = (s1, len(parts))
            self.job_submit_ns[app] = max(0.0, job.arrival_ns)
            # completion tracking is registered up front for every job —
            # including ones that arrive later — so ``all_done`` keeps the
            # engine running until open-loop arrivals have completed too.
            if job.collective == "reduce":
                root = job.root if job.root is not None else parts[0]
                self.have[(app, root)] = bytearray(B)
                self.app_remaining[app] = B
            else:
                for h in parts:
                    self.have[(app, h)] = bytearray(B)
                self.app_remaining[app] = len(parts) * B
            if job.arrival_ns > 0.0:
                self.engine.push(job.arrival_ns, EV_JOB_ARRIVE, app, 0, None)
            else:
                self._activate_job(app)
        self.workload.start()
        if cfg.switch_fail_ns is not None and cfg.failed_switch is not None:
            self.engine.push(cfg.switch_fail_ns, EV_FAIL_SWITCH,
                             cfg.failed_switch, 0, None)

    def _activate_job(self, app: int) -> None:
        """Start ``app``'s protocol: at construction (t=0 jobs), when its
        ``EV_JOB_ARRIVE`` fires, or when the admission controller retries a
        deferred job after capacity frees up."""
        job = self.jobs[app]
        parts = self.leaders[app]
        B = self.blocks[app]
        if len(parts) == 1:
            # degenerate single-participant collective: already reduced
            h = parts[0]
            flags = self.have[(app, h)]
            for b in range(B):
                flags[b] = 1
            self.app_remaining[app] = 0
            self.completed_blocks += B
            self.job_start_ns[app] = self.now
            self.app_done_ns[app] = self.now
            return
        if self.admission is not None:
            decision = self.admission.on_job_arrival(self, app, job)
            if decision == "defer":
                return  # retried via on_job_done when a slot frees up
            if decision == "degrade":
                # quota exhausted: the whole job rides the §3.3 host-based
                # path (bypass packets, leader unicasts the result)
                self.bypass_apps.add(app)
                self.app_fallback_blocks[app] = B
        self.job_start_ns[app] = self.now
        self.strategy.setup_job(app, job, parts)

    def job_finished(self, app: int) -> None:
        """All of ``app``'s blocks completed: stamp the finish time and give
        the admission controller its quota slots back."""
        self.app_done_ns[app] = self.now
        if self.admission is not None:
            self.admission.on_job_done(self, app)

    # ------------------------------------------------------------- protocol
    def expected_total(self, app: int, block: int) -> int:
        c = self.jobs[app].collective
        if c == "barrier":
            return 0
        if c == "broadcast":
            return contribution(app, block, self.leader_of(app, block))
        s1, p = self.contrib_sum_base[app]
        return _CONTRIB_MULT * s1 + p * (31 * block + 7919 * app)

    def leader_of(self, app: int, block: int) -> int:
        job = self.jobs[app]
        if job.collective in ("reduce", "broadcast"):
            return job.root if job.root is not None else self.leaders[app][0]
        parts = self.leaders[app]
        return parts[block % len(parts)]

    def contribution_of(self, app: int, block: int, host: int) -> int:
        c = self.jobs[app].collective
        if c == "barrier":
            return 0
        if c == "broadcast":
            root = self.leader_of(app, block)
            return contribution(app, block, root) if host == root else 0
        return contribution(app, block, host)

    # ----------------------------------------------- hooks used by the layers
    @property
    def now(self) -> float:
        return self.engine.now

    @property
    def events(self) -> int:
        return self.engine.events

    @property
    def tables(self):
        """Per-switch descriptor tables (compat accessor; state lives in the
        switch layer)."""
        return self.switch.tables

    def maybe_drop(self) -> bool:
        return self.cfg.drop_prob > 0.0 and self.rng.random() < self.cfg.drop_prob

    def arrive_switch(self, t: float, sw: int, port: int, pkt: Packet) -> None:
        self.engine.push(t, EV_ARRIVE_SWITCH, sw, port, pkt)

    def arrive_host(self, t: float, host: int, pkt: Packet) -> None:
        self.engine.push(t, EV_ARRIVE_HOST, host, 0, pkt)

    def all_done(self) -> bool:
        return all(v == 0 for v in self.app_remaining.values())

    # -------------------------------------------------------------------- run
    def _handle_pump(self, a: int, b: int, c: object) -> None:
        self.hostproto.hosts[a].pump_scheduled = False
        self.hostproto.pump(a)

    def _handle_retx(self, a: int, b: int, c: object) -> None:
        app, block, gen = c
        self.hostproto.host_retx_check(a, app, block, gen)

    def _handle_leader_done(self, a: int, b: int, c: object) -> None:
        app, block, total = c
        self.hostproto.leader_block_done(a, app, block, total)

    def run(self) -> SimResult:
        cfg = self.cfg
        handlers = {
            EV_ARRIVE_SWITCH: self.switch.arrive,
            EV_ARRIVE_HOST: lambda a, b, c: self.hostproto.arrive(a, c),
            EV_PUMP: self._handle_pump,
            EV_TIMER: self.switch.on_timer,
            EV_RETX: self._handle_retx,
            EV_FAIL_SWITCH: lambda a, b, c: self.switch.fail_switch(a),
            EV_LEADER_DONE: self._handle_leader_done,
            EV_JOB_ARRIVE: lambda a, b, c: self._activate_job(a),
        }
        self.engine.run(handlers, self.all_done, cfg.max_events)
        end = max(self.app_done_ns.values()) if self.app_done_ns else self.now
        utils = self.net.utilizations(end if end > 0 else 1.0)
        goodput = {}
        for app, job in self.jobs.items():
            # JCT, not absolute finish: identical for t=0 jobs, and the only
            # meaningful denominator for open-loop (late-arriving) jobs
            dur = self.app_done_ns.get(app, self.now) - self.job_submit_ns[app]
            goodput[app] = (job.data_bytes * 8.0) / dur if dur > 0 else 0.0
        maxdesc = max(self.switch.desc_high) if self.switch.desc_high else 0
        return SimResult(
            duration_ns=end,
            start_ns=0.0,
            goodput_gbps=goodput,
            correct=(self.mismatches == 0 and self.all_done()),
            link_utilization=utils,
            avg_utilization=sum(utils) / len(utils) if utils else 0.0,
            stragglers=self.stragglers,
            collisions=self.collisions,
            restorations=self.restorations,
            retransmissions=self.retransmissions,
            fallbacks=self.fallbacks,
            max_descriptors_per_switch=maxdesc,
            max_descriptor_bytes=maxdesc * cfg.mtu_bytes,
            events=self.events,
            dropped_packets=self.dropped,
            completed_blocks=self.completed_blocks,
            job_submit_ns=dict(self.job_submit_ns),
            job_start_ns=dict(self.job_start_ns),
            job_finish_ns=dict(self.app_done_ns),
            job_admitted={a: a not in self.bypass_apps for a in self.jobs},
            app_fallback_blocks=dict(self.app_fallback_blocks),
            tenant_of=dict(self.tenant_of),
        )
