"""Workload layer: background congestion traffic and sender-side noise.

The paper's evaluation (§5.2) surrounds the allreduce with two disturbance
models, both of which live here rather than in the host protocol:

* **Random-uniform congestion** — every non-participant "noise host" streams
  ``noise_msg_bytes``-sized messages to uniformly re-drawn noise-host peers.
  The background jobs and the allreduce are distinct applications: noise
  flows target noise hosts, sharing the fabric (leaf/spine links) with the
  allreduce but not the participants' NICs.
* **Sender OS noise (§5.2.5)** — with probability ``noise_prob`` a host's
  next send is delayed by ``noise_delay_ns``, emulating jittery sender
  stacks.

Both consume the simulator's single RNG stream, so runs stay reproducible.
"""
from __future__ import annotations

from typing import List, Optional

from .types import Packet, PacketKind


class CongestionWorkload:
    """Background-traffic generation + sender-noise decisions."""

    def __init__(self, sim, noise_hosts: Optional[List[int]]):
        self.sim = sim
        self.noise_hosts = list(noise_hosts or [])
        self._noise_set = set(self.noise_hosts)

    def start(self) -> None:
        """Kick every noise host's pump at t=0 (after job setup)."""
        for h in self.noise_hosts:
            self.sim.hostproto.schedule_pump(h, 0.0)

    def next_noise_packet(self, host: int, hs) -> Optional[Packet]:
        """The next background-traffic packet for ``host`` (None when the
        host is not a noise host). ``hs`` is the host's ``_HostState``, which
        carries the current message's peer/remaining-bytes cursor."""
        if host not in self._noise_set:
            return None
        if len(self.noise_hosts) < 2:
            return None  # a lone noise host has no peer to stream to
        sim = self.sim
        cfg = sim.cfg
        if hs.noise_remaining <= 0:
            # random-uniform pattern *among the congestion hosts* (§5.2)
            peer = self.noise_hosts[sim.rng.randrange(len(self.noise_hosts))]
            while peer == host:
                peer = self.noise_hosts[
                    sim.rng.randrange(len(self.noise_hosts))]
            hs.noise_peer = peer
            hs.noise_remaining = cfg.noise_msg_bytes
            hs.noise_msg_idx += 1
        take = min(cfg.payload_bytes, hs.noise_remaining)
        hs.noise_remaining -= take
        return Packet(kind=PacketKind.NOISE, dest=hs.noise_peer, id=0,
                      size_bytes=take + cfg.header_bytes, src=host,
                      chunk=hs.noise_msg_idx)

    def sender_delay_ns(self, host: int) -> Optional[float]:
        """§5.2.5 sender-side OS noise: delay the pending send or not."""
        cfg = self.sim.cfg
        if cfg.noise_prob > 0.0 and self.sim.rng.random() < cfg.noise_prob:
            return cfg.noise_delay_ns
        return None
