"""FROZEN pre-optimization copy of the simulator hot path — DO NOT EDIT.

This package vendors the discrete-event core exactly as it stood before the
hot-path overhaul PR (engine / types / topology / network / switch /
hostproto / workloads / simulator, all-relative imports, no external deps).
``benchmarks/perf.py`` runs it back-to-back with the live engine in the same
process, so the reported speedup is a like-for-like ratio that is robust to
machine noise — the acceptance contract ("events/sec vs the pre-PR engine")
stays verifiable on any hardware, forever.

The only permitted change to these files is the surgical removal of imports
that would drag in the rest of the repo; behaviour must stay bit-identical
to the PR-4 tree (the golden replays pin both engines to the same results).
"""
from .simulator import Simulator  # noqa: F401
from .types import Algo, AllreduceJob, SimConfig, scaled_config, three_tier_config  # noqa: F401
