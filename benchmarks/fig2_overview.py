"""Fig. 2: goodput of ring vs static in-network vs Canary at 1% and 75% of
hosts, with and without background congestion."""
from __future__ import annotations

from repro.core.canary import Algo, run_allreduce

from .common import bench_cfg, bench_hosts, bench_size, emit, timed


def main(reps: int = 1) -> None:
    cfg = bench_cfg()
    size = bench_size()
    for frac in (0.01, 0.75):
        n = bench_hosts(frac)
        for cong in (False, True):
            for algo, nt, label in ((Algo.RING, 1, "ring"),
                                    (Algo.STATIC_TREE, 1, "static1"),
                                    (Algo.CANARY, 1, "canary")):
                r, us = timed(run_allreduce, cfg, algo, n, size, n_trees=nt,
                              congestion=cong, reps=reps)
                emit(f"fig2/{label}/hosts{frac:.0%}/cong={int(cong)}", us,
                     f"goodput_gbps={r.goodput_gbps_mean:.1f};"
                     f"correct={r.correct}")


if __name__ == "__main__":
    main()
