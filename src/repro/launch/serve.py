"""Serving launcher: batched generation with a reduced config on CPU, or the
production mesh on TPU.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.models import get_config
from repro.serving import Engine, ServeConfig


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--sliding-window", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, args.variant)
    if args.sliding_window:
        cfg = cfg.long_context_variant(args.sliding_window)
    engine = Engine(ServeConfig(model=cfg, batch=args.batch,
                                max_len=args.max_len))
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = 0.02 * jnp.ones((args.batch, cfg.encoder_seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    tokens, stats = engine.generate(prompts, args.new_tokens, frames=frames)
    print(f"generated {tokens.shape} tokens")
    print(f"prefill {stats['prefill_s']*1e3:.0f}ms  "
          f"decode {stats['decode_tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
