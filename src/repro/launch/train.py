"""Training launcher.

CPU-scale entry point (examples/tests) and the mesh-configured production
path. ``--arch <id> --variant smoke`` trains a reduced config for a few
hundred steps on synthetic data; on a real TPU slice the same module drives
the production mesh with ``--mesh single|multi``.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --variant smoke --steps 100 --grad-sync canary --data-parallel 1
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import jax

from repro.data import DataConfig
from repro.models import get_config
from repro.optim import AdamWConfig, cosine_with_warmup
from repro.parallel.context import ParallelContext, parallel_context
from repro.train import TrainConfig, Trainer, TrainerConfig


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-sync", default="auto",
                    choices=["auto", "canary", "canary_fp", "ring",
                             "hierarchical"])
    ap.add_argument("--canary-blocks", type=int, default=16)
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--replan-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, args.variant)
    dp = args.data_parallel or max(1, len(jax.devices())
                                   // args.model_parallel)
    mesh = jax.make_mesh((dp, args.model_parallel), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sched = cosine_with_warmup(args.lr, warmup_steps=max(1, args.steps // 20),
                               total_steps=args.steps)
    tc = TrainConfig(model=cfg,
                     optimizer=AdamWConfig(lr=args.lr, schedule=sched),
                     grad_sync=args.grad_sync,
                     canary_blocks=args.canary_blocks)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch,
                      seq_len=args.seq)
    trainer_cfg = TrainerConfig(train=tc, data=data, steps=args.steps,
                                log_every=args.log_every,
                                checkpoint_dir=args.checkpoint_dir,
                                checkpoint_every=args.checkpoint_every,
                                replan_every=args.replan_every)
    ctx = ParallelContext(mesh=mesh, data_axes=("data",), model_axis="model")
    with parallel_context(ctx):
        trainer = Trainer(trainer_cfg, mesh=mesh)
        history = trainer.run()
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({args.grad_sync})")
    if args.history_out:
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
