"""Production meshes.

Target: TPU v5e pods — 256 chips/pod in a (16, 16) mesh; the multi-pod
configuration stacks 2 pods into (2, 16, 16) over ("pod", "data", "model").
``pod`` and ``data`` both carry batch parallelism (and FSDP), ``model``
carries tensor/expert/sequence parallelism.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """(data axes, model axis) for a production-shaped mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


# Hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
