import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) pair this lowers + compiles the real
train/serve step on the production meshes — 16x16 single-pod and 2x16x16
multi-pod — using ShapeDtypeStruct stand-ins (no allocation), then extracts:

* ``compiled.memory_analysis()``  — per-device bytes (proves it fits),
* ``compiled.cost_analysis()``    — per-device FLOPs / bytes accessed,
* collective bytes parsed from the optimized HLO (all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute result sizes),

and derives the three §Roofline terms. Results land in
``experiments/dryrun/<arch>__<shape>__<mesh>[__<gradsync>].json``.

NOTE: the XLA_FLAGS line above must execute before any other jax import in
the process; run this module as the entry point
(``python -m repro.launch.dryrun``), never import it from a process that
already initialized jax with a different device count.
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, mesh_axes,
                               make_production_mesh)
from repro.models import get_config, init_cache, init_params, list_archs
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.optim import init as adamw_init
from repro.parallel.context import ParallelContext, parallel_context
from repro.parallel.sharding import batch_spec, cache_specs, param_specs
from repro.serving import make_serve_step
from repro.train import TrainConfig, make_train_step

from repro.launch.analysis import (INPUT_SHAPES, _COLLECTIVES,
                                   _DTYPE_BYTES,
                                   model_flops_per_step,
                                   parse_collective_bytes)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes_tree,
        specs_tree)


def build_dryrun(arch: str, shape_name: str, mesh, grad_sync: str = "auto",
                 cfg_override: Optional[ModelConfig] = None,
                 microbatches: int = 1, moe_impl: str = ""
                 ) -> Tuple[Any, Tuple, ModelConfig]:
    """Returns (fn, example_args_sds, cfg) ready for jit().lower()."""
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    seq, gb = spec["seq_len"], spec["global_batch"]
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if moe_impl:
        cfg = cfg.with_(moe_impl=moe_impl)
    dp_axes, model_axis = mesh_axes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    if kind == "decode" and shape_name == "long_500k":
        if not cfg.supports_long_decode():
            raise ValueError(f"{arch} skips long_500k (see DESIGN.md §5)")
        cfg = cfg.long_context_variant(window=8192)

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(partial(init_params, cfg), key)
    # explicit grad sync reduces over data axes itself -> params replicated
    use_fsdp = grad_sync == "auto"
    p_specs = param_specs(params_shapes, mesh, fsdp=dp, model=model_axis,
                          use_fsdp=use_fsdp)
    params_sds = _tree_sds(params_shapes, p_specs, mesh)

    if kind == "train":
        oc = AdamWConfig(state_dtype="bfloat16"
                         if cfg.param_count() > 1e11 else "float32")
        tc = TrainConfig(model=cfg, optimizer=oc, grad_sync=grad_sync,
                         microbatches=microbatches)
        step = make_train_step(tc, mesh=mesh, dp_axes=dp_axes,
                               model_axis=model_axis)
        opt_shapes = jax.eval_shape(lambda p: adamw_init(p, oc),
                                    params_shapes)
        from repro.optim import AdamWState
        opt_sds = AdamWState(
            step=_sds((), jnp.int32, mesh, P()),
            m=_tree_sds(opt_shapes.m, p_specs, mesh),
            v=_tree_sds(opt_shapes.v, p_specs, mesh))
        bspec = batch_spec(mesh, gb, dp)
        text_seq = seq - (cfg.num_patches if cfg.frontend == "vision_stub"
                          else 0)
        batch = {
            "tokens": _sds((gb, text_seq), jnp.int32, mesh, bspec),
            "labels": _sds((gb, text_seq), jnp.int32, mesh, bspec),
        }
        dt = jnp.dtype(cfg.dtype)
        if cfg.frontend == "audio_stub":
            batch["frames"] = _sds((gb, cfg.encoder_seq, cfg.d_model), dt,
                                   mesh, bspec)
        if cfg.frontend == "vision_stub":
            batch["patches"] = _sds((gb, cfg.num_patches, cfg.d_model), dt,
                                    mesh, bspec)
        return step, (params_sds, opt_sds, batch), cfg

    if kind == "prefill":
        from repro.models import forward

        def prefill_fn(params, batch):
            kw = {}
            if "frames" in batch:
                kw["frames"] = batch["frames"]
            if "patches" in batch:
                kw["extra_embeds"] = batch["patches"]
            logits, _ = forward(params, batch["tokens"], cfg, **kw)
            return jax.lax.with_sharding_constraint(
                logits, NamedSharding(mesh, P(dp, None, model_axis)))

        bspec = batch_spec(mesh, gb, dp)
        text_seq = seq - (cfg.num_patches if cfg.frontend == "vision_stub"
                          else 0)
        batch = {"tokens": _sds((gb, text_seq), jnp.int32, mesh, bspec)}
        dt = jnp.dtype(cfg.dtype)
        if cfg.frontend == "audio_stub":
            batch["frames"] = _sds((gb, cfg.encoder_seq, cfg.d_model), dt,
                                   mesh, bspec)
        if cfg.frontend == "vision_stub":
            batch["patches"] = _sds((gb, cfg.num_patches, cfg.d_model), dt,
                                    mesh, bspec)
        return prefill_fn, (params_sds, batch), cfg

    # decode
    serve = make_serve_step(cfg)
    cache_shapes = jax.eval_shape(partial(init_cache, cfg, gb, seq), )
    c_specs = cache_specs(cache_shapes, mesh, dp_axes=dp, model=model_axis)
    cache_sds = _tree_sds(cache_shapes, c_specs, mesh)
    bspec = batch_spec(mesh, gb, dp)
    tokens = _sds((gb, 1), jnp.int32, mesh, bspec)
    return serve, (params_sds, cache_sds, tokens), cfg


def _probe_costs(arch: str, shape_name: str, mesh, grad_sync: str,
                 n_periods: int, microbatches: int = 1,
                 moe_impl: str = "") -> Dict[str, float]:
    """Lower an UNROLLED shallow clone (n_periods repeat periods) and return
    its per-device costs. XLA's HloCostAnalysis counts a ``while`` body once
    regardless of trip count, so scanned-stack costs must be extrapolated
    from two unrolled probes (see extrapolated_costs)."""
    import repro.models.registry as registry
    from repro.models.transformer import layer_period
    cfg_full = get_config(arch)
    per = layer_period(cfg_full)
    overrides = dict(num_layers=per * n_periods, scan_layers=False,
                     remat=False)
    if cfg_full.is_encoder_decoder:
        overrides["encoder_layers"] = n_periods
    probe_cfg = cfg_full.with_(**overrides)
    orig_get = registry.get_config
    try:
        registry.get_config = lambda n, v="full": probe_cfg \
            if n == arch else orig_get(n, v)
        # rebuild through the same path so shardings/steps are identical
        fn, args, _ = build_dryrun(arch, shape_name, mesh,
                                   grad_sync=grad_sync, cfg_override=probe_cfg,
                                   microbatches=microbatches,
                                   moe_impl=moe_impl)
    finally:
        registry.get_config = orig_get
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "link_bytes": coll["total_link_bytes"],
    }


def extrapolated_costs(arch: str, shape_name: str, mesh, grad_sync: str,
                       n_periods_full: int, microbatches: int = 1,
                       moe_impl: str = "") -> Dict[str, float]:
    """cost(L periods) = fixed + L * per_period  =>  probe at 1 and 2."""
    c1 = _probe_costs(arch, shape_name, mesh, grad_sync, 1, microbatches,
                      moe_impl)
    c2 = _probe_costs(arch, shape_name, mesh, grad_sync, 2, microbatches,
                      moe_impl)
    out = {}
    for k in c1:
        delta = max(0.0, c2[k] - c1[k])
        fixed = max(0.0, c1[k] - delta)
        out[k] = fixed + n_periods_full * delta
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            grad_sync: str = "auto", out_dir: str = "experiments/dryrun",
            save_hlo: bool = False, seq_parallel: bool = False,
            microbatches: int = 1, tag: str = "",
            moe_impl: str = "") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes, model_axis = mesh_axes(mesh)
    ctx = ParallelContext(mesh=mesh, data_axes=dp_axes, model_axis=model_axis,
                          sequence_parallel=seq_parallel)
    t0 = time.time()
    with parallel_context(ctx):
        fn, args, cfg = build_dryrun(arch, shape_name, mesh,
                                     grad_sync=grad_sync,
                                     microbatches=microbatches,
                                     moe_impl=moe_impl)
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    chips = mesh.devices.size
    spec = INPUT_SHAPES[shape_name]
    from repro.models.transformer import layer_period
    n_per = cfg.num_layers // layer_period(cfg)
    with parallel_context(ctx):
        extr = extrapolated_costs(arch, shape_name, mesh, grad_sync, n_per,
                                  microbatches, moe_impl)
    # the microbatch accumulation loop is also a scan whose body XLA counts
    # once; each iteration does ~1/k of the step's work
    mb_scale = microbatches if spec["kind"] == "train" else 1
    flops_dev = extr["flops"] * mb_scale
    bytes_dev = extr["bytes"] * mb_scale
    coll_bytes_extr = extr["link_bytes"] * mb_scale
    mf = model_flops_per_step(cfg, spec["kind"], spec["seq_len"],
                              spec["global_batch"])
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_bytes_extr / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(chips), "grad_sync": grad_sync,
        "seq_parallel": seq_parallel, "microbatches": microbatches,
        "compile_s": round(t_compile, 1),
        "model_variant": cfg.name,
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_link_bytes": coll_bytes_extr,
            "collectives_scanned_body": coll["per_op_bytes"],
            "collective_counts_scanned_body": coll["per_op_count"],
            "raw_scanned_flops": float(ca.get("flops", 0.0)),
        },
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "total_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_device": mf / chips,
            "useful_flops_ratio": (mf / chips) / flops_dev
            if flops_dev else 0.0,
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{grad_sync}" if grad_sync != "auto" else ""
    if tag:
        suffix += f"__{tag}"
    fname = f"{arch.replace('/', '_')}__{shape_name}__" \
            f"{result['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, fname.replace(".json", ".hlo")),
                  "w") as f:
            f.write(hlo)
    return result


def should_skip(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long_decode():
        return "enc-dec full attention — documented skip (DESIGN.md §5)"
    return None


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--grad-sync", default="auto")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-impl", default="")
    args = ap.parse_args()
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            skip = should_skip(arch, shape)
            if skip:
                print(f"SKIP  {arch:18s} {shape:12s}: {skip}", flush=True)
                continue
            for mp in meshes:
                tag = f"{arch:18s} {shape:12s} {'2x16x16' if mp else '16x16 '}"
                try:
                    r = run_one(arch, shape, mp, grad_sync=args.grad_sync,
                                out_dir=args.out, save_hlo=args.save_hlo,
                                seq_parallel=args.seq_parallel,
                                microbatches=args.microbatches, tag=args.tag,
                                moe_impl=args.moe_impl)
                    roof = r["roofline"]
                    print(f"OK    {tag} compile={r['compile_s']:6.1f}s "
                          f"mem/dev={r['memory']['total_bytes']/2**30:6.2f}GiB "
                          f"dom={roof['dominant']:12s} "
                          f"useful={roof['useful_flops_ratio']:.2f}",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAIL  {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall dry-runs compiled.")


if __name__ == "__main__":
    main()
