"""Launchers: mesh construction, multi-pod dry-run, train/serve CLIs.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — only run it as the
process entry point (``python -m repro.launch.dryrun``); do not import it
here or from library code.
"""
from ..compat import patch_jax as _patch_jax

_patch_jax()

from .mesh import make_host_mesh, make_production_mesh, mesh_axes

__all__ = ["make_host_mesh", "make_production_mesh", "mesh_axes"]
