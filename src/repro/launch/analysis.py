"""Side-effect-free dry-run analysis helpers (importable anywhere —
no XLA_FLAGS mutation; see repro.launch.dryrun for the driver)."""
from __future__ import annotations

import re
import warnings
from typing import Dict

from repro.models.config import ModelConfig

INPUT_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# dtypes we have already warned about (warn once per process, not per line)
_WARNED_DTYPES: set = set()


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    all-reduce moves ~2x its payload per device (reduce + broadcast phases /
    ring equivalents); the others move ~1x their result. The returned
    ``total_link_bytes`` applies those multipliers — the §Roofline collective
    term divides it by the per-link bandwidth.

    A dtype missing from ``_DTYPE_BYTES`` is assumed 4 bytes wide; rather
    than doing that silently, every occurrence is tallied in the returned
    ``unknown_dtypes`` field (dtype -> op count) and a ``RuntimeWarning`` is
    emitted once per dtype per process, so a new XLA dtype cannot skew the
    roofline unnoticed.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    unknown: Dict[str, int] = {}
    # e.g.:  %all-reduce.1 = bf16[1024,512]{1,0} all-reduce(...)
    shape_re = re.compile(
        r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+([a-z\-]+)")
    for line in hlo_text.splitlines():
        hit = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                hit = c
                break
        if hit is None:
            continue
        m = shape_re.search(line)
        if not m:
            continue
        dtype, dims, _ = m.groups()
        size = _DTYPE_BYTES.get(dtype)
        if size is None:
            size = 4
            unknown[dtype] = unknown.get(dtype, 0) + 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        out[hit] += size
        count[hit] += 1
    for dtype in unknown:
        if dtype not in _WARNED_DTYPES:
            _WARNED_DTYPES.add(dtype)
            warnings.warn(
                f"parse_collective_bytes: unknown HLO dtype {dtype!r} — "
                "assuming 4 bytes/element; add it to _DTYPE_BYTES",
                RuntimeWarning, stacklevel=2)
    total = sum(v * (2.0 if k == "all-reduce" else 1.0)
                for k, v in out.items())
    return {"per_op_bytes": out, "per_op_count": count,
            "total_link_bytes": total, "unknown_dtypes": unknown}


def model_flops_per_step(cfg: ModelConfig, kind: str, seq: int,
                         global_batch: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active
    params; decode processes D = batch tokens per step."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = global_batch * seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = global_batch * seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * global_batch  # decode: one token per sequence
