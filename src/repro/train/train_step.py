"""Training step factory with pluggable gradient synchronization.

``grad_sync`` strategies:

* ``auto``          — GSPMD inserts the gradient collectives implied by the
                      param shardings (FSDP: reduce-scatter; replicated:
                      all-reduce). The performance baseline.
* ``canary``        — the paper's technique: per-data-shard gradients are
                      reduced explicitly with blockwise multi-root dynamic
                      trees (``canary_allreduce_tree``) inside a
                      partial-auto ``shard_map`` (manual over the data axes,
                      the model axis stays GSPMD-automatic).
* ``ring``          — explicit bandwidth-optimal reduce-scatter/all-gather
                      (the paper's host-based baseline).
* ``hierarchical``  — pod-local reduce-scatter, cross-pod exchange,
                      pod-local all-gather (the in-switch aggregation
                      analogue; multi-pod meshes only).
* ``canary_fp``     — canary + fixed-point (int32) blocks: bit-reproducible
                      sums regardless of tree shape (paper §6 + beyond-paper
                      determinism).

Explicit grad-sync modes require params *replicated* over the data axes
(``use_fsdp=False``) since they perform the data-axis reduction themselves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.collective import canary_allreduce_tree
from repro.models import forward
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, AdamWState
from repro.optim import init as adamw_init
from repro.optim import update as adamw_update
from .losses import cross_entropy

EXPLICIT_MODES = ("canary", "ring", "hierarchical", "canary_fp")


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    optimizer: AdamWConfig = AdamWConfig()
    grad_sync: str = "auto"
    canary_blocks: int = 16
    canary_roots: Optional[Tuple[int, ...]] = None  # congestion-oracle plan
    z_loss: float = 0.0
    # gradient accumulation: split the global batch into k microbatches and
    # scan over them — activation memory scales with B/k (§Perf lever)
    microbatches: int = 1


def make_loss_fn(tc: TrainConfig, constrain: str = "full") -> Callable:
    """``constrain``: 'full' (batch->data, vocab->model), 'model' (vocab only
    — safe inside a data-manual shard_map), or 'none'."""
    cfg = tc.model

    def loss_fn(params, batch):
        from jax.sharding import NamedSharding
        from repro.parallel.context import get_parallel_context
        ctx = get_parallel_context()
        kwargs = {}
        if "frames" in batch:
            kwargs["frames"] = batch["frames"]
        if "patches" in batch:
            kwargs["extra_embeds"] = batch["patches"]
        logits, aux = forward(params, batch["tokens"], cfg, **kwargs)
        if ctx is not None and constrain != "none":
            # keep the (B, S, V) logits sharded: batch over the data axes,
            # vocab over the model axis — without this constraint GSPMD may
            # materialize replicated logits (tens of GiB at 4k x 256)
            spec = P(ctx.data_spec, None, ctx.model_axis) \
                if constrain == "full" else P(None, None, ctx.model_axis)
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(ctx.mesh, spec))
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:   # VLM prefix: score text only
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
        loss, metrics = cross_entropy(logits, labels, z_loss=tc.z_loss)
        total = loss + cfg.moe_aux_coef * aux
        metrics["aux_loss"] = aux
        return total, metrics

    return loss_fn


def make_train_step(tc: TrainConfig, mesh: Optional[Mesh] = None,
                    dp_axes: Tuple[str, ...] = ("data",),
                    model_axis: str = "model") -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). jit/lower is the caller's job (launcher / dryrun)."""
    loss_fn = make_loss_fn(tc, constrain="full" if tc.grad_sync == "auto"
                           else "none")

    if tc.grad_sync == "auto":
        def train_step(params, opt_state, batch):
            k = tc.microbatches
            if k <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda v: v.reshape((k, v.shape[0] // k) + v.shape[1:]),
                    batch)

                def mb_step(acc, one):
                    g_acc, m_acc = acc
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, one)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                    m_acc = jax.tree.map(lambda a, m: a + m / k, m_acc,
                                         metrics)
                    return (g_acc, m_acc), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
                m0 = {"loss": jnp.zeros((), jnp.float32),
                      "accuracy": jnp.zeros((), jnp.float32),
                      "aux_loss": jnp.zeros((), jnp.float32)}
                (grads, metrics), _ = jax.lax.scan(mb_step, (g0, m0), mb)
                grads = jax.tree.map(lambda g, p: (g / k).astype(p.dtype),
                                     grads, params)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 tc.optimizer)
            metrics.update(om)
            return params, opt_state, metrics
        return train_step

    if tc.grad_sync not in EXPLICIT_MODES:
        raise ValueError(f"unknown grad_sync {tc.grad_sync}")
    if mesh is None:
        raise ValueError("explicit grad_sync modes need a mesh")

    inner = dp_axes[-1]                   # tree axis (intra-pod)
    outer = dp_axes[0] if len(dp_axes) > 1 else None
    axis_size = mesh.shape[inner]
    mode = {"canary": "canary", "canary_fp": "canary", "ring": "ring",
            "hierarchical": "hierarchical"}[tc.grad_sync]
    fixed_point = tc.grad_sync == "canary_fp"
    roots = list(tc.canary_roots) if tc.canary_roots is not None else None

    def grads_fn(params, batch):
        """Per-data-shard gradients + explicit Canary reduction."""
        import dataclasses as _dc
        from repro.parallel.context import (get_parallel_context,
                                            parallel_context)
        ctx = get_parallel_context()
        if ctx is not None and ctx.constrain_activations:
            # data axes are manual inside this shard_map: activation
            # constraints must not mention them
            with parallel_context(_dc.replace(ctx, constrain_activations=False,
                                              allow_shardmap_layers=False)):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        synced = canary_allreduce_tree(
            grads, axis_name=inner, axis_size=axis_size, roots=roots,
            num_blocks=tc.canary_blocks, mode=mode, outer_axis=outer,
            fixed_point=fixed_point)
        # average over the data parallelism degree
        dp = axis_size * (mesh.shape[outer] if outer else 1)
        synced = jax.tree.map(lambda g: g / dp, synced)
        metrics = jax.tree.map(
            lambda m: jax.lax.pmean(jax.lax.pmean(m, inner), outer)
            if outer else jax.lax.pmean(m, inner), metrics)
        return synced, metrics

    batch_in_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])

    def train_step(params, opt_state, batch):
        sharded_grads = jax.shard_map(
            grads_fn,
            mesh=mesh,
            in_specs=(P(), jax.tree.map(lambda _: batch_in_spec, batch)),
            out_specs=(P(), P()),
            axis_names=set(dp_axes),
            check_vma=False,
        )(params, batch)
        grads, metrics = sharded_grads
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             tc.optimizer)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def init_train_state(tc: TrainConfig, key) -> Tuple[Any, AdamWState]:
    from repro.models import init_params
    params = init_params(tc.model, key)
    return params, adamw_init(params, tc.optimizer)
