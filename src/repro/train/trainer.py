"""Training loop: data pipeline + train_step + congestion-oracle feedback +
checkpointing. CPU-scale by design (the examples train ~10-100M-param reduced
configs); the same code jit-lowers for the production meshes via launch/.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collective import CongestionOracle
from repro.data import DataConfig, batch_at
from repro.optim import AdamWConfig
from .train_step import TrainConfig, init_train_state, make_train_step


@dataclass
class TrainerConfig:
    train: TrainConfig
    data: DataConfig
    steps: int = 50
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    replan_every: int = 0     # >0: re-plan canary roots from oracle feedback


class Trainer:
    def __init__(self, cfg: TrainerConfig, mesh=None, dp_axes=("data",),
                 model_axis="model", seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.model_axis = model_axis
        self.params, self.opt_state = init_train_state(
            cfg.train, jax.random.PRNGKey(seed))
        self.oracle: Optional[CongestionOracle] = None
        if cfg.train.grad_sync in ("canary", "canary_fp") and mesh is not None:
            self.oracle = CongestionOracle(
                axis_size=mesh.shape[dp_axes[-1]],
                num_blocks=cfg.train.canary_blocks)
        self._build_step()
        self.history: List[Dict[str, float]] = []

    def _build_step(self):
        tc = self.cfg.train
        if self.oracle is not None:
            tc = TrainConfig(model=tc.model, optimizer=tc.optimizer,
                             grad_sync=tc.grad_sync,
                             canary_blocks=tc.canary_blocks,
                             canary_roots=tuple(self.oracle.plan()),
                             z_loss=tc.z_loss)
        fn = make_train_step(tc, mesh=self.mesh, dp_axes=self.dp_axes,
                             model_axis=self.model_axis)
        self.step_fn = jax.jit(fn, donate_argnums=(0, 1))

    def _make_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        np_batch = batch_at(self.cfg.data, step)
        batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        mcfg = self.cfg.train.model
        B = self.cfg.data.global_batch
        if mcfg.frontend == "audio_stub":
            batch["frames"] = 0.02 * jnp.ones(
                (B, mcfg.encoder_seq, mcfg.d_model), jnp.dtype(mcfg.dtype))
        if mcfg.frontend == "vision_stub":
            batch["patches"] = 0.02 * jnp.ones(
                (B, mcfg.num_patches, mcfg.d_model), jnp.dtype(mcfg.dtype))
        return batch

    def run(self) -> List[Dict[str, float]]:
        cfg = self.cfg
        for step in range(cfg.steps):
            batch = self._make_batch(step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics["step"] = step
            metrics["step_time_s"] = dt
            self.history.append(metrics)
            if self.oracle is not None:
                self.oracle.feedback(dt)
                if cfg.replan_every and (step + 1) % cfg.replan_every == 0:
                    self._build_step()   # adopt the re-planned roots
            if cfg.log_every and step % cfg.log_every == 0:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"acc {metrics.get('accuracy', 0):.4f} {dt*1e3:.0f}ms")
            if cfg.checkpoint_dir and cfg.checkpoint_every and \
                    (step + 1) % cfg.checkpoint_every == 0:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(cfg.checkpoint_dir, step + 1, self.params,
                                self.opt_state)
        return self.history
