"""Training losses: cross-entropy (+ z-loss) and MoE auxiliary terms."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray = None, z_loss: float = 0.0
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """logits (B, S, V) float, labels (B, S) int32. Stable fp32 reduction."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = (nll * mask).sum() / denom
        acc = ((lg.argmax(-1) == labels) * mask).sum() / denom
    else:
        loss = nll.mean()
        acc = (lg.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "accuracy": acc.astype(jnp.float32)}
