from ..compat import patch_jax as _patch_jax

_patch_jax()

from .losses import cross_entropy
from .train_step import (TrainConfig, init_train_state, make_loss_fn,
                         make_train_step)
from .trainer import Trainer, TrainerConfig

__all__ = ["TrainConfig", "Trainer", "TrainerConfig", "cross_entropy",
           "init_train_state", "make_loss_fn", "make_train_step"]
