"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

Policy (MaxText-style FSDP + tensor parallelism):

* ``model`` axis carries tensor parallelism — attention heads, MLP hidden,
  MoE experts, Mamba inner channels, vocab.
* the data axes (``("pod", "data")`` or ``("data",)``) carry batch
  parallelism and FSDP sharding of params + optimizer state.
* every rule is divisibility-guarded: if the preferred dim does not divide
  evenly over the axis the rule falls through to the next candidate (e.g.
  qwen2-7b's 28 heads over a 16-way model axis fall back to sharding
  d_model over data x model), ending at full replication.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey


Axes = Union[str, Tuple[str, ...], None]


def _axes_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(mesh: Mesh, shape: Tuple[int, ...], spec: P) -> bool:
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            continue
        if dim % _axes_size(mesh, axes) != 0:
            return False
    return True


def _first_fit(mesh: Mesh, shape: Tuple[int, ...], options) -> P:
    for spec in options:
        if _fits(mesh, shape, spec):
            return spec
    return P()


def leaf_spec(name: str, shape: Tuple[int, ...], stacked: bool,
              mesh: Mesh, fsdp: Axes, model: str, use_fsdp: bool = True) -> P:
    """PartitionSpec for one named parameter leaf."""
    f = fsdp if use_fsdp else None
    logical = shape[1:] if stacked else shape

    def out(spec: P) -> P:
        return P(None, *spec) if stacked else spec

    nd = len(logical)
    if name == "tok":
        return out(_first_fit(mesh, logical,
                              [P(model, f), P(f, model), P(None, model), P()]))
    if name == "unembed":
        return out(_first_fit(mesh, logical,
                              [P(f, model), P(model, f), P(model, None), P()]))
    if name == "wq":
        return out(_first_fit(mesh, logical,
                              [P(f, model, None),
                               P((*_t(f), model), None, None),
                               P(f, None, None), P()]))
    if name in ("wk", "wv"):
        return out(_first_fit(mesh, logical,
                              [P(f, model, None), P(f, None, None),
                               P(model, None, None), P()]))
    if name == "wo":
        return out(_first_fit(mesh, logical,
                              [P(model, None, f),
                               P(None, None, (*_t(f), model)),
                               P(None, None, f), P()]))
    if name in ("bq", "bk", "bv"):
        return out(_first_fit(mesh, logical, [P(model, None), P()]))
    if name in ("w_up", "w_gate"):
        if nd == 3:  # MoE experts (E, d, f)
            return out(_first_fit(mesh, logical,
                                  [P(model, f, None), P(None, f, model),
                                   P(None, model, None), P()]))
        return out(_first_fit(mesh, logical,
                              [P(f, model), P(model, None), P()]))
    if name == "w_down":
        if nd == 3:  # MoE experts (E, f, d)
            return out(_first_fit(mesh, logical,
                                  [P(model, None, f), P(None, model, f),
                                   P(None, None, model), P()]))
        return out(_first_fit(mesh, logical,
                              [P(model, f), P(None, model), P()]))
    if name == "router":
        return out(P())
    if name == "w_in":
        return out(_first_fit(mesh, logical, [P(f, model), P(None, model), P()]))
    if name == "w_out":
        return out(_first_fit(mesh, logical, [P(model, f), P(model, None), P()]))
    if name == "conv_w":
        return out(_first_fit(mesh, logical, [P(None, model), P()]))
    if name == "conv_b":
        return out(_first_fit(mesh, logical, [P(model), P()]))
    # norms, scalars, A_log, D, dt_bias, norm_scale ...
    return out(P())


def _t(axes: Axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _leaf_name(path) -> Tuple[str, bool]:
    """(innermost dict key, is-inside-'layers'/'encoder' stack)."""
    name = ""
    stacked = False
    for k in path:
        if isinstance(k, DictKey):
            if k.key in ("layers", "encoder"):
                stacked = True
            name = str(k.key)
    return name, stacked


def param_specs(params: Any, mesh: Mesh, *, fsdp: Axes = "data",
                model: str = "model", use_fsdp: bool = True) -> Any:
    """Tree of PartitionSpecs matching ``params``."""
    def rule(path, leaf):
        name, stacked = _leaf_name(path)
        return leaf_spec(name, leaf.shape, stacked, mesh, fsdp, model,
                         use_fsdp=use_fsdp)
    return jax.tree_util.tree_map_with_path(rule, params)


def param_shardings(params: Any, mesh: Mesh, **kw) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, **kw))


def batch_spec(mesh: Mesh, global_batch: int, dp_axes: Axes) -> P:
    """Batch sharding: data axes when divisible, else replicate."""
    if global_batch % _axes_size(mesh, dp_axes) == 0:
        return P(dp_axes)
    # try the first data axis alone
    axes = _t(dp_axes)
    for i in range(len(axes) - 1, 0, -1):
        sub = axes[:i]
        if global_batch % _axes_size(mesh, sub) == 0:
            return P(sub)
    return P(None)


def cache_specs(cache: Any, mesh: Mesh, *, dp_axes: Axes, model: str) -> Any:
    """Decode-cache sharding: batch over data axes when divisible; KV heads
    over model when divisible, else cache length over model (sequence-
    parallel decode attention for long contexts)."""
    def rule(path, leaf):
        name, _ = _leaf_name(path)
        shp = leaf.shape
        if name in ("k", "v") and len(shp) == 5:       # (n_per, B, C, KV, hd)
            opts = [P(None, dp_axes, None, model, None),
                    P(None, dp_axes, model, None, None),
                    P(None, None, model, None, None),
                    P(None, dp_axes, None, None, None), P()]
            return _first_fit(mesh, shp, opts)
        if name == "state" and len(shp) == 4:          # (n_per, B, h, p, n)? ssm
            pass
        if name == "state":                            # (n_per, B, H, P, N)
            opts = [P(None, dp_axes, model, None, None),
                    P(None, dp_axes, None, None, None),
                    P(None, None, model, None, None), P()]
            return _first_fit(mesh, shp, opts)
        if name == "conv":                             # (n_per, B, K-1, ch)
            opts = [P(None, dp_axes, None, model),
                    P(None, dp_axes, None, None),
                    P(None, None, None, model), P()]
            return _first_fit(mesh, shp, opts)
        if name == "pos":
            return P()
        # cross-attention caches etc.
        if len(shp) >= 2:
            opts = [P(None, dp_axes), P()]
            return _first_fit(mesh, shp, opts)
        return P()
    return jax.tree_util.tree_map_with_path(rule, cache)
