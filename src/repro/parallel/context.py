"""Global parallel context: which mesh/axes the model layers should use.

Layers stay mesh-agnostic; the launcher/trainer installs a context and the
layers consult it for shard_map regions (expert parallelism, Canary grad
sync) and sharding constraints. When no context is installed (unit tests,
single CPU) every layer falls back to its single-program path.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Tuple, Union

from jax.sharding import Mesh


@dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh
    data_axes: Tuple[str, ...]   # batch-parallel axes, e.g. ("pod", "data")
    model_axis: str              # tensor/expert-parallel axis
    # Layers insert batch-sharding constraints on activations at period
    # boundaries (keeps GSPMD gathering FSDP weights instead of replicating
    # activations). Must be False inside data-manual shard_map regions.
    constrain_activations: bool = True
    # MoE expert-parallel shard_map cannot nest inside a data-manual
    # shard_map region (explicit grad-sync modes); those set this to False.
    allow_shardmap_layers: bool = True
    # Sequence parallelism: shard the sequence dim of boundary activations
    # over the model axis. Cuts scan-saved residuals (the dominant memory
    # term for wide models) by tp_size at the cost of per-layer all-gathers.
    sequence_parallel: bool = False

    @property
    def data_spec(self) -> Union[str, Tuple[str, ...]]:
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.model_axis]


_state = threading.local()


def set_parallel_context(ctx: Optional[ParallelContext]) -> None:
    _state.ctx = ctx


def get_parallel_context() -> Optional[ParallelContext]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def parallel_context(ctx: ParallelContext):
    prev = get_parallel_context()
    set_parallel_context(ctx)
    try:
        yield ctx
    finally:
        set_parallel_context(prev)
