"""Version compatibility shims.

The codebase targets the current JAX API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``), but must
also run on older toolchains (down to jax 0.4.x / Python 3.10) where those
names either do not exist or live under ``jax.experimental``. Rather than
sprinkling feature checks through every call site, :func:`patch_jax` installs
forward-compatible aliases once, at ``repro`` import time:

* ``jax.sharding.AxisType`` — stubbed enum when missing (the values are only
  ever forwarded to ``make_mesh``, which the wrapper below ignores on old
  versions).
* ``jax.make_mesh`` — wrapped to accept and drop ``axis_types`` when the
  installed signature predates it.
* ``jax.shard_map`` — aliased to ``jax.experimental.shard_map.shard_map`` with
  ``check_vma`` translated to the old ``check_rep`` spelling.

Pure Python stdlib gaps (e.g. ``enum.StrEnum`` on 3.10) are handled locally in
the modules that need them, not here.
"""
from __future__ import annotations

import enum
import functools
import inspect

_PATCHED = False


def patch_jax() -> None:
    """Install forward-compat aliases onto the ``jax`` package (idempotent).

    A no-op when jax is missing entirely (the simulator core has no jax
    dependency) or already new enough.
    """
    global _PATCHED
    if _PATCHED:
        return
    _PATCHED = True
    try:
        import jax
        import jax.sharding
    except ImportError:  # simulator-only environments
        return

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    _orig_make_mesh = getattr(jax, "make_mesh", None)
    try:
        params = inspect.signature(_orig_make_mesh).parameters \
            if _orig_make_mesh is not None else {}
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        params = {}
    if _orig_make_mesh is not None and "axis_types" not in params:

        @functools.wraps(_orig_make_mesh)
        def make_mesh(*args, axis_types=None, **kwargs):
            del axis_types  # pre-AxisType meshes are implicitly Auto
            return _orig_make_mesh(*args, **kwargs)

        jax.make_mesh = make_mesh

    if not hasattr(jax.lax, "axis_size"):
        from jax import core as _core

        def _one_axis_size(a):
            frame = _core.axis_frame(a)
            # 0.4.3x returns the size directly; earlier versions a frame object
            return frame if isinstance(frame, int) else frame.size

        def axis_size(axis_name):
            """Static size of a bound mapped axis (new-jax ``lax.axis_size``)."""
            if isinstance(axis_name, (tuple, list)):
                n = 1
                for a in axis_name:
                    n *= _one_axis_size(a)
                return n
            return _one_axis_size(axis_name)

        jax.lax.axis_size = axis_size

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *args, check_vma=None, axis_names=None, **kwargs):
            if check_vma is not None and "check_rep" not in kwargs:
                kwargs["check_rep"] = check_vma
            if axis_names is not None and "auto" not in kwargs:
                # new API: manual over ``axis_names`` only; old API spells the
                # complement via ``auto``
                mesh = kwargs.get("mesh") or (args[0] if args else None)
                if mesh is not None:
                    kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
            return _shard_map(f, *args, **kwargs)

        jax.shard_map = shard_map
