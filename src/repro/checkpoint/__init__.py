from ..compat import patch_jax as _patch_jax

_patch_jax()

from .checkpointer import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint"]
