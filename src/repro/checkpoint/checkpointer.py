"""Checkpointing: pytree save/restore with shape/dtype manifest.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` (tree structure,
shapes, dtypes, step). Restore validates the manifest against the target
tree and (optionally) device_puts onto provided shardings. Deterministic
data (repro.data) makes (checkpoint step -> batch stream) resume exact.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, params: Any,
                    opt_state: Any = None) -> str:
    path = os.path.join(directory, f"step_{step}")
    os.makedirs(path, exist_ok=True)
    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    leaves, treedef = _flatten(state)

    def _np(x):
        a = np.asarray(x)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            # npz has no native bfloat16: store a lossless fp32 upcast; the
            # manifest keeps the original dtype and restore re-casts.
            return np.asarray(x, dtype=np.float32)
        return a

    arrays = {f"leaf_{i}": _np(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, params_like: Any,
                       opt_like: Any = None, shardings: Any = None
                       ) -> Tuple[Any, Any, int]:
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    like = {"params": params_like}
    if opt_like is not None:
        like["opt"] = opt_like
    leaves_like, treedef = _flatten(like)
    if manifest["num_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, target tree "
            f"has {len(leaves_like)} — architecture mismatch?")
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"target {np.shape(ref)}")
        leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state["params"] = jax.device_put(state["params"], shardings)
    params = state["params"]
    opt = state.get("opt")
    return params, opt, manifest["step"]
