from .engine import Engine, ServeConfig, make_serve_step

__all__ = ["Engine", "ServeConfig", "make_serve_step"]
