from ..compat import patch_jax as _patch_jax

_patch_jax()

from .engine import Engine, ServeConfig, make_serve_step

__all__ = ["Engine", "ServeConfig", "make_serve_step"]
