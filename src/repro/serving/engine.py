"""Serving engine: batched prefill + decode with KV / SSM-state caches.

``serve_step`` (one token for the whole batch against a fixed-size cache) is
the unit the decode dry-run shapes lower; the ``Engine`` class wraps it with
prefill and simple continuous batching for the runnable examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import (decode_step, forward, init_cache, init_params,
                          prepare_cross_cache)
from repro.models.config import ModelConfig


@dataclass
class ServeConfig:
    model: ModelConfig
    batch: int
    max_len: int
    temperature: float = 0.0   # 0 = greedy


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens1) -> (next_tokens, logits, cache)."""
    def serve_step(params, cache, tokens1):
        logits, cache = decode_step(params, cache, tokens1, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, cache
    return serve_step


class Engine:
    """Minimal batched serving loop over the functional model."""

    def __init__(self, sc: ServeConfig, params=None, seed: int = 0):
        self.sc = sc
        cfg = sc.model
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.cache = init_cache(cfg, sc.batch, sc.max_len)
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.prefill_fn = jax.jit(
            lambda p, toks, kw: forward(p, toks, cfg, **kw))

    def prefill(self, prompts: jnp.ndarray, frames=None) -> jnp.ndarray:
        """Teacher-forced prefill; fills the KV cache by stepping tokens.

        For attention-only models a bulk prefill would be a single forward;
        stepping keeps one code path valid for SSM/hybrid caches too (decode
        correctness is what the examples demonstrate).
        """
        cfg = self.sc.model
        if cfg.is_encoder_decoder:
            if frames is None:
                raise ValueError("enc-dec serving needs frames")
            self.cache["cross"] = prepare_cross_cache(self.params, frames, cfg)
        B, S = prompts.shape
        last = None
        for t in range(S):
            last, _, self.cache = self.step_fn(self.params, self.cache,
                                               prompts[:, t:t + 1])
        return last

    def generate(self, prompts: jnp.ndarray, new_tokens: int,
                 frames=None) -> Tuple[jnp.ndarray, Dict[str, float]]:
        t0 = time.perf_counter()
        nxt = self.prefill(prompts, frames=frames)
        t_prefill = time.perf_counter() - t0
        out = [nxt]
        t1 = time.perf_counter()
        for _ in range(new_tokens - 1):
            nxt, _, self.cache = self.step_fn(self.params, self.cache, nxt)
            out.append(nxt)
        t_decode = time.perf_counter() - t1
        tokens = jnp.concatenate(out, axis=1)
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": (new_tokens - 1) * prompts.shape[0]
            / max(t_decode, 1e-9),
        }
        return tokens, stats
