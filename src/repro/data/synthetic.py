"""Deterministic synthetic LM data pipeline.

Tokens are produced by a counter-based integer hash (SplitMix64-style) of
(seed, step, position) — fully deterministic, seekable to any step (exact
resume after checkpoint restore), no storage, and identical across hosts so
every data shard can materialize its slice independently.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


def batch_at(cfg: DataConfig, step: int,
             batch_slice: Optional[Tuple[int, int]] = None
             ) -> Dict[str, np.ndarray]:
    """Materialize the (sliced) batch for ``step``.

    ``batch_slice=(lo, hi)`` returns rows [lo, hi) of the global batch —
    the per-data-shard view.
    """
    lo, hi = batch_slice or (0, cfg.global_batch)
    rows = np.arange(lo, hi, dtype=np.uint64)[:, None]
    cols = np.arange(cfg.seq_len + 1, dtype=np.uint64)[None, :]
    key = np.uint64((cfg.seed * 1_000_003
                     + step * 0xD1B54A32D192ED03) & 0xFFFFFFFFFFFFFFFF)
    with np.errstate(over="ignore"):
        raw = _splitmix64(key + rows * np.uint64(0x100000001B3) + cols)
    toks = (raw % np.uint64(cfg.vocab_size)).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
