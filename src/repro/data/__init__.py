from ..compat import patch_jax as _patch_jax

_patch_jax()

from .synthetic import DataConfig, batch_at, iterate

__all__ = ["DataConfig", "batch_at", "iterate"]
