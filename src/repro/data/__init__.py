from .synthetic import DataConfig, batch_at, iterate

__all__ = ["DataConfig", "batch_at", "iterate"]
