"""Named, ready-made training-workload scenarios.

Each scenario pins everything a prediction needs: which registered
architecture (smoke variant — the full configs work identically but are not
CPU-test material), the fabric it trains on, the data-parallel degree, batch
geometry, DDP bucket size and the wire-byte scale (see
:mod:`~.predictor` on ``bytes_scale``). The registry is string-keyed like
the simulator's algorithm/topology registries, so downstream suites and
examples name scenarios instead of re-assembling knobs:

    predict_scenario("deepseek-moe/fat_tree", algo=Algo.CANARY,
                     congestion=True)

Covered axes: dense (llama3), MoE with expert sharding (deepseek), SSM
(mamba2) and encoder-decoder audio (whisper), each on both registered
fabrics (``fat_tree`` and ``three_tier``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

# jax-free: repro.models.__init__ is lazy, so the registry imports without
# pulling the jax-backed model half (pinned by test_model_comm)
from repro.models.config import ModelConfig
from repro.models.registry import get_config as _registry_get_config

from ..canary.types import Algo, SimConfig, scaled_config, three_tier_config
from .predictor import IterationPrediction, predict_iteration
from .timeline import HostSpec


def get_model_config(name: str, variant: str = "smoke") -> ModelConfig:
    """``repro.models.registry.get_config`` with a smoke-variant default
    (the CPU-runnable configs are what simulator-side consumers want)."""
    return _registry_get_config(name, variant)


@dataclass(frozen=True)
class WorkloadScenario:
    """One named (model x fabric x batch geometry) training workload."""

    name: str
    arch: str                      # repro.models.registry key
    topology: str                  # "fat_tree" | "three_tier"
    dp_hosts: int = 8
    seq: int = 128
    global_batch: int = 8
    bucket_bytes: int = 1 << 17    # 128 KiB DDP buckets at smoke scale
    bytes_scale: float = 0.125     # wire-byte scale (predictor docstring)
    expert_sharding: bool = False
    variant: str = "smoke"         # "full" runs the published config
    host: HostSpec = field(default_factory=HostSpec)
    description: str = ""


SCENARIOS: Dict[str, WorkloadScenario] = {}


def register_scenario(s: WorkloadScenario) -> WorkloadScenario:
    if s.name in SCENARIOS:
        raise ValueError(f"scenario {s.name!r} already registered")
    SCENARIOS[s.name] = s
    return s


def get_scenario(name: str) -> WorkloadScenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}") from None


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def make_sim_cfg(scenario: WorkloadScenario, **overrides) -> SimConfig:
    """The scenario's fabric (both are ~1/16-scale models, CPU-fast)."""
    if scenario.topology == "fat_tree":
        return scaled_config(4, **overrides)            # 16 hosts
    if scenario.topology == "three_tier":
        return three_tier_config(**overrides)           # 32 hosts, 3 tiers
    raise ValueError(f"unknown topology {scenario.topology!r}")


def predict_scenario(name: str, *, algo: Algo = Algo.CANARY,
                     n_trees: int = 1, congestion: bool = False,
                     sim_cfg: Optional[SimConfig] = None,
                     **overrides) -> IterationPrediction:
    """Run one named scenario end to end. ``overrides`` replace scenario
    fields (e.g. ``dp_hosts=4, bytes_scale=0.03`` for a faster cell)."""
    s = get_scenario(name)
    if overrides:
        s = replace(s, **overrides)
    cfg = sim_cfg if sim_cfg is not None else make_sim_cfg(s)
    model = get_model_config(s.arch, s.variant)
    return predict_iteration(
        model, cfg, algo=algo, n_trees=n_trees, dp_hosts=s.dp_hosts,
        seq=s.seq, global_batch=s.global_batch, bucket_bytes=s.bucket_bytes,
        expert_sharding=s.expert_sharding, host=s.host,
        bytes_scale=s.bytes_scale, congestion=congestion)


def _register_defaults() -> None:
    models = (
        ("llama3-dense", "llama3.2-1b", False,
         "dense GQA decoder, classic DDP"),
        ("deepseek-moe", "deepseek-moe-16b", True,
         "fine-grained MoE, routed experts sharded (EP) — expert grads "
         "skip the DP allreduce"),
        ("mamba2", "mamba2-130m", False, "attention-free SSM stack"),
        ("whisper", "whisper-large-v3", False,
         "encoder-decoder audio; encoder grads release after the decoder's"),
    )
    for short, arch, ep, desc in models:
        for topo in ("fat_tree", "three_tier"):
            # the 3-tier fabric has 2x the hosts and 4-hop cross-pod paths:
            # halve the wire scale so event counts stay comparable per cell
            register_scenario(WorkloadScenario(
                name=f"{short}/{topo}", arch=arch, topology=topo,
                bytes_scale=0.125 if topo == "fat_tree" else 0.0625,
                expert_sharding=ep, description=desc))


_register_defaults()
