"""Workload compiler: model configs -> bucketed gradient traffic -> predicted
iteration time.

The missing bridge between the repo's ML stack (``repro.models`` /
``repro.configs`` — ten published architectures) and the packet-level
simulator, letting the repo answer "how much faster does this *model* train
under Canary?" rather than "how fast is one 1 MiB allreduce?":

* :mod:`~.model_comm` — per-layer gradient sizes from any registered
  :class:`~repro.models.config.ModelConfig`, packed into DDP-style
  reverse-layer-order buckets (dtype-aware, MoE-expert-sharding-aware).
* :mod:`~.timeline`   — the backward pass as roofline-estimated compute
  segments that release buckets over time.
* :mod:`~.predictor`  — each bucket becomes an ``AllreduceJob`` with a
  staggered ``arrival_ns`` (the fleet subsystem's ``EV_JOB_ARRIVE`` path);
  one simulator run yields predicted iteration time and the
  exposed-communication fraction, with scaling curves over hosts x
  algorithm x congestion.
* :mod:`~.scenarios`  — named ready-made scenarios (dense llama3 /
  deepseek-moe / mamba2 / whisper on fat_tree / three_tier).

Pure analysis + simulator consumers: importing this package touches neither
jax nor any simulator state (goldens replay bit-for-bit with it imported —
pinned by ``tests/workload/test_workload_fleet.py``).
"""
from .model_comm import (GRAD_DTYPE_BYTES, CommPlan, GradBucket, GradSegment,
                         grad_dtype_bytes, grad_segments, pack_buckets,
                         total_dp_grad_bytes)
from .predictor import (BucketOutcome, IterationPrediction, compile_jobs,
                        pick_participants, predict_iteration, scaling_curves)
from .scenarios import (SCENARIOS, WorkloadScenario, get_model_config,
                        get_scenario, list_scenarios, make_sim_cfg,
                        predict_scenario, register_scenario)
from .timeline import (ComputeSegment, HostSpec, IterationTimeline,
                       build_timeline)

__all__ = [
    "GRAD_DTYPE_BYTES", "SCENARIOS", "BucketOutcome", "CommPlan",
    "ComputeSegment", "GradBucket", "GradSegment", "HostSpec",
    "IterationPrediction", "IterationTimeline", "WorkloadScenario",
    "build_timeline", "compile_jobs", "get_model_config", "get_scenario",
    "grad_dtype_bytes",
    "grad_segments", "list_scenarios", "make_sim_cfg", "pack_buckets",
    "pick_participants", "predict_iteration", "predict_scenario",
    "register_scenario", "scaling_curves", "total_dp_grad_bytes",
]
