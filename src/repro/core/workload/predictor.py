"""End-to-end iteration-time prediction at packet level.

Bridges the analytic side (:mod:`~.model_comm` buckets +
:mod:`~.timeline` release times) to the packet simulator: every bucket
becomes one :class:`~repro.core.canary.types.AllreduceJob` whose
``arrival_ns`` is its release time, so late buckets activate mid-run through
the fleet subsystem's ``EV_JOB_ARRIVE`` machinery while earlier buckets'
packets are still in flight — exactly DDP's compute/communication overlap.

Predicted iteration time is ``max(compute_end, last bucket finish)``: the
optimizer step is deliberately excluded (it is local and identical across
allreduce algorithms). The *exposed-communication fraction* —
``(iteration - compute) / iteration`` — is the headline number: it is the
share of the iteration the accelerators sit idle waiting for gradient
traffic, i.e. what an in-network allreduce is supposed to shrink.

``bytes_scale`` scales the simulated wire bytes. The default fabrics are
1/16-scale models of the paper's 1024-host network (see
``benchmarks/common.py``); scaling the gradient traffic by the same kind of
factor keeps smoke-model runs CPU-fast while preserving the compute/comm
overlap structure. Scale-1 full-model runs are the same code path.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.models.config import ModelConfig

from ..canary.simulator import Simulator
from ..canary.types import Algo, AllreduceJob, SimConfig, SimResult
from .model_comm import CommPlan, pack_buckets
from .timeline import HostSpec, IterationTimeline, build_timeline


@dataclass(frozen=True)
class BucketOutcome:
    """One bucket's simulated life: released, submitted, finished."""

    index: int
    app: int
    sim_bytes: int              # wire bytes after ``bytes_scale``
    release_ns: float           # compute-side: when its gradients were ready
    finish_ns: float            # simulator: when its allreduce completed


@dataclass
class IterationPrediction:
    """Predicted end-to-end training-iteration time for one algorithm."""

    model: str
    algo: str
    plan: CommPlan
    timeline: IterationTimeline
    buckets: List[BucketOutcome]
    sim: SimResult
    iteration_ns: float
    compute_ns: float           # forward + backward (no communication)
    comm_last_finish_ns: float
    exposed_comm_ns: float      # iteration - compute: accelerator idle time
    exposed_comm_frac: float

    @property
    def correct(self) -> bool:
        return self.sim.correct

    def summary(self) -> str:
        return (f"{self.model}/{self.algo}: iter={self.iteration_ns / 1e3:.1f}us "
                f"compute={self.compute_ns / 1e3:.1f}us "
                f"exposed_comm={self.exposed_comm_frac:.1%} "
                f"buckets={len(self.buckets)} correct={self.correct}")


def pick_participants(cfg: SimConfig, n: int,
                      seed: Optional[int] = None) -> List[int]:
    """``n`` data-parallel ranks placed randomly across the fabric (same
    placement model as ``repro.core.canary.algorithms.pick_hosts``)."""
    rng = random.Random(cfg.seed if seed is None else seed)
    return rng.sample(range(cfg.num_hosts), n)


def compile_jobs(plan: CommPlan, timeline: IterationTimeline,
                 participants: Sequence[int], *, bytes_scale: float = 1.0,
                 app_base: int = 0, tenant: int = 0) -> List[AllreduceJob]:
    """Lower a (plan, timeline) pair to arrival-timed allreduce jobs."""
    if bytes_scale <= 0:
        raise ValueError("bytes_scale must be positive")
    jobs = []
    for b, release in zip(plan.buckets, timeline.bucket_release_ns):
        jobs.append(AllreduceJob(
            app=app_base + b.index, participants=list(participants),
            data_bytes=max(1, round(b.bytes * bytes_scale)),
            arrival_ns=release, tenant=tenant))
    return jobs


def predict_iteration(model_cfg: ModelConfig, sim_cfg: SimConfig, *,
                      algo: Algo = Algo.CANARY, n_trees: int = 1,
                      participants: Optional[Sequence[int]] = None,
                      dp_hosts: Optional[int] = None,
                      seq: int = 128, global_batch: int = 8,
                      bucket_bytes: int = 1 << 20,
                      grad_dtype: Optional[str] = None,
                      expert_sharding: bool = False,
                      host: Optional[HostSpec] = None,
                      bytes_scale: float = 1.0,
                      congestion: bool = False,
                      noise_hosts: Optional[Sequence[int]] = None,
                      app_base: int = 0) -> IterationPrediction:
    """Compile ``model_cfg``'s gradient traffic and simulate one iteration.

    Either pass explicit ``participants`` or a ``dp_hosts`` count (placed
    via :func:`pick_participants`). ``congestion=True`` puts every
    non-participant host on random-uniform background traffic (§5.2) unless
    ``noise_hosts`` is given explicitly.
    """
    if participants is None:
        if dp_hosts is None:
            raise ValueError("pass participants or dp_hosts")
        participants = pick_participants(sim_cfg, dp_hosts)
    participants = list(participants)
    plan = pack_buckets(model_cfg, bucket_bytes=bucket_bytes,
                        grad_dtype=grad_dtype,
                        expert_sharding=expert_sharding)
    timeline = build_timeline(model_cfg, plan, seq=seq,
                              global_batch=global_batch,
                              dp_hosts=len(participants), host=host)
    jobs = compile_jobs(plan, timeline, participants,
                        bytes_scale=bytes_scale, app_base=app_base)
    noise: List[int] = list(noise_hosts) if noise_hosts is not None else []
    if congestion and noise_hosts is None:
        pset = set(participants)
        noise = [h for h in range(sim_cfg.num_hosts) if h not in pset]
    sim = Simulator(sim_cfg, jobs, algo=algo, n_trees=n_trees,
                    noise_hosts=noise or None)
    result = sim.run()
    outcomes = [BucketOutcome(index=b.index, app=j.app, sim_bytes=j.data_bytes,
                              release_ns=j.arrival_ns,
                              finish_ns=result.job_finish_ns.get(
                                  j.app, float("nan")))
                for b, j in zip(plan.buckets, jobs)]
    compute_ns = timeline.compute_ns
    last_finish = max((o.finish_ns for o in outcomes), default=0.0)
    iteration_ns = max(compute_ns, last_finish)
    exposed = iteration_ns - compute_ns
    return IterationPrediction(
        model=model_cfg.name, algo=str(algo), plan=plan, timeline=timeline,
        buckets=outcomes, sim=result, iteration_ns=iteration_ns,
        compute_ns=compute_ns, comm_last_finish_ns=last_finish,
        exposed_comm_ns=exposed,
        exposed_comm_frac=exposed / iteration_ns if iteration_ns > 0 else 0.0)


def scaling_curves(model_cfg: ModelConfig, sim_cfg: SimConfig, *,
                   hosts_list: Sequence[int],
                   algos: Sequence[Tuple[Algo, int]] = ((Algo.CANARY, 1),
                                                        (Algo.STATIC_TREE, 1),
                                                        (Algo.RING, 1)),
                   congestion_levels: Sequence[bool] = (False, True),
                   **predict_kw) -> List[Dict]:
    """Predicted iteration time over hosts x algorithm x congestion.

    Placement is fixed per host count (all algorithms and congestion levels
    see identical participant sets), so rows are directly comparable.
    Returns one flat dict per cell, JSON-ready.
    """
    rows: List[Dict] = []
    for n in hosts_list:
        parts = pick_participants(sim_cfg, n)
        for algo, n_trees in algos:
            for cong in congestion_levels:
                p = predict_iteration(model_cfg, sim_cfg, algo=algo,
                                      n_trees=n_trees, participants=parts,
                                      congestion=cong, **predict_kw)
                rows.append({
                    "model": p.model, "hosts": n, "algo": p.algo,
                    "n_trees": n_trees, "congestion": cong,
                    "iteration_ns": p.iteration_ns,
                    "compute_ns": p.compute_ns,
                    "comm_last_finish_ns": p.comm_last_finish_ns,
                    "exposed_comm_frac": p.exposed_comm_frac,
                    "buckets": len(p.buckets),
                    "dp_grad_bytes": p.plan.total_grad_bytes,
                    "correct": p.correct,
                })
    return rows
