"""Backward-pass timeline: when does each gradient bucket become ready?

The backward pass is modeled as one roofline-estimated compute segment per
:class:`~.model_comm.GradSegment`, executed in backward order. A bucket's
allreduce can launch the moment its last segment finishes — that release
time becomes the bucket job's ``arrival_ns`` in the simulator, so the
packet-level run sees exactly the staggered, compute-overlapped traffic a
DDP trainer emits.

Roofline model (per segment, per device):

* FLOPs — the 6ND split: forward ``2 * active_params * tokens``, backward
  ``4 * active_params * tokens`` (``model_flops_per_step`` in
  ``repro.launch.analysis`` uses the same 6ND/2ND accounting; the per-segment
  attribution is by active parameters, so segment FLOPs sum to the
  whole-model figure).
* bytes — weights read + gradients written (backward: weight read, grad
  write, weight-grad write ~ 3x params) plus activation traffic
  (~``4 * tokens * d_model`` reads/writes per segment).
* ``time = max(flops / (peak * mfu), bytes / hbm_bw)`` — compute- or
  memory-bound, whichever binds.

Hardware defaults are the TPU v5e constants from ``repro.launch.mesh``
(kept as literals here so the simulator core stays jax-free; pinned equal
by ``tests/workload/test_model_comm.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.models.config import ModelConfig

from .model_comm import CommPlan

# TPU v5e (== repro.launch.mesh PEAK_FLOPS_BF16 / HBM_BW; jax-free copy)
_V5E_PEAK_FLOPS = 197e12
_V5E_HBM_BW = 819e9


@dataclass(frozen=True)
class HostSpec:
    """Roofline device model for one data-parallel rank."""

    peak_flops: float = _V5E_PEAK_FLOPS   # per-chip peak (bf16)
    hbm_bw: float = _V5E_HBM_BW           # bytes/s
    mfu: float = 0.4                      # achieved fraction of peak FLOPs

    def segment_ns(self, flops: float, mem_bytes: float) -> float:
        compute_s = flops / (self.peak_flops * self.mfu)
        memory_s = mem_bytes / self.hbm_bw
        return max(compute_s, memory_s) * 1e9


@dataclass(frozen=True)
class ComputeSegment:
    """One backward-pass segment on the modeled timeline."""

    name: str
    order: int
    start_ns: float
    end_ns: float
    flops: float


@dataclass(frozen=True)
class IterationTimeline:
    """Compute-side timeline of one training iteration (no communication)."""

    forward_ns: float
    backward_ns: float
    segments: Tuple[ComputeSegment, ...]        # backward order
    bucket_release_ns: Tuple[float, ...]        # absolute, one per bucket

    @property
    def compute_ns(self) -> float:
        """Pure compute time: forward + backward, zero exposed comm."""
        return self.forward_ns + self.backward_ns


def build_timeline(cfg: ModelConfig, plan: CommPlan, *, seq: int,
                   global_batch: int, dp_hosts: int,
                   host: Optional[HostSpec] = None) -> IterationTimeline:
    """Schedule ``plan``'s segments on the roofline device model.

    ``dp_hosts`` is the data-parallel degree: each rank computes over
    ``global_batch / dp_hosts`` sequences, and each bucket is allreduced
    across all ``dp_hosts`` ranks.
    """
    if dp_hosts <= 0 or seq <= 0 or global_batch <= 0:
        raise ValueError("seq, global_batch and dp_hosts must be positive")
    host = host or HostSpec()
    tokens = seq * global_batch / dp_hosts
    db = plan.dtype_bytes

    # forward: 2ND over the whole model (segment order does not matter here)
    fwd_flops = sum(2.0 * s.active_params * tokens for s in plan.segments)
    fwd_bytes = sum(2.0 * s.total_params * db
                    + 2.0 * tokens * cfg.d_model * db for s in plan.segments)
    forward_ns = host.segment_ns(fwd_flops, fwd_bytes)

    # backward: per-segment 4ND, laid out sequentially in backward order
    segments = []
    t = 0.0
    end_by_order = {}
    for s in plan.segments:
        flops = 4.0 * s.active_params * tokens
        mem = 3.0 * s.total_params * db + 4.0 * tokens * cfg.d_model * db
        dur = host.segment_ns(flops, mem)
        segments.append(ComputeSegment(name=s.name, order=s.order,
                                       start_ns=t, end_ns=t + dur,
                                       flops=flops))
        t += dur
        end_by_order[s.order] = segments[-1].end_ns
    backward_ns = t

    releases = tuple(forward_ns + end_by_order[b.last_order]
                     for b in plan.buckets)
    return IterationTimeline(forward_ns=forward_ns, backward_ns=backward_ns,
                             segments=tuple(segments),
                             bucket_release_ns=releases)
