"""From a :class:`~repro.models.config.ModelConfig` to gradient traffic.

Data-parallel training synchronizes one gradient per parameter every
iteration. DDP-style implementations do not allreduce per tensor: they pack
gradients into fixed-size *buckets* in reverse layer order — the order the
backward pass produces them — and launch one allreduce per bucket as soon as
its last gradient is ready, overlapping communication with the rest of the
backward pass. This module derives that structure analytically:

* :func:`grad_segments` — per-layer gradient sizes (parameters, routed-expert
  parameters, per-token *active* parameters) in backward completion order:
  LM head first, decoder layers last→first, encoder layers (whisper) after
  the decoder, input embedding last. The decomposition mirrors
  ``ModelConfig.param_count()`` term by term and is pinned to it exactly by
  ``tests/workload/test_model_comm.py`` over every registered architecture.
* :func:`pack_buckets` — DDP-style packing into a :class:`CommPlan`: fill a
  bucket in backward order until it reaches ``bucket_bytes``, then close it.
  A segment larger than ``bucket_bytes`` is split into bucket-sized chunks
  first (real DDP packs at tensor granularity, so one big layer spans
  several buckets); every chunk of a segment carries the segment's release
  point, since its gradients only all exist once that layer's backward is
  done. Gradient dtype defaults to the model's compute dtype.

MoE expert sharding: with ``expert_sharding=False`` (classic DDP) every rank
holds every expert and routed-expert gradients ride the same data-parallel
allreduce. With ``True`` (expert parallelism, ``moe_impl="ep"``) each rank
owns a shard of the experts — expert gradients are reduced inside the
expert group by the layer's all-to-alls, *not* by the DP allreduce — so they
are excluded from the buckets and reported as ``expert_grad_bytes``.

Everything here is pure arithmetic on the config — no jax, no simulator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.models.config import ModelConfig

# Gradients are exchanged in the model's compute dtype (bf16 training keeps
# bf16 grads on the wire; fp32 master copies live in the optimizer).
GRAD_DTYPE_BYTES = {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2}


@dataclass(frozen=True)
class GradSegment:
    """Gradients released by one backward step (one layer / head / embed).

    ``order`` is the backward completion order (0 = first gradients out).
    ``params`` are data-parallel-replicated parameters whose gradients ride
    the DP allreduce; ``expert_params`` are routed-expert parameters (see
    module docstring); ``active_params`` are the per-token *activated*
    parameters, used to attribute FLOPs to this segment
    (``sum(active_params) == cfg.active_param_count()``).
    """

    name: str
    order: int
    params: int
    expert_params: int
    active_params: int

    @property
    def total_params(self) -> int:
        return self.params + self.expert_params


@dataclass(frozen=True)
class GradBucket:
    """One DDP gradient bucket == one allreduce job.

    ``last_order`` is the backward order of the latest segment in the bucket:
    the bucket's allreduce can launch once that segment's backward completes.
    """

    index: int
    bytes: int
    params: int
    segments: Tuple[str, ...]
    last_order: int


@dataclass(frozen=True)
class CommPlan:
    """A model's complete per-iteration gradient-communication plan."""

    model: str
    dtype_bytes: int
    bucket_bytes: int
    expert_sharding: bool
    segments: Tuple[GradSegment, ...]
    buckets: Tuple[GradBucket, ...]
    total_grad_bytes: int          # DP-allreduced bytes (sum of bucket bytes)
    expert_grad_bytes: int         # excluded by expert sharding (0 otherwise)

    def summary(self) -> str:
        return (f"{self.model}: {len(self.segments)} segments -> "
                f"{len(self.buckets)} buckets x <= ~{self.bucket_bytes} B, "
                f"dp_grad={self.total_grad_bytes} B "
                f"expert_sharded={self.expert_grad_bytes} B")


def grad_dtype_bytes(cfg: ModelConfig,
                     grad_dtype: Optional[str] = None) -> int:
    dt = grad_dtype if grad_dtype is not None else cfg.dtype
    try:
        return GRAD_DTYPE_BYTES[dt]
    except KeyError:
        raise ValueError(f"unknown gradient dtype {dt!r}; known: "
                         f"{sorted(GRAD_DTYPE_BYTES)}") from None


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkv = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
    return qkv + cfg.num_heads * hd * d


def _ssm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    return d * (2 * di + 2 * di) + 2 * di * n + di * d


def _dense_mlp_params(cfg: ModelConfig) -> int:
    mult = 3 if cfg.activation == "swiglu" else 2
    return mult * cfg.d_model * cfg.d_ff


def grad_segments(cfg: ModelConfig) -> Tuple[GradSegment, ...]:
    """Per-segment gradient sizes in backward completion order.

    Mirrors ``ModelConfig.param_count()`` exactly:
    ``sum(s.total_params) == cfg.param_count()`` and
    ``sum(s.active_params) == cfg.active_param_count()``.
    """
    d, v = cfg.d_model, cfg.vocab_size
    segs = []
    order = 0
    # LM head gradients come out first (loss -> logits -> output projection).
    # Tied embeddings accumulate into the embedding gradient instead, which
    # is only complete once the backward reaches the input embedding.
    if not cfg.tie_embeddings:
        segs.append(GradSegment("head", order, v * d, 0, v * d))
        order += 1
    for i in reversed(range(cfg.num_layers)):
        if cfg.layer_kind(i) == "attn":
            mixer = _attn_params(cfg)
        else:
            mixer = _ssm_params(cfg)
        params = mixer + 2 * d                      # + norms
        expert = 0
        active = 0
        if cfg.layer_is_moe(i):
            expert = cfg.moe_experts * 3 * d * cfg.moe_d_ff
            # shared experts (fused into d_ff when set) + router stay dense
            params += cfg.moe_shared_experts * 3 * d * cfg.moe_d_ff \
                if not cfg.d_ff else 3 * d * cfg.d_ff
            params += d * cfg.moe_experts
            active = params + cfg.moe_top_k * 3 * d * cfg.moe_d_ff
        else:
            params += _dense_mlp_params(cfg)
            active = params
        segs.append(GradSegment(f"layer{i}", order, params, expert, active))
        order += 1
    # Encoder backward (whisper) runs after the decoder's. param_count()
    # folds the decoder cross-attention into the encoder loop; mirror that.
    for i in reversed(range(cfg.encoder_layers)):
        params = _attn_params(cfg) + _dense_mlp_params(cfg) + 2 * d
        if cfg.is_encoder_decoder:
            params += _attn_params(cfg)             # decoder cross-attention
        segs.append(GradSegment(f"enc{i}", order, params, 0, params))
        order += 1
    segs.append(GradSegment("embed", order, v * d, 0, v * d))
    return tuple(segs)


def pack_buckets(cfg: ModelConfig, *, bucket_bytes: int,
                 grad_dtype: Optional[str] = None,
                 expert_sharding: bool = False) -> CommPlan:
    """Pack :func:`grad_segments` into DDP-style buckets (module docstring)."""
    if bucket_bytes <= 0:
        raise ValueError("bucket_bytes must be positive")
    db = grad_dtype_bytes(cfg, grad_dtype)
    segments = grad_segments(cfg)
    buckets = []
    cur_bytes, cur_params, cur_names, cur_last = 0, 0, [], -1
    expert_bytes = 0

    def close() -> None:
        nonlocal cur_bytes, cur_params, cur_names, cur_last
        buckets.append(GradBucket(index=len(buckets), bytes=cur_bytes,
                                  params=cur_params,
                                  segments=tuple(cur_names),
                                  last_order=cur_last))
        cur_bytes, cur_params, cur_names, cur_last = 0, 0, [], -1

    for seg in segments:
        dp_params = seg.params
        if expert_sharding:
            expert_bytes += seg.expert_params * db
        else:
            dp_params += seg.expert_params
        if dp_params == 0:
            continue
        # split a segment bigger than the bucket cap into bucket-sized
        # chunks (DDP packs per tensor; one big layer spans several buckets)
        n_chunks = max(1, -(-dp_params * db // bucket_bytes))
        base, rem = divmod(dp_params, n_chunks)
        for c in range(n_chunks):
            chunk_params = base + (1 if c < rem else 0)
            name = seg.name if n_chunks == 1 else f"{seg.name}#{c}"
            cur_bytes += chunk_params * db
            cur_params += chunk_params
            cur_names.append(name)
            cur_last = seg.order
            if cur_bytes >= bucket_bytes:
                close()
    if cur_names:
        close()
    return CommPlan(model=cfg.name, dtype_bytes=db, bucket_bytes=bucket_bytes,
                    expert_sharding=expert_sharding, segments=segments,
                    buckets=tuple(buckets),
                    total_grad_bytes=sum(b.bytes for b in buckets),
                    expert_grad_bytes=expert_bytes)


def total_dp_grad_bytes(cfg: ModelConfig, *, grad_dtype: Optional[str] = None,
                        expert_sharding: bool = False) -> int:
    """Total bytes the DP allreduce moves per iteration (no bucketing)."""
    db = grad_dtype_bytes(cfg, grad_dtype)
    total = 0
    for seg in grad_segments(cfg):
        total += seg.params + (0 if expert_sharding else seg.expert_params)
    return total * db
