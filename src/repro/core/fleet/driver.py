"""Fleet driver: one multi-tenant, open-loop simulation end to end.

Glues the fleet pieces onto the ``Simulator`` facade:

1. a :class:`FleetScenario` (topology config + tenants + arrival-timed jobs
   + quota policy),
2. an :class:`~repro.core.fleet.quota.AdmissionController` built from it,
3. one ``Simulator`` run with open-loop ``EV_JOB_ARRIVE`` activations,
4. optional per-job *uncontended* baseline runs (the same job alone on an
   idle fabric, no quotas) to turn JCTs into slowdowns,
5. a :class:`FleetResult` with per-job records, per-tenant aggregates and
   Jain's fairness index.

Baselines are cached by job shape — a training tenant re-running the same
placement every iteration costs one baseline simulation, not one per job.
"""
from __future__ import annotations

import dataclasses
import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..canary.simulator import Simulator
from ..canary.types import (Algo, AllreduceJob, SimConfig, SimResult,
                            TenantSpec)
from .metrics import (JobRecord, job_records, per_tenant_means,
                      per_tenant_percentiles, percentile, tenant_fairness)
from .quota import AdmissionController


@dataclass
class FleetScenario:
    """Everything one fleet run needs. ``jobs`` carry their own
    ``arrival_ns``/``tenant``; every job's tenant must appear in ``tenants``
    unless the quota policy is ``"none"``."""

    cfg: SimConfig
    tenants: List[TenantSpec]
    jobs: List[AllreduceJob]
    algo: Algo = Algo.CANARY
    n_trees: int = 1
    noise_hosts: Optional[List[int]] = None
    quota_policy: str = "weighted"     # none | equal | weighted
    overflow: str = "degrade"          # degrade | defer
    baselines: bool = True             # run uncontended JCTs for slowdown
    demand_slots: Optional[int] = None  # override the Little's-law demand


@dataclass
class FleetResult:
    """Outputs of one fleet run."""

    sim: SimResult
    jobs: List[JobRecord]
    admission: AdmissionController
    mean_jct_ns: float
    max_jct_ns: float
    mean_slowdown: Optional[float]     # None when baselines were off
    jain_fairness: float               # across tenants (see metrics.py)
    degraded_jobs: int
    deferred_jobs: int
    # fleet-wide JCT tail (linear-interpolation percentiles over all jobs);
    # NaN when no job finished. Per-tenant tails live in ``per_tenant``.
    p50_jct_ns: float = float("nan")
    p99_jct_ns: float = float("nan")
    per_tenant: Dict[int, dict] = field(default_factory=dict)
    # tenant -> [(t_ns, blocks_in_flight)], present only when the scenario's
    # cfg enabled telemetry (merged from the hub's per-app probe series)
    tenant_series: Dict[int, List[Tuple[float, float]]] = \
        field(default_factory=dict)
    # full run diagnosis (repro.core.telemetry.attribution.Diagnosis):
    # per-tenant cause attribution + hotspot ranking, present only when the
    # scenario's cfg enabled telemetry — a tenant's p99 traced to causes
    # and to the fabric links responsible (ARCHITECTURE.md §Diagnosis)
    diagnosis: Optional[object] = None
    # survivability aggregates (repro.core.faults), trivial without a fault
    # schedule (survival_rate 1.0, zero recovery): fraction of jobs that
    # completed, mean/max post-heal recovery tails, and the injected
    # fault/heal event log from the underlying ``SimResult``
    survival_rate: float = 1.0
    mean_recovery_ns: float = 0.0
    max_recovery_ns: float = 0.0
    fault_events: List[dict] = field(default_factory=list)

    @property
    def correct(self) -> bool:
        return self.sim.correct

    def summary(self) -> str:
        sd = f"{self.mean_slowdown:.2f}" if self.mean_slowdown is not None \
            else "n/a"
        return (f"jobs={len(self.jobs)} correct={self.correct} "
                f"mean_jct={self.mean_jct_ns/1e3:.1f}us "
                f"p50={self.p50_jct_ns/1e3:.1f}us "
                f"p99={self.p99_jct_ns/1e3:.1f}us slowdown={sd} "
                f"jain={self.jain_fairness:.3f} degraded={self.degraded_jobs} "
                f"deferred={self.deferred_jobs}")


class FleetDriver:
    """Build and run one :class:`FleetScenario`."""

    def __init__(self, scenario: FleetScenario):
        self.scenario = scenario
        self._baseline_cache: Dict[Tuple, float] = {}

    # ----------------------------------------------------------- construction
    def make_admission(self) -> AdmissionController:
        s = self.scenario
        return AdmissionController(s.tenants, policy=s.quota_policy,
                                   overflow=s.overflow, demand=s.demand_slots)

    def build_simulator(self) -> Simulator:
        s = self.scenario
        return Simulator(s.cfg, s.jobs, algo=s.algo, n_trees=s.n_trees,
                         noise_hosts=s.noise_hosts,
                         admission=self.make_admission())

    # ------------------------------------------------------------- baselines
    def _baseline_jct(self, job: AllreduceJob) -> float:
        """Uncontended JCT of ``job``: same fabric/algo, alone, at t=0, no
        quotas, no background noise."""
        s = self.scenario
        key = (tuple(sorted(job.participants)), job.data_bytes,
               job.collective, job.root)
        cached = self._baseline_cache.get(key)
        if cached is not None:
            return cached
        solo = dataclasses.replace(job, arrival_ns=0.0, tenant=-1)
        sim = Simulator(s.cfg, [solo], algo=s.algo, n_trees=s.n_trees)
        jct = sim.run().duration_ns
        self._baseline_cache[key] = jct
        return jct

    # -------------------------------------------------------------------- run
    def run(self) -> FleetResult:
        s = self.scenario
        sim = self.build_simulator()
        result = sim.run()
        baselines = None
        if s.baselines:
            baselines = {j.app: self._baseline_jct(j) for j in s.jobs
                         if len(j.participants) > 1}
        records = job_records(result, baselines)
        admission = sim.admission
        jcts = [r.jct_ns for r in records if r.jct_ns == r.jct_ns]
        slowdowns = [r.slowdown for r in records if r.slowdown is not None]
        mean_jct_by_tenant = per_tenant_means(records, "jct_ns")
        mean_sd_by_tenant = per_tenant_means(records, "slowdown")
        jct_pcts = per_tenant_percentiles(records, "jct_ns")
        sd_pcts = per_tenant_percentiles(records, "slowdown")
        per_tenant: Dict[int, dict] = {}
        for t in sorted({r.tenant for r in records}):
            trs = [r for r in records if r.tenant == t]
            jp = jct_pcts.get(t, {})
            sp = sd_pcts.get(t, {})
            per_tenant[t] = {
                "jobs": len(trs),
                "mean_jct_ns": mean_jct_by_tenant.get(t, float("nan")),
                "mean_slowdown": mean_sd_by_tenant.get(t),
                "p50_jct_ns": jp.get("p50", float("nan")),
                "p99_jct_ns": jp.get("p99", float("nan")),
                "p50_slowdown": sp.get("p50"),
                "p99_slowdown": sp.get("p99"),
                "degraded_jobs": sum(1 for r in trs if not r.admitted),
                "fallback_blocks": sum(r.fallback_blocks for r in trs),
            }
        diag = None
        if sim.telemetry is not None:
            # lazy import: the fleet layer only pulls in the diagnosis
            # machinery when a run actually recorded telemetry
            from ..telemetry import diagnose, view_of
            diag = diagnose(view_of(sim.telemetry))
        return FleetResult(
            sim=result,
            jobs=records,
            admission=admission,
            mean_jct_ns=statistics.mean(jcts) if jcts else float("nan"),
            max_jct_ns=max(jcts) if jcts else float("nan"),
            mean_slowdown=statistics.mean(slowdowns) if slowdowns else None,
            jain_fairness=tenant_fairness(records),
            degraded_jobs=sum(1 for r in records if not r.admitted),
            deferred_jobs=len(admission.deferrals) if admission else 0,
            p50_jct_ns=percentile(jcts, 50.0) if jcts else float("nan"),
            p99_jct_ns=percentile(jcts, 99.0) if jcts else float("nan"),
            per_tenant=per_tenant,
            tenant_series=(tenant_remaining_series(sim, s.jobs)
                           if sim.telemetry is not None else {}),
            diagnosis=diag,
            survival_rate=(sum(result.survived.values())
                           / len(result.survived)
                           if result.survived else 1.0),
            mean_recovery_ns=(statistics.mean(result.fault_recovery_ns
                                              .values())
                              if result.fault_recovery_ns else 0.0),
            max_recovery_ns=(max(result.fault_recovery_ns.values())
                             if result.fault_recovery_ns else 0.0),
            fault_events=list(result.fault_events),
        )


def tenant_remaining_series(sim, jobs) -> Dict[int, List[Tuple[float, float]]]:
    """Merge the telemetry hub's per-app ``app/{app}/remaining`` probe series
    into one step-summed blocks-in-flight series per tenant.

    Each app series is a step function (delta-encoded); the merge walks the
    union of their timestamps carrying each app's last value, so the sum is
    exact at every recorded point. The merged series are also written back
    into the hub registry as ``tenant/{t}/remaining`` so the exporters emit
    them alongside the raw per-app tracks."""
    reg = sim.telemetry.registry
    by_tenant: Dict[int, list] = {}
    for j in jobs:
        ts = reg.series.get(f"app/{j.app}/remaining")
        if ts is None:
            continue
        t = j.tenant if j.tenant >= 0 else j.app
        by_tenant.setdefault(t, []).append(ts)
    out: Dict[int, List[Tuple[float, float]]] = {}
    for t, series in sorted(by_tenant.items()):
        stamps = sorted({tt for ts in series for tt in ts.t})
        idx = [0] * len(series)
        last = [0.0] * len(series)
        merged: List[Tuple[float, float]] = []
        for tt in stamps:
            for k, ts in enumerate(series):
                while idx[k] < len(ts.t) and ts.t[idx[k]] <= tt:
                    last[k] = ts.v[idx[k]]
                    idx[k] += 1
            total = sum(last)
            if not merged or merged[-1][1] != total:
                merged.append((tt, total))
        out[t] = merged
        hub_ts = reg.ts(f"tenant/{t}/remaining")
        for tt, v in merged:
            hub_ts.record(tt, v)
    return out


def run_fleet(scenario: FleetScenario) -> FleetResult:
    """One-call convenience wrapper."""
    return FleetDriver(scenario).run()
