"""Fleet subsystem: multi-tenant in-network allreduce at datacenter scale.

Layered on the :class:`~repro.core.canary.Simulator` facade (see
``ARCHITECTURE.md``, "Fleet subsystem"):

* :mod:`~.arrivals` — open-loop workload generation (Poisson / periodic
  training iterations / bursty traces) feeding ``EV_JOB_ARRIVE`` events.
* :mod:`~.quota`    — **enforced** descriptor-table budgets: per-tenant slot
  regions derived from the §3.2.2 occupancy model, weighted sharing, and
  admission control that degrades (§3.3 host-based path) or defers jobs.
* :mod:`~.metrics`  — per-job JCT / slowdown and Jain's fairness index.
* :mod:`~.driver`   — :class:`FleetDriver`: scenario in, :class:`FleetResult`
  (with uncontended-baseline slowdowns) out.

The layer is pay-for-what-you-use: a run without an admission controller —
or with ``quota_policy="none"`` — is bit-identical to the plain simulator
(pinned by ``tests/fleet/test_golden_compat.py``).
"""
from ..canary.types import AllreduceJob, TenantSpec
from .arrivals import (bursty_arrivals, make_jobs, periodic_arrivals,
                       poisson_arrivals, trace_arrivals)
from .driver import FleetDriver, FleetResult, FleetScenario, run_fleet
from .metrics import (JobRecord, jain_index, job_records, per_tenant_means,
                      tenant_fairness)
from .quota import (ADMIT, DEFER, DEGRADE, AdmissionController, demand_slots,
                    model_diameter)

__all__ = [
    "ADMIT", "DEFER", "DEGRADE", "AdmissionController", "AllreduceJob",
    "FleetDriver", "FleetResult", "FleetScenario", "JobRecord", "TenantSpec",
    "bursty_arrivals", "demand_slots", "jain_index", "job_records",
    "make_jobs", "model_diameter", "per_tenant_means", "periodic_arrivals",
    "poisson_arrivals", "run_fleet", "tenant_fairness", "trace_arrivals",
]
