"""Per-job QoS metrics for fleet runs: JCT, slowdown, Jain fairness.

``SimResult`` carries the raw lifecycle stamps (submit/start/finish per app);
this module turns them into the numbers multi-tenant papers compare on:

* **JCT** — job completion time, ``finish - submit`` (includes any deferral
  wait imposed by admission control).
* **slowdown** — JCT divided by the same job's *uncontended* JCT (alone on
  the fabric, no quotas); 1.0 means sharing cost the tenant nothing.
* **Jain's fairness index** — ``(Σx)² / (n·Σx²)`` over per-tenant mean
  slowdowns: 1.0 when every tenant suffers equally, ``1/n`` when one tenant
  absorbs all the contention.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from ..canary.types import SimResult


def jain_index(values: Sequence[float]) -> float:
    """Jain's fairness index over ``values`` (1.0 = perfectly fair)."""
    vals = [float(v) for v in values]
    if not vals:
        return 1.0
    s = sum(vals)
    s2 = sum(v * v for v in vals)
    if s2 <= 0.0:
        return 1.0
    return (s * s) / (len(vals) * s2)


@dataclass(frozen=True)
class JobRecord:
    """One job's lifecycle, flattened from a fleet ``SimResult``."""

    app: int
    tenant: int
    submit_ns: float
    start_ns: float        # admission time (> submit when the job was deferred)
    finish_ns: float
    jct_ns: float
    admitted: bool         # False: degraded to the §3.3 host-based path
    fallback_blocks: int
    slowdown: Optional[float] = None  # vs uncontended run; None w/o baseline

    @property
    def wait_ns(self) -> float:
        """Queueing delay imposed by admission control."""
        return self.start_ns - self.submit_ns


def job_records(result: SimResult,
                baselines: Optional[Dict[int, float]] = None
                ) -> List[JobRecord]:
    """Flatten ``result``'s per-job stamps; ``baselines`` maps app ->
    uncontended JCT in ns (for slowdown)."""
    out = []
    for app in sorted(result.job_submit_ns):
        submit = result.job_submit_ns[app]
        finish = result.job_finish_ns.get(app, float("nan"))
        jct = finish - submit
        base = (baselines or {}).get(app)
        out.append(JobRecord(
            app=app,
            tenant=result.tenant_of.get(app, app),
            submit_ns=submit,
            start_ns=result.job_start_ns.get(app, submit),
            finish_ns=finish,
            jct_ns=jct,
            admitted=result.job_admitted.get(app, True),
            fallback_blocks=result.app_fallback_blocks.get(app, 0),
            slowdown=(jct / base) if base else None,
        ))
    return out


def per_tenant_means(records: Sequence[JobRecord],
                     attr: str = "slowdown") -> Dict[int, float]:
    """tenant -> mean of ``attr`` over its jobs (jobs missing the attr are
    skipped; tenants with no usable jobs are dropped)."""
    by_tenant: Dict[int, List[float]] = {}
    for r in records:
        v = getattr(r, attr)
        if v is None or v != v:
            continue
        by_tenant.setdefault(r.tenant, []).append(float(v))
    return {t: statistics.mean(vs) for t, vs in by_tenant.items()}


def percentile(values: Sequence[float], q: float) -> float:
    """The ``q``-th percentile (0-100) with linear interpolation between
    closest ranks — numpy's default method, hand-rolled so the fleet layer
    stays dependency-free. Raises on an empty sequence."""
    if not values:
        raise ValueError("percentile of empty sequence")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    vs = sorted(float(v) for v in values)
    if len(vs) == 1:
        return vs[0]
    rank = (q / 100.0) * (len(vs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(vs) - 1)
    frac = rank - lo
    return vs[lo] * (1.0 - frac) + vs[hi] * frac


def per_tenant_percentiles(records: Sequence[JobRecord],
                           attr: str = "jct_ns",
                           qs: Sequence[float] = (50.0, 99.0)
                           ) -> Dict[int, Dict[str, float]]:
    """tenant -> {"p50": ..., "p99": ...} over ``attr`` of its jobs — the
    user-facing latency numbers a serving fleet is judged on (a tenant's
    p99 JCT is what its own SLO sees; the mean hides the tail). Jobs
    missing the attr are skipped, tenants with no usable jobs dropped."""
    by_tenant: Dict[int, List[float]] = {}
    for r in records:
        v = getattr(r, attr)
        if v is None or v != v:
            continue
        by_tenant.setdefault(r.tenant, []).append(float(v))
    return {t: {f"p{q:g}": percentile(vs, q) for q in qs}
            for t, vs in by_tenant.items()}


def tenant_fairness(records: Sequence[JobRecord]) -> float:
    """Jain's index over per-tenant mean slowdowns (falls back to mean JCTs
    when no baselines were run)."""
    means = per_tenant_means(records, "slowdown")
    if not means:
        means = per_tenant_means(records, "jct_ns")
    return jain_index(list(means.values()))
