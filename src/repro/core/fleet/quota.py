"""Enforced switch-memory quotas + admission control (§3.2.2, §3.3).

The paper argues descriptor memory is the scarce switch resource bounding how
many tenants can aggregate in-network at once. The seed repo had the analytic
:class:`~repro.core.canary.memory_model.OccupancyModel` but the dataplane
never enforced it. This module closes that loop:

* :func:`demand_slots` converts the Little's-law occupancy bound into the
  number of descriptor slots one running job needs per switch.
* :class:`AdmissionController` carves the descriptor table into per-tenant
  slot *regions* (policy-weighted) and, at every job arrival, converts the
  tenant's region into a concurrency budget ``region_slots // demand``.

For CANARY, enforcement is physical, not advisory: an admitted app's
descriptors hash only within its tenant's region
(``CanaryStrategy.slot_of``), so a tenant can never occupy more slots per
switch than its quota — overload inside the region collides and bypasses
(§3.2.1) rather than stealing neighbours' slots. Jobs beyond the concurrency
budget are **degraded** to the §3.3 host-based path (bypass packets, leader
unicasts the result) or **deferred** until a running job of the same tenant
finishes.

STATIC_TREE has no slot-hashed table (descriptors follow the configured
plan, which has no §3.2.1 collision/bypass escape hatch a full region could
fall back on), so for it the quota acts as the admission-level concurrency
budget only — the per-switch footprint of an *admitted* static-tree job is
bounded by its blocks in flight, not by the region. Host-based strategies
(``uses_switch_memory = False``, e.g. RING) consume no descriptors and are
always admitted without a region.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Set, Tuple

from ..canary.memory_model import OccupancyModel, model_for
from ..canary.types import SimConfig, TenantSpec

# admission decisions (returned by AdmissionController.on_job_arrival)
ADMIT = "admit"
DEGRADE = "degrade"
DEFER = "defer"

POLICIES = ("none", "equal", "weighted")
OVERFLOW = ("degrade", "defer")


def model_diameter(cfg: SimConfig) -> int:
    """Switch-depth used for the occupancy model of ``cfg``'s topology."""
    return 3 if cfg.topology == "three_tier" else 2


def demand_slots(cfg: SimConfig,
                 model: Optional[OccupancyModel] = None) -> int:
    """Descriptor slots one in-network job needs per switch.

    Little's law (§3.2.2): ``occupancy_bytes`` of descriptor state are in
    flight per switch per allreduce; at one MTU-sized block per descriptor
    that is ``occupancy_bytes / mtu_bytes`` slots, independent of the reduced
    data size and the host count.
    """
    if model is None:
        model = model_for(cfg, diameter=model_diameter(cfg))
    return max(1, math.ceil(model.occupancy_bytes / cfg.mtu_bytes))


class AdmissionController:
    """Per-tenant descriptor-table budgets, installed on a ``Simulator``.

    Pass as ``Simulator(..., admission=controller)``. The facade calls
    :meth:`on_job_arrival` when a job activates (t=0 or its ``EV_JOB_ARRIVE``)
    and :meth:`on_job_done` when its last block completes. ``policy='none'``
    admits everything with no regions — attached but inert, which is what the
    golden-compat tests pin.
    """

    def __init__(self, tenants: List[TenantSpec], *, policy: str = "weighted",
                 overflow: str = "degrade",
                 demand: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown quota policy {policy!r}; have {POLICIES}")
        if overflow not in OVERFLOW:
            raise ValueError(f"unknown overflow action {overflow!r}; "
                             f"have {OVERFLOW}")
        seen = [t.tenant for t in tenants]
        if len(set(seen)) != len(seen):
            raise ValueError(f"duplicate tenant ids: {sorted(seen)}")
        self.tenants = list(tenants)
        self.policy = policy
        self.overflow = overflow
        self.demand_override = demand
        # filled by attach()
        self.demand = 0
        self.regions: Dict[int, Tuple[int, int]] = {}   # tenant -> (off, size)
        self.caps: Dict[int, int] = {}                  # tenant -> max concurrent
        # runtime state
        self.running: Dict[int, Set[int]] = {}          # tenant -> running apps
        self.deferred: Dict[int, List[int]] = {}        # tenant -> FIFO of apps
        self.decisions: Dict[int, str] = {}             # app -> final decision
        self.deferrals: Dict[int, int] = {}             # app -> times deferred

    # ------------------------------------------------------------------ setup
    def attach(self, sim) -> "AdmissionController":
        """Derive per-tenant regions/budgets from ``sim.cfg`` (called by the
        ``Simulator`` constructor)."""
        cfg = sim.cfg
        self.demand = self.demand_override or demand_slots(cfg)
        self.regions.clear()
        self.caps.clear()
        # reset runtime state so one controller can serve consecutive runs
        self.running.clear()
        self.deferred.clear()
        self.decisions.clear()
        self.deferrals.clear()
        if self.policy == "none":
            return self
        total_w = sum(t.weight for t in self.tenants)
        if total_w <= 0:
            raise ValueError("tenant weights must sum > 0")
        offset = 0
        for t in sorted(self.tenants, key=lambda t: t.tenant):
            share = (t.weight / total_w) if self.policy == "weighted" \
                else 1.0 / len(self.tenants)
            size = max(1, int(cfg.table_size * share))
            size = min(size, cfg.table_size - offset)
            if size <= 0:
                raise ValueError("descriptor table too small for the tenant "
                                 f"set (table_size={cfg.table_size})")
            self.regions[t.tenant] = (offset, size)
            self.caps[t.tenant] = size // self.demand
            offset += size
        return self

    # ------------------------------------------------------------ admission
    def on_job_arrival(self, sim, app: int, job) -> str:
        tenant = sim.tenant_of[app]
        if self.policy == "none" or not sim.strategy.uses_switch_memory:
            self.decisions[app] = ADMIT
            return ADMIT
        if tenant not in self.regions:
            raise ValueError(f"app {app} belongs to unknown tenant {tenant}; "
                             f"configured: {sorted(self.regions)}")
        running = self.running.setdefault(tenant, set())
        if len(running) < self.caps[tenant]:
            running.add(app)
            sim.slot_regions[app] = self.regions[tenant]
            self.decisions[app] = ADMIT
            return ADMIT
        if self.overflow == "defer" and running:
            # a running job of this tenant will finish and retry us; with an
            # empty running set (cap == 0) deferring would deadlock, so the
            # job degrades instead
            self.deferred.setdefault(tenant, []).append(app)
            self.deferrals[app] = self.deferrals.get(app, 0) + 1
            self.decisions[app] = DEFER
            return DEFER
        self.decisions[app] = DEGRADE
        return DEGRADE

    def on_job_done(self, sim, app: int) -> None:
        if self.policy == "none":
            return
        tenant = sim.tenant_of.get(app, app)
        running = self.running.get(tenant)
        if running is None or app not in running:
            return  # degraded/deferred jobs held no slots
        running.discard(app)
        queue = self.deferred.get(tenant)
        if queue:
            # exactly one slot freed -> retry exactly one deferred job
            sim._activate_job(queue.pop(0))

    def release(self, sim, app: int) -> None:
        """Free a still-running app's quota slot mid-run (repro.core.faults):
        when a fault escalates ``app`` to the host-based fallback it stops
        consuming switch memory, so its slot can re-admit one deferred job
        immediately instead of waiting for the degraded app to finish.
        ``on_job_done`` later finds the slot already released and no-ops."""
        if self.policy == "none":
            return
        tenant = sim.tenant_of.get(app, app)
        running = self.running.get(tenant)
        if running is None or app not in running:
            return
        running.discard(app)
        sim.slot_regions.pop(app, None)
        queue = self.deferred.get(tenant)
        if queue:
            sim._activate_job(queue.pop(0))

    # ------------------------------------------------------------ inspection
    def degraded_apps(self) -> Set[int]:
        return {a for a, d in self.decisions.items() if d == DEGRADE}

    def region_of(self, tenant: int) -> Optional[Tuple[int, int]]:
        return self.regions.get(tenant)

    def summary(self) -> str:
        per = " ".join(
            f"t{t.tenant}[slots={self.regions.get(t.tenant, (0, 0))[1]} "
            f"cap={self.caps.get(t.tenant, 'inf')}]"
            for t in sorted(self.tenants, key=lambda t: t.tenant))
        return (f"policy={self.policy} overflow={self.overflow} "
                f"demand={self.demand} slots/job {per}")
