"""Open-loop workload generation: when do tenants submit jobs?

The seed simulator only ran closed-loop fleets — every job present at t=0.
Datacenter tenants submit *over time* (Flare, Segal et al.), so the fleet
subsystem generates arrival times and turns them into
:class:`~repro.core.canary.types.AllreduceJob` lists whose ``arrival_ns``
becomes a first-class engine event (``EV_JOB_ARRIVE``).

Three arrival processes cover the paper-adjacent scenarios:

* :func:`poisson_arrivals`  — memoryless open-loop submissions (the classic
  datacenter arrival model).
* :func:`periodic_arrivals` — a training tenant issuing one allreduce per
  iteration, with optional jitter.
* :func:`bursty_arrivals`   — trace-like bursts: ``burst_size`` near-simultaneous
  submissions separated by quiet gaps.

All generators take an explicit ``random.Random`` so fleet scenarios stay
bit-reproducible, and return sorted absolute times in nanoseconds.
"""
from __future__ import annotations

import random
from typing import List, Optional, Sequence

from ..canary.types import AllreduceJob, TenantSpec


def poisson_arrivals(n_jobs: int, mean_interarrival_ns: float, *,
                     rng: random.Random, start_ns: float = 0.0) -> List[float]:
    """``n_jobs`` Poisson-process submit times (exponential interarrivals)."""
    if n_jobs < 0 or mean_interarrival_ns <= 0:
        raise ValueError("need n_jobs >= 0 and mean_interarrival_ns > 0")
    t, out = start_ns, []
    for _ in range(n_jobs):
        t += rng.expovariate(1.0 / mean_interarrival_ns)
        out.append(t)
    return out


def periodic_arrivals(n_jobs: int, period_ns: float, *, start_ns: float = 0.0,
                      jitter_ns: float = 0.0,
                      rng: Optional[random.Random] = None) -> List[float]:
    """Training-iteration arrivals: one job per ``period_ns``, plus uniform
    jitter in ``[0, jitter_ns)`` (requires ``rng`` when jitter is on)."""
    if jitter_ns > 0.0 and rng is None:
        raise ValueError("jitter_ns > 0 needs an rng")
    out = []
    for i in range(n_jobs):
        t = start_ns + i * period_ns
        if jitter_ns > 0.0:
            t += rng.random() * jitter_ns
        out.append(t)
    return sorted(out)


def bursty_arrivals(n_bursts: int, burst_size: int, burst_gap_ns: float, *,
                    start_ns: float = 0.0,
                    intra_burst_ns: float = 0.0) -> List[float]:
    """Trace-driven-style bursts: ``burst_size`` jobs ``intra_burst_ns`` apart,
    bursts separated by ``burst_gap_ns``."""
    out = []
    for b in range(n_bursts):
        t0 = start_ns + b * burst_gap_ns
        out.extend(t0 + j * intra_burst_ns for j in range(burst_size))
    return out


def trace_arrivals(times_ns: Sequence[float]) -> List[float]:
    """Explicit submit times (e.g. replayed from a production trace)."""
    out = sorted(float(t) for t in times_ns)
    if out and out[0] < 0:
        raise ValueError("arrival times must be >= 0")
    return out


def make_jobs(tenant: TenantSpec, arrivals: Sequence[float],
              host_pool: Sequence[int], hosts_per_job: int,
              data_bytes: int, *, rng: random.Random, app_base: int,
              fixed_placement: bool = True,
              collective: str = "allreduce") -> List[AllreduceJob]:
    """Turn arrival times into a tenant's job list.

    ``fixed_placement=True`` models a training tenant: every iteration runs
    over the same ``hosts_per_job``-host sample from the tenant's pool.
    ``False`` re-samples placement per job (batch/inference tenants). App ids
    are ``app_base, app_base+1, ...`` — the caller keeps them fleet-unique.
    """
    if hosts_per_job < 2 or hosts_per_job > len(host_pool):
        raise ValueError(f"hosts_per_job={hosts_per_job} outside "
                         f"[2, {len(host_pool)}] for tenant {tenant.tenant}")
    placement = rng.sample(list(host_pool), hosts_per_job)
    jobs = []
    for i, t in enumerate(arrivals):
        if not fixed_placement:
            placement = rng.sample(list(host_pool), hosts_per_job)
        jobs.append(AllreduceJob(app=app_base + i, participants=list(placement),
                                 data_bytes=data_bytes, collective=collective,
                                 arrival_ns=float(t), tenant=tenant.tenant))
    return jobs
