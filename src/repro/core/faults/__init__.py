"""Fault injection: mid-run failures as scheduled engine events.

The seed simulator could only fail a switch *statically* (``switch_fail_ns``
pushes one ``EV_FAIL_SWITCH`` before the run starts, and the switch never
recovers). This package turns failure into a first-class, schedulable event
stream: ``SimConfig(faults=[...])`` builds a :class:`FaultSchedule` that
injects ``EV_FAULT`` / ``EV_HEAL`` events (engine kinds 15/16, dispatched in
the uncounted orchestration band, so the golden ``events`` field never moves)
at the configured times.

Registered fault kinds (string-keyed, like transports and backends)::

    {"kind": "switch_crash", "target": 5, "at_ns": 2e3, "heal_ns": 5e4}
    {"kind": "link_down",    "target": "leaf0->spine3", "at_ns": ..., "heal_ns": ...}
    {"kind": "link_degrade", "target": 17, "factor": 0.1, "at_ns": ..., "heal_ns": ...}
    {"kind": "link_flap",    "target": ..., "at_ns": ..., "down_ns": ...,
                             "period_ns": ..., "cycles": 4}
    {"kind": "host_slow",    "target": 9, "at_ns": ..., "heal_ns": ...}

Specs are FLAT, JSON-able dicts so sweep work items survive the
``asdict -> SimConfig(**cfg)`` round trip. Link targets are either an index
into ``Topology.all_links()`` or a name from ``Topology.link_names()``.

Failure model
-------------
* **switch_crash** marks the switch failed AND flushes its dataplane
  (descriptor table, slot map, armed timers — the SRAM is gone), then
  poisons every link *into* the switch so traffic stops being offered to it.
  Packets already in flight still arrive and drop at the failed-switch check
  (charged to ``switch_fail``, exactly like the legacy path). Healing
  un-poisons the links and lets the switch admit descriptors again.
* **link_down** poisons the link (``busy_until`` = ``LINK_DOWN_HORIZON``,
  see ``topology.py``) and *drains its staged-arrival FIFO*: everything
  behind the head is popped and charged as dropped; the head entry — which
  owns the link's armed heap entry — is neutralized in place (packet slot
  set to ``None``; the engine skips such pops), preserving the
  one-heap-entry-per-busy-link invariant.
* **link_degrade** scales ``bytes_per_ns`` by ``factor`` (already-queued
  serialization commitments keep their old timestamps — only new sends see
  the degraded rate), restoring the original rate on heal.
* **link_flap** is link_down on a timer: down for ``down_ns`` out of every
  ``period_ns``, ``cycles`` times.
* **host_slow** parks the host's send pump (the straggler model §5.2.5, but
  scheduled and recoverable); the heal re-pumps it.

Graceful degradation contract
-----------------------------
LB policies treat poisoned links as infinite backlog and route around them
(including the ECMP/flowlet fast paths — a dead group member is removed, as
on real switches). A block that exhausts ``max_generations`` while a fault
is live escalates its whole app to the §3.3 host-based fallback
(:meth:`FaultSchedule.escalate_app`): bypass packets, no switch memory, and
the app's quota slot is released so deferred jobs can re-admit. With the
``gbn`` transport every reduction stays *exact* under any fault schedule —
the survivability tests pin this invariant; without it, losses are measured
(``drop_causes``), never hidden.

Everything here is pay-for-what-you-use: no schedule -> ``Simulator.faults``
is ``None`` and every hook site in the hot layers reduces to one guarded
identity check (or one float compare against the poison horizon on an
already-loaded ``busy_until``) — the goldens replay bit-for-bit.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Type

from ..canary.engine import EV_FAULT, EV_HEAL
from ..canary.topology import LINK_DOWN_HORIZON, Link

__all__ = ["Fault", "FaultSchedule", "FAULTS", "register_fault"]


class Fault:
    """One scheduled failure. Subclasses implement :meth:`apply` /
    :meth:`heal`; the schedule owns timing and bookkeeping."""

    kind: str = ""

    def __init__(self, schedule: "FaultSchedule", spec: dict):
        self.schedule = schedule
        self.spec = spec
        self.target = spec.get("target")
        self.at_ns = float(spec["at_ns"])
        heal = spec.get("heal_ns")
        self.heal_ns: Optional[float] = None if heal is None else float(heal)
        if self.heal_ns is not None and self.heal_ns <= self.at_ns:
            raise ValueError(f"{self.kind}: heal_ns must be > at_ns ({spec})")

    def apply(self, sim) -> None:
        raise NotImplementedError

    def heal(self, sim) -> None:
        raise NotImplementedError

    # flaps override: the next EV_FAULT time after a heal, or None
    def next_cycle_ns(self, now: float) -> Optional[float]:
        return None


FAULTS: Dict[str, Type[Fault]] = {}


def register_fault(name: str):
    """Class decorator: make a fault kind selectable via spec dicts."""

    def deco(cls: Type[Fault]) -> Type[Fault]:
        cls.kind = name
        FAULTS[name] = cls
        return cls

    return deco


class _LinkFaultMixin:
    """Shared link-target resolution (index or link_names() name)."""

    def resolve_link(self, sim) -> Tuple[Link, int]:
        net = sim.net
        t = self.target
        if isinstance(t, str):
            names = net.link_names()
            try:
                idx = names.index(t)
            except ValueError:
                raise ValueError(
                    f"{self.kind}: unknown link name {t!r}") from None
        else:
            idx = int(t)
        links = net.all_links()
        if not 0 <= idx < len(links):
            raise ValueError(f"{self.kind}: link index {idx} out of range "
                             f"(fabric has {len(links)} links)")
        return links[idx], idx


@register_fault("switch_crash")
class SwitchCrash(Fault):
    """Crash + (optional) recovery of one switch."""

    def __init__(self, schedule, spec):
        super().__init__(schedule, spec)
        self._poisoned: List[Link] = []

    def apply(self, sim) -> None:
        sw = int(self.target)
        if not 0 <= sw < sim.net.num_switches:
            raise ValueError(f"switch_crash: switch {sw} out of range")
        sim.switch.crash_switch(sw)
        sched = self.schedule
        self._poisoned = []
        for link in sim.net.links_into(sw):
            if sched.poison(link, "switch_fail", sw):
                self._poisoned.append(link)

    def heal(self, sim) -> None:
        sim.switch.heal_switch(int(self.target))
        sched = self.schedule
        for link in self._poisoned:
            sched.unpoison(link)
        self._poisoned = []


@register_fault("link_down")
class LinkDown(Fault, _LinkFaultMixin):
    def __init__(self, schedule, spec):
        super().__init__(schedule, spec)
        self._link: Optional[Link] = None

    def apply(self, sim) -> None:
        link, _ = self.resolve_link(sim)
        # claim the link only if we poisoned it — under overlapping faults
        # the first claimant's heal revives it
        self._link = link if self.schedule.poison(link, "link_down", -1) \
            else None

    def heal(self, sim) -> None:
        if self._link is not None:
            self.schedule.unpoison(self._link)
            self._link = None


@register_fault("link_degrade")
class LinkDegrade(Fault, _LinkFaultMixin):
    """Bandwidth brown-out: scale the link rate by ``factor`` (0 < f < 1)."""

    def __init__(self, schedule, spec):
        super().__init__(schedule, spec)
        self.factor = float(spec.get("factor", 0.1))
        if not 0.0 < self.factor < 1.0:
            raise ValueError("link_degrade: factor must be in (0, 1)")
        self._link: Optional[Link] = None
        self._orig = 0.0

    def apply(self, sim) -> None:
        link, _ = self.resolve_link(sim)
        self._link = link
        self._orig = link.bytes_per_ns
        link.bytes_per_ns = self._orig * self.factor

    def heal(self, sim) -> None:
        if self._link is not None:
            self._link.bytes_per_ns = self._orig
            self._link = None


@register_fault("link_flap")
class LinkFlap(LinkDown):
    """link_down on a duty cycle: down ``down_ns`` out of every
    ``period_ns``, ``cycles`` times (heal_ns is derived, not given)."""

    def __init__(self, schedule, spec):
        spec = dict(spec)
        self.down_ns = float(spec.get("down_ns", 0.0))
        self.period_ns = float(spec.get("period_ns", 0.0))
        self.cycles = int(spec.get("cycles", 1))
        if not (0.0 < self.down_ns < self.period_ns):
            raise ValueError("link_flap needs 0 < down_ns < period_ns")
        if self.cycles < 1:
            raise ValueError("link_flap needs cycles >= 1")
        spec["heal_ns"] = float(spec["at_ns"]) + self.down_ns
        super().__init__(schedule, spec)
        self._cycles_left = self.cycles

    def next_cycle_ns(self, now: float) -> Optional[float]:
        self._cycles_left -= 1
        if self._cycles_left <= 0:
            return None
        # next down edge: one period after the previous one
        nxt = self.at_ns + self.period_ns
        self.at_ns = nxt
        self.heal_ns = nxt + self.down_ns
        return nxt


@register_fault("host_slow")
class HostSlow(Fault):
    """A recoverable straggler: the host's pump is parked until the heal."""

    def apply(self, sim) -> None:
        host = int(self.target)
        if not 0 <= host < sim.cfg.num_hosts:
            raise ValueError(f"host_slow: host {host} out of range")
        self.schedule.paused_hosts.add(host)

    def heal(self, sim) -> None:
        host = int(self.target)
        self.schedule.paused_hosts.discard(host)
        sim.hostproto.schedule_pump(host, sim.now)


class FaultSchedule:
    """Owns the run's fault set: injects the events, poisons/heals links,
    charges fault drops by cause, and computes survivability metrics."""

    def __init__(self, sim):
        self.sim = sim
        self.faults: List[Fault] = []
        for spec in sim.cfg.faults:
            try:
                cls = FAULTS[spec["kind"]]
            except KeyError:
                raise ValueError(
                    f"unknown fault kind {spec.get('kind')!r}; "
                    f"registered: {sorted(FAULTS)}") from None
            self.faults.append(cls(self, spec))
        # Link -> drop cause while poisoned (Links hash by identity)
        self._down: Dict[Link, str] = {}
        self._where: Dict[Link, int] = {}
        self.drop_counts: Dict[str, int] = {}
        self.paused_hosts: set = set()
        self.events: List[dict] = []          # flat fault/heal/escalate log
        self.escalated: set = set()
        self._n_active = 0
        # fault-active windows: [start, end]; end is None while open
        self._windows: List[List[Optional[float]]] = []
        self._open: Dict[int, int] = {}       # fault idx -> window idx

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Arm the schedule (called by the facade after job setup)."""
        sim = self.sim
        sim.hostproto._fault_paused = self.paused_hosts
        for i, f in enumerate(self.faults):
            sim.engine.push(f.at_ns, EV_FAULT, i, 0, self)

    def handle_fault(self, a: int, _b: int, _c: object) -> None:
        sim = self.sim
        f = self.faults[a]
        f.apply(sim)
        now = sim.now
        self._n_active += 1
        self._open[a] = len(self._windows)
        self._windows.append([now, None])
        self.events.append(dict(kind=f.kind, target=f.target, t_ns=now,
                                phase="fault"))
        tel = sim.telemetry
        if tel is not None:
            tel.on_fault(f.kind, f.target, True)
        if f.heal_ns is not None:
            sim.engine.push(f.heal_ns, EV_HEAL, a, 0, self)

    def handle_heal(self, a: int, _b: int, _c: object) -> None:
        sim = self.sim
        f = self.faults[a]
        f.heal(sim)
        now = sim.now
        self._n_active -= 1
        w = self._open.pop(a, None)
        if w is not None:
            self._windows[w][1] = now
        self.events.append(dict(kind=f.kind, target=f.target, t_ns=now,
                                phase="heal"))
        tel = sim.telemetry
        if tel is not None:
            tel.on_fault(f.kind, f.target, False)
        nxt = f.next_cycle_ns(now)
        if nxt is not None:
            sim.engine.push(nxt, EV_FAULT, a, 0, self)

    def any_active(self) -> bool:
        return self._n_active > 0

    # --------------------------------------------------------- link poisoning
    def poison(self, link: Link, cause: str, where: int) -> bool:
        """Mark ``link`` dead and drain its staged FIFO. Returns False when
        the link is already poisoned (by an overlapping fault) — the caller
        must then not claim it for healing."""
        if link in self._down:
            return False
        self._down[link] = cause
        self._where[link] = where
        link.busy_until = LINK_DOWN_HORIZON
        q = link.inflight
        if q:
            # everything behind the head is dropped outright; the head owns
            # the link's armed heap entry, so it is neutralized in place and
            # the engine skips its (packet-less) pop
            while len(q) > 1:
                entry = q.pop()
                if entry[2] is not None:
                    self._charge(entry[2], cause, where)
            head = q[0]
            if head[2] is not None:
                q[0] = (head[0], head[1], None)
                self._charge(head[2], cause, where)
        return True

    def unpoison(self, link: Link) -> None:
        if self._down.pop(link, None) is None:
            return
        self._where.pop(link, None)
        # the backlog that existed at fault time was dropped; the healed
        # link comes back idle
        link.busy_until = self.sim.now

    def on_tx_down(self, link: Link, pkt, where: int) -> None:
        """A send was offered to a poisoned link (tx hot paths detect the
        horizon on the already-loaded ``busy_until`` and call here)."""
        self._charge(pkt, self._down.get(link, "link_down"),
                     self._where.get(link, where))

    def _charge(self, pkt, cause: str, where: int) -> None:
        sim = self.sim
        sim.dropped += 1
        self.drop_counts[cause] = self.drop_counts.get(cause, 0) + 1
        tel = sim.telemetry
        if tel is not None:
            tel.on_drop(cause, where)
        if not pkt.multicast:
            sim.pool.free(pkt)

    # ------------------------------------------------------------- degradation
    def escalate_app(self, app: int) -> None:
        """Generation-cap escalation (§3.3): flip ``app`` to the host-based
        fallback mid-run. Later blocks send bypass packets (no switch
        memory), the strategy's cached per-app constants are rebuilt, and
        the app's quota slot is released for deferred jobs."""
        sim = self.sim
        if app in sim.bypass_apps:
            return
        sim.bypass_apps.add(app)
        self.escalated.add(app)
        inv = getattr(sim.strategy, "invalidate_send_cache", None)
        if inv is not None:
            inv(app)
        if sim.admission is not None:
            sim.admission.release(sim, app)
        self.events.append(dict(kind="escalate", target=app, t_ns=sim.now,
                                phase="escalate"))

    # ------------------------------------------------------------ end of run
    def _union(self, t_end: float) -> List[Tuple[float, float]]:
        spans = sorted((s, e if e is not None else t_end)
                       for s, e in self._windows)
        out: List[List[float]] = []
        for s, e in spans:
            if out and s <= out[-1][1]:
                if e > out[-1][1]:
                    out[-1][1] = e
            else:
                out.append([s, e])
        return [(s, e) for s, e in out]

    def finish(self) -> Tuple[Dict[int, float], Dict[int, float],
                              Dict[int, bool]]:
        """Per-app survivability metrics: fault exposure, recovery tail and
        survival (see the ``SimResult`` field docs)."""
        sim = self.sim
        t_end = sim.now
        union = self._union(t_end)
        exposure: Dict[int, float] = {}
        recovery: Dict[int, float] = {}
        survived: Dict[int, bool] = {}
        for app in sim.jobs:
            start = sim.job_start_ns.get(app, sim.job_submit_ns.get(app, 0.0))
            done = sim.app_done_ns.get(app)
            survived[app] = done is not None
            fin = done if done is not None else t_end
            exp = 0.0
            last_heal = None
            for s, e in union:
                lo, hi = max(s, start), min(e, fin)
                if hi > lo:
                    exp += hi - lo
                    if e <= fin and (last_heal is None or e > last_heal):
                        last_heal = e
            exposure[app] = exp
            recovery[app] = max(0.0, fin - last_heal) \
                if last_heal is not None else 0.0
        return exposure, recovery, survived
