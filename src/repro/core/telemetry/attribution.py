"""Critical-path slowdown attribution: *why* was this run slow?

The decomposition half of the diagnosis layer (ARCHITECTURE.md §Diagnosis;
the raw-telemetry views live in ``analysis.py``). Every completed block span
is partitioned along its own time axis into a **closed taxonomy of causes**:

========================  ==================================================
``bcast_tail``            leader-done -> last-participant completion
                          broadcast (the block is reduced, hosts are still
                          learning about it)
``fault_recovery``        injected-fault windows (repro.core.faults): block
                          time spent while a switch crash, link failure or
                          host straggler fault was active — the most
                          specific evidence, claimed before the congestion
                          symptoms the fault also produces
``pfc_pause``             fabric-wide PFC pause windows (transport=dcqcn
                          with PFC enabled)
``retx_recovery``         loss-recovery windows: block-level retx requests
                          and go-back-N timer retransmits, each counted as
                          the timeout window ``[t - timeout, t]`` that
                          preceded the recovery instant. Block-level retx
                          windows only count when the run recorded actual
                          loss (``RunView.loss_evidence``) — a retx request
                          under zero loss is a congestion *symptom* and its
                          wait time belongs to the causes below
``collision_bypass``      §3.2.1 descriptor-collision detours: the
                          contribution skipped in-network aggregation and
                          was host-aggregated at the leader instead.
                          Evidence is the *serialized* detour windows (the
                          leader processes bypassed contributions one at a
                          time) plus congestion on the leader's own
                          down-link — bypass traffic is unicast to the
                          leader, so a backlog there while collisions are
                          recorded is the bypass convoy, not generic
                          fabric queueing
``dcqcn_pacing``          windows during which a participant was DCQCN-paced
                          below line rate
``queueing``              windows during which the most-backlogged fabric
                          link held more than one MTU of queued bytes
``timeout_flush``         §3.1.1 best-effort timeout stalls: the tail of
                          each descriptor window that flushed by timeout
                          (the switch sat waiting for children that never
                          came). Ranked *below* pacing and queueing: a
                          timeout window spent congested or paced is those
                          causes' fault — what is left is the switch idly
                          waiting for a child that was merely late (noise)
                          or never sent
``wire``                  the uncontended floor: per-hop serialization +
                          propagation across the fabric plus the host-side
                          leader aggregate, capped at the topology estimate
``other``                 the explicit residual — whatever the recorded
                          signals cannot explain
========================  ==================================================

**Conservation contract.** Causes are measured as *disjoint interval
subsets* of the block's own span ``[t0, t1)``: each extractor intersects
its evidence intervals with the still-unattributed remainder and subtracts
what it takes, in the priority order above (most-specific evidence first),
and ``other`` is defined as the leftover measure. The components therefore
sum to the measured span *by construction*; the only slack is float
rounding across the interval arithmetic, so the documented tolerance is
``CONSERVATION_REL_TOL`` (relative, default 1e-6) — not a fudge factor for
modelling error, which lands in ``other`` instead and stays visible.
``tests/core/test_diagnosis.py`` property-tests the contract on congested
fat_tree and three_tier cells and pins that each injected bottleneck (hot
link, table_size collisions, loss+gbn, DCQCN pacing) surfaces as the top
cause.

Adding a cause (the recipe, also in ARCHITECTURE.md §Diagnosis): derive an
``Intervals`` evidence set from spans/instants/series in ``RunView``, insert
one ``_take(...)`` call at the right specificity rank in
:func:`attribute_block`, add the name to ``CAUSES`` — conservation then
holds automatically, and the property test will fail if the new extractor
overlaps the span boundary.

Job-level attribution composes per-block results along the job's critical
path (``analysis.critical_path``): each path segment contributes its
block's causes scaled by the fraction of that block's span the segment
covers, and idle gaps (time no block span covers) land in ``other``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .analysis import (BlockRecord, Hotspot, Intervals, RunView,
                       critical_path, hotspots, job_interval)

__all__ = ["CAUSES", "CONSERVATION_REL_TOL", "BlockAttribution",
           "AppAttribution", "Diagnosis", "attribute_block",
           "attribute_app", "diagnose"]

# the closed taxonomy, in attribution priority order (most specific first);
# report output preserves this order for stable diffs
CAUSES = ("bcast_tail", "fault_recovery", "pfc_pause", "retx_recovery",
          "collision_bypass", "dcqcn_pacing", "queueing", "timeout_flush",
          "wire", "other")

# conservation tolerance: float rounding across interval subtraction only —
# sum(causes) is structurally <= span, and `other` absorbs the remainder,
# so any drift beyond accumulated ulps is a bug, not noise
CONSERVATION_REL_TOL = 1e-6
_ABS_TOL_NS = 1e-3


def _tol(span_ns: float) -> float:
    return max(_ABS_TOL_NS, abs(span_ns) * CONSERVATION_REL_TOL)


@dataclass
class BlockAttribution:
    """One block's span decomposed into the closed cause taxonomy."""

    app: int
    block: int
    t0: float
    t1: float
    causes: Dict[str, float]
    complete: bool = True

    @property
    def span_ns(self) -> float:
        return self.t1 - self.t0

    def conservation_error_ns(self) -> float:
        return abs(sum(self.causes.values()) - self.span_ns)

    def check(self) -> None:
        """Raise if the conservation contract is violated."""
        err = self.conservation_error_ns()
        if err > _tol(self.span_ns):
            raise AssertionError(
                f"conservation violated for app {self.app} block "
                f"{self.block}: causes sum to "
                f"{sum(self.causes.values()):.6f} ns vs span "
                f"{self.span_ns:.6f} ns (err {err:.6f} ns)")

    def top_cause(self) -> str:
        return max(CAUSES, key=lambda c: self.causes.get(c, 0.0))

    def to_dict(self) -> dict:
        return {"app": self.app, "block": self.block, "t0": self.t0,
                "t1": self.t1, "span_ns": self.span_ns,
                "complete": self.complete, "causes": dict(self.causes)}


@dataclass
class AppAttribution:
    """One job's makespan decomposed along its critical path."""

    app: int
    tenant: int
    t0: float
    t1: float
    causes: Dict[str, float]
    n_blocks: int
    idle_ns: float   # critical-path gaps (counted inside causes["other"])

    @property
    def makespan_ns(self) -> float:
        return self.t1 - self.t0

    def top_cause(self) -> str:
        return max(CAUSES, key=lambda c: self.causes.get(c, 0.0))

    def to_dict(self) -> dict:
        return {"app": self.app, "tenant": self.tenant,
                "makespan_ns": self.makespan_ns, "n_blocks": self.n_blocks,
                "idle_ns": self.idle_ns, "causes": dict(self.causes)}


# ------------------------------------------------------------ per-block core
def _take(remaining: Intervals, evidence: Intervals,
          causes: Dict[str, float], name: str) -> Intervals:
    """Attribute ``remaining ∩ evidence`` to ``name``; return the new
    remainder. This is the conservation mechanism: every cause takes a
    disjoint subset of the block's own time axis."""
    got = remaining.intersect(evidence)
    m = got.measure()
    if m > 0.0:
        causes[name] += m
        return remaining.subtract(got)
    return remaining


def attribute_block(view: RunView, blk: BlockRecord) -> BlockAttribution:
    """Decompose one block span into the closed cause taxonomy (see module
    docstring for the priority order and the conservation argument)."""
    t0, t1 = blk.t0, blk.t1
    causes = {c: 0.0 for c in CAUSES}
    out = BlockAttribution(app=blk.app, block=blk.block, t0=t0, t1=t1,
                           causes=causes, complete=blk.complete)
    total = t1 - t0
    if total <= 0.0:
        return out
    remaining = Intervals([(t0, t1)])

    # 1. broadcast tail: everything after leader_done is the done-broadcast
    if blk.bcast_t0 is not None and t0 <= blk.bcast_t0 < t1:
        causes["bcast_tail"] = t1 - blk.bcast_t0
        remaining = Intervals([(t0, blk.bcast_t0)])

    # 2. fault-active windows (repro.core.faults): a crashed switch, dead
    #    link or paused host is the most specific possible evidence — any
    #    block time spent inside one is fault recovery, whatever congestion
    #    symptoms it also produced
    fault_iv = view.fault_intervals()
    if not fault_iv.is_empty():
        remaining = _take(remaining, fault_iv, causes, "fault_recovery")

    # 3. PFC pause windows (fabric-wide union: a paused sender stalls the
    #    reduction tree feeding it, so any overlap is attributable)
    remaining = _take(remaining, view.pfc_intervals(), causes, "pfc_pause")

    # 4. loss-recovery windows: each recovery instant at time t implies the
    #    preceding timeout window [t - timeout, t] was spent waiting
    parts = set(view.participants(blk.app))
    ivs: List[Tuple[float, float]] = []
    if view.loss_evidence:
        for _what, t in view.retx_instants(blk.app, blk.block):
            ivs.append((t - view.retx_timeout_ns, t))
    for _host, t in view.gbn_retx_instants(parts or None):
        ivs.append((t - view.gbn_timeout_ns, t))
    if ivs:
        remaining = _take(remaining, Intervals(ivs), causes, "retx_recovery")

    # 5. collision detours. The leader host-aggregates bypassed
    #    contributions serially, so the detour windows chain: each starts
    #    when its collision fired or when the previous detour finished,
    #    whichever is later. While collisions are on record for this block,
    #    backlog on the leader's own down-link is the bypass convoy itself
    #    (unicast to the leader), so those windows count as evidence too.
    col_t = view.collision_instants(blk.app, blk.block)
    if col_t:
        det = view.collision_detour_ns
        ivs = []
        cur = -math.inf
        for t in sorted(col_t):
            s = t if t > cur else cur
            ivs.append((s, s + det))
            cur = s + det
        if blk.leader is not None:
            down = view.num_hosts + blk.leader  # leaf->leader link index
            ivs.extend(view.link_congested_intervals(down).spans)
        remaining = _take(remaining, Intervals(ivs), causes,
                          "collision_bypass")

    # 6. DCQCN pacing: windows with any participant below line rate
    if parts:
        pace = view.pacing_intervals(sorted(parts))
        if not pace.is_empty():
            remaining = _take(remaining, pace, causes, "dcqcn_pacing")

    # 7. queueing: remaining time while a link that can carry this app's
    #    traffic held > 1 MTU of backlog (bystander host links excluded)
    remaining = _take(remaining, view.app_congested_intervals(sorted(parts)),
                      causes, "queueing")

    # 8. timeout-flush stalls: the waited-out tail of each timeout window
    #    (only what pacing/queueing above did not already claim — an idle
    #    switch waiting out its window on an uncongested fabric)
    ivs = [(max(w.t0, w.t1 - view.timeout_ns), w.t1)
           for w in view.desc_windows(blk.app, blk.block)
           if w.reason == "timeout"]
    if ivs:
        remaining = _take(remaining, Intervals(ivs), causes, "timeout_flush")

    # 9. wire floor, capped at the topology estimate; the rest is residual
    rest = remaining.measure()
    wire = min(rest, view.wire_estimate_ns)
    causes["wire"] = wire
    # exact-by-construction closure: `other` is defined as the leftover
    causes["other"] = max(0.0, total - sum(
        v for c, v in causes.items() if c != "other"))
    return out


# ------------------------------------------------------------- per-job level
def attribute_app(view: RunView, app: int,
                  block_attrs: Optional[Dict[Tuple[int, int],
                                             BlockAttribution]] = None
                  ) -> Optional[AppAttribution]:
    """Compose per-block attributions along ``app``'s critical path. Each
    path segment contributes its block's causes scaled by the fraction of
    the block span the segment covers; idle gaps land in ``other``."""
    path = critical_path(view, app)
    if not path:
        return None
    if block_attrs is None:
        block_attrs = {}
    causes = {c: 0.0 for c in CAUSES}
    idle = 0.0
    n_blocks = 0
    for seg in path:
        if seg.block is None:
            idle += seg.span_ns
            causes["other"] += seg.span_ns
            continue
        n_blocks += 1
        key = (seg.block.app, seg.block.block)
        ba = block_attrs.get(key)
        if ba is None:
            ba = block_attrs[key] = attribute_block(view, seg.block)
        if ba.span_ns > 0.0:
            scale = seg.span_ns / ba.span_ns
            for c, v in ba.causes.items():
                causes[c] += v * scale
    iv = job_interval(view, app)
    return AppAttribution(app=app, tenant=view.tenant_of(app), t0=iv[0],
                          t1=iv[1], causes=causes, n_blocks=n_blocks,
                          idle_ns=idle)


# ---------------------------------------------------------------- diagnosis
@dataclass
class Diagnosis:
    """The full diagnosis of one run: per-block and per-job attributions,
    ranked totals, congestion hotspots (global and per-tenant) and the
    truncation state that qualifies all of it."""

    per_block: List[BlockAttribution]
    per_app: Dict[int, AppAttribution]
    per_tenant: Dict[int, Dict[str, float]]
    totals: Dict[str, float]
    hotspots: List[Hotspot]
    tenant_hotspots: Dict[int, List[Hotspot]]
    truncation: Dict[str, object]
    notes: List[str] = field(default_factory=list)

    @property
    def truncated(self) -> bool:
        return bool(self.truncation.get("spans_dropped", 0)
                    or self.truncation.get("samples_dropped", 0)
                    or self.truncation.get("pkt_instants_capped", False))

    def ranked(self) -> List[Tuple[str, float, float]]:
        """Causes as (name, ns, fraction-of-total), largest first."""
        total = sum(self.totals.values())
        out = [(c, self.totals.get(c, 0.0),
                self.totals.get(c, 0.0) / total if total > 0.0 else 0.0)
               for c in CAUSES]
        out.sort(key=lambda r: r[1], reverse=True)
        return out

    def top_cause(self) -> str:
        r = self.ranked()
        return r[0][0] if r else "other"

    def to_json(self) -> dict:
        return {
            "top_cause": self.top_cause(),
            "totals_ns": {c: self.totals.get(c, 0.0) for c in CAUSES},
            "ranked": [{"cause": c, "ns": ns, "frac": frac}
                       for c, ns, frac in self.ranked()],
            "per_app": {str(a): aa.to_dict()
                        for a, aa in sorted(self.per_app.items())},
            "per_tenant": {str(t): dict(c)
                           for t, c in sorted(self.per_tenant.items())},
            "per_block": [b.to_dict() for b in self.per_block],
            "hotspots": [h.to_dict() for h in self.hotspots],
            "tenant_hotspots": {str(t): [h.to_dict() for h in hs]
                                for t, hs in
                                sorted(self.tenant_hotspots.items())},
            "truncated": self.truncated,
            "truncation": dict(self.truncation),
            "notes": list(self.notes),
        }

    def to_text(self) -> str:
        """The human 'why was this slow' report."""
        lines: List[str] = []
        w = lines.append
        w("== diagnosis: why was this run slow? " + "=" * 34)
        if self.truncated:
            w("!! TELEMETRY TRUNCATED "
              f"(spans_dropped={self.truncation.get('spans_dropped', 0)}, "
              f"samples_dropped={self.truncation.get('samples_dropped', 0)}, "
              "pkt_instants_capped="
              f"{self.truncation.get('pkt_instants_capped', False)}) --")
            w("!! instant-driven causes below are a LOWER BOUND; raise the "
              "telemetry_max_* caps for a complete attribution")
        for note in self.notes:
            w(f"note: {note}")
        total = sum(self.totals.values())
        w(f"critical-path attribution over {len(self.per_app)} job(s), "
          f"{len(self.per_block)} block span(s), "
          f"{total / 1e3:.1f} us attributed:")
        for cause, ns, frac in self.ranked():
            if ns <= 0.0:
                continue
            bar = "#" * max(1, int(round(frac * 40)))
            w(f"  {cause:<18}{ns / 1e3:>12.1f} us  {frac * 100:>5.1f}%  "
              f"{bar}")
        if self.hotspots:
            w("top congestion hotspots (mean queue delay over the run):")
            for i, h in enumerate(self.hotspots[:10], 1):
                w(f"  {i:>2}. {h.name:<20}{h.mean_queue_ns / 1e3:>9.2f} us "
                  f"mean | peak {h.peak_backlog_bytes / 1024.0:.1f} KiB | "
                  f"busy {h.busy_frac * 100:.0f}%")
        for app, aa in sorted(self.per_app.items()):
            w(f"app {app} (tenant {aa.tenant}): makespan "
              f"{aa.makespan_ns / 1e3:.1f} us over {aa.n_blocks} "
              f"critical-path block(s), top cause: {aa.top_cause()}")
        if len(self.per_tenant) > 1:
            w("per-tenant attribution:")
            for t, causes in sorted(self.per_tenant.items()):
                tot = sum(causes.values())
                top = max(CAUSES, key=lambda c: causes.get(c, 0.0))
                hs = self.tenant_hotspots.get(t) or []
                hot = f", hottest link: {hs[0].name}" if hs else ""
                w(f"  tenant {t}: {tot / 1e3:.1f} us attributed, top cause "
                  f"{top}{hot}")
        return "\n".join(lines)


def diagnose(view: RunView, top_links: int = 10) -> Diagnosis:
    """Run the full diagnosis over one run's telemetry."""
    notes: List[str] = []
    blocks = view.blocks()
    if not blocks:
        notes.append("no block spans recorded "
                     "(telemetry_spans off or zero blocks) -- "
                     "no per-block attribution possible")
    if not view.probes_on:
        notes.append("probes disabled: queueing / dcqcn_pacing attribution "
                     "and hotspot ranking are unavailable")
    open_blocks = [b for b in blocks if not b.complete]
    if open_blocks:
        notes.append(f"{len(open_blocks)} block(s) still open at end of run "
                     "-- their spans are truncated at the run end")

    block_attrs: Dict[Tuple[int, int], BlockAttribution] = {}
    per_app: Dict[int, AppAttribution] = {}
    for app in view.apps():
        aa = attribute_app(view, app, block_attrs)
        if aa is not None:
            per_app[app] = aa
    # blocks never on any critical path still get attributed (the per-block
    # section is the complete record; the totals are path-weighted)
    for blk in blocks:
        key = (blk.app, blk.block)
        if key not in block_attrs:
            block_attrs[key] = attribute_block(view, blk)

    totals = {c: 0.0 for c in CAUSES}
    per_tenant: Dict[int, Dict[str, float]] = {}
    tenant_windows: Dict[int, Intervals] = {}
    for app, aa in per_app.items():
        for c, v in aa.causes.items():
            totals[c] += v
        tc = per_tenant.setdefault(aa.tenant, {c: 0.0 for c in CAUSES})
        for c, v in aa.causes.items():
            tc[c] += v
        iv = job_interval(view, app)
        if iv is not None:
            win = tenant_windows.get(aa.tenant, Intervals())
            tenant_windows[aa.tenant] = win.union(Intervals([iv]))

    hs = hotspots(view, top=top_links)
    tenant_hs = {t: hotspots(view, window=win, top=top_links)
                 for t, win in tenant_windows.items()} \
        if len(tenant_windows) > 1 else {}

    diag = Diagnosis(per_block=sorted(block_attrs.values(),
                                      key=lambda b: (b.app, b.block)),
                     per_app=per_app, per_tenant=per_tenant, totals=totals,
                     hotspots=hs, tenant_hotspots=tenant_hs,
                     truncation=view.truncation, notes=notes)
    for ba in diag.per_block:
        ba.check()
    return diag
