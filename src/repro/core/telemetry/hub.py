"""The Telemetry hub: probes, spans and instants for one simulation run.

Contract (mirrors the trace recorder, see ARCHITECTURE.md §Telemetry):

* **Off = no object.** ``Simulator.telemetry`` is ``None`` unless
  ``SimConfig.telemetry`` is set; every hook site in the layers is one
  guarded ``if self._telemetry is not None`` identity check, so the off
  path costs nothing measurable.
* **Observation-only.** No hook draws from ``sim.rng``, schedules a
  protocol event, or mutates layer state. The periodic probe rides its own
  engine event kind (``EV_TELEMETRY_PROBE``) which the run loop dispatches
  *outside* the golden ``events`` count — telemetry-on runs replay every
  golden bit-for-bit, including the event counter.
* **No packet retention.** Hooks read packet/descriptor fields during the
  call and keep only plain numbers — the packet pool recycles objects, so
  holding a reference would alias a future packet.
* **Cheap when on.** Hooks run once per protocol event in the hottest
  loops, so they do no string formatting: spans and instants are appended
  as small raw tuples (first element = type tag) and only rendered into
  names/args by the exporters; per-switch series and histograms are
  pre-resolved at :meth:`finalize`; the per-descriptor sites are inlined
  into the switch layer as plain appends/compares against hub-owned state
  (no bound-method call). The perf suite pins the on-overhead budget
  (``benchmarks.perf.TELEMETRY_BUDGET``).
* **Lazy consolidation.** The run itself only collects raw logs and
  counters; :meth:`finish` (called at the end of ``Simulator.run``) does
  O(counters + one pass over the flush log) bookkeeping so
  ``SimResult.telemetry_summary`` is exact, and everything heavier —
  decoding descriptor spans, merging the per-packet instant log, replaying
  histograms, snapshotting run metadata — runs at most once, the first
  time a reader touches ``spans``, ``instants``, ``registry``, ``meta`` or
  ``open_blocks``. A sweep that never reads its telemetry never pays for
  consolidation. One semantic consequence: when the span cap binds,
  lifecycle (block/bcast) spans recorded during the run take priority, and
  descriptor spans merge afterwards in flush order — the drop *totals*
  stay exact either way.

Two data planes:

* **Probes** (``telemetry_probes``): every ``telemetry_probe_ns`` of sim
  time, sample per-link queue backlog, per-switch descriptor-table
  occupancy (the series are sampled; the per-switch *high-water* gauge is
  event-driven at on_desc_alloc and therefore exact at any cadence — see
  ``desc_high_water``), per-host DCQCN pacing rate, transport counter rates
  (ECN marks, CNPs, PFC pauses, go-back-N retx) and per-app outstanding
  block count. Series are delta-encoded (see ``metrics.TimeSeries``).
* **Spans** (``telemetry_spans``): block lifecycle (first REDUCE send ->
  last participant completion, with the leader-done -> completion broadcast
  tail as a nested span), per-descriptor aggregation windows (alloc ->
  timeout/complete flush), and instant events for drops, collisions,
  stragglers, retransmissions, CNPs and PFC pause/resume.

Span tuples (exporters render these — keep in sync with ``export.py``):

* ``("block", app, block, t0, t1, last_host)``
* ``("bcast", app, block, t0, t1)``
* ``("desc", sw, app, block, reason, merges, children, t0, t1)``

Instant tuples:

* ``("leader_done", app, block, leader, t)``
* ``("collision"|"straggler", sw, app, block, t)`` — the hot hooks log the
  raw packed packet id and the app/block decode happens once, lazily, at
  consolidation
* ``("drop", cause, where, t)``
* ``("retx", what, app, host, block, t)``
* ``("cnp", dst, src, t)``
* ``("pfc", host, paused, t)``
* ``("gbn", what, host, count, t)``
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from ..canary.engine import EV_TELEMETRY_PROBE
from ..canary.types import APP_SHIFT, GEN_BITS
from .metrics import MetricsRegistry

__all__ = ["Telemetry"]

# generation-free block key: (app << _APP_BITS_SHIFT) | block — the same
# packing as Packet.id >> GEN_BITS, so on_host_send computes it with one shift
_APP_BITS_SHIFT = APP_SHIFT - GEN_BITS
_BLOCK_MASK = (1 << _APP_BITS_SHIFT) - 1


class Telemetry:
    """Per-run telemetry hub. Constructed by the facade when
    ``cfg.telemetry`` is set; :meth:`finalize` runs after all layers bind."""

    def __init__(self, sim) -> None:
        self.sim = sim
        cfg = sim.cfg
        self.cfg = cfg
        self.probe_ns = float(cfg.telemetry_probe_ns)
        self._registry = MetricsRegistry(
            series_cap=cfg.telemetry_max_samples)
        self.probes = 0
        self.spans_dropped = 0
        self._probes_on = bool(cfg.telemetry_probes)
        self._spans_on = bool(cfg.telemetry_spans)
        self._max_spans = int(cfg.telemetry_max_spans)
        self._max_pkt = min(int(cfg.telemetry_max_pkt_instants),
                            self._max_spans)
        self._engine = sim.engine
        # raw span/instant tuples (see module docstring for the shapes).
        # ``_spans``/``_instants`` back the lazy ``spans``/``instants``
        # properties; per-packet instants (stragglers/collisions) and
        # descriptor flushes collect in their own raw logs and merge in at
        # consolidation
        self._spans: List[Tuple] = []
        self._instants: List[Tuple] = []
        self._pkt_instants: List[Tuple] = []
        self._desc_log: List[Tuple] = []  # appended inline by switch.py
        # lazy-consolidation state: finish() freezes the exact totals the
        # summary needs, _consolidate() does the heavy decode on first read
        self._finished = False
        self._consolidated = False
        self._desc_merged = 0  # full desc-log entries that fit the cap
        self._pkt_merged = 0   # pkt instants that fit the cap
        self.spans_total = 0
        self.instants_total = 0
        self._final_now = 0.0  # engine time at finish(), for the closing
        self._summary = None   # sample + meta snapshot at consolidation
        # plain attribute counters for the per-event hooks (surfaced by
        # summary_dict; string-keyed registry counters are for rare events)
        self.desc_allocs = 0
        self.flush_timeout = 0
        self.flush_complete = 0
        self.blocks_started = 0
        self.blocks_completed = 0
        # set from the sim's own exact totals at finish(); the hooks never
        # count these (they fire per packet — the sim already counts them)
        self.collisions = 0
        self.stragglers = 0
        # hot-path gates, mirrored INTO the layers as pre-bound site state
        # (strategy._tel_open / strategy._tel_pkt / hostproto._tel_left, see
        # start()) so each hot site pays one attribute load + identity check;
        # want_sends drops when every block has opened (the hub retracts
        # the site attribute), want_pkt_instants when the per-packet
        # instant log caps out (that site retracts itself) — either way
        # the site then goes fully cold
        self.want_sends = self._spans_on
        self.want_completes = self._spans_on
        self.want_pkt_instants = self._spans_on and self._max_pkt > 0
        # open block-lifecycle state, keyed (app << _APP_BITS_SHIFT) | block.
        # ``block_open`` and ``block_left`` are PUBLIC: the two hottest call
        # sites inline their common-case check/decrement against them and
        # only call into the hub on the rare interesting transition (first
        # send of a block, last completion of a block) — see
        # AggregationStrategy.next_host_packet and
        # HostProtocol.complete_at_host.
        self.block_open: Dict[int, float] = {}
        self._leader_done_t: Dict[int, float] = {}
        self.block_left: Dict[int, List[int]] = {}  # filled in start()
        self._strategy = None  # site owner for _tel_open/_tel_pkt (start())
        # pre-created histograms, fed from raw value lists the hot hooks
        # append to; consolidation replays the lists into the buckets
        self._lat_hist = self._registry.hist("block/latency_ns")
        self._win_hist = self._registry.hist("desc/window_ns")
        self._lat_vals: List[float] = []
        self._win_vals: List[float] = []
        # bound in finalize()
        self._links: List = []
        self._link_ts: List = []
        self._tables: List[dict] = []
        self._sw_ts: List = []
        self._sw_hi: List[int] = []
        self._total_blocks = -1  # set in start(); -1 = never triggers swap
        self._tp = None
        self._tp_last: Dict[str, float] = {}
        self.occupancy_model_bytes = 0.0
        self.occupancy_model_descriptors = 0.0
        # span finalization (filled lazily by _consolidate(); consumed by
        # the diagnosis layer, see analysis.view_of): run metadata snapshot
        # and the blocks still open when the run ended (budget abort /
        # deferred job) — the attribution must never mistake a truncated
        # lifecycle for a fast one
        self._meta: Dict[str, object] = None
        self._open_blocks: List[Tuple[int, int, float, float]] = None

    # ------------------------------------------------------------- lifecycle
    def finalize(self) -> None:
        """Pre-resolve probe targets now that the layer graph exists, and
        install the pre-bound descriptor hooks into the strategy (the hub is
        constructed after the layers, so the strategy cannot bind them at
        its own construction)."""
        sim = self.sim
        strat = sim.strategy
        strat._telemetry = self
        reg = self._registry
        self._links = list(sim.net.all_links())
        self._link_ts = [reg.ts(f"link/{i}/backlog_bytes")
                         for i in range(len(self._links))]
        self._tables = sim.switch.tables
        # event-driven per-switch occupancy: series + exact high-waters
        self._sw_ts = [reg.ts(f"switch/{i}/descriptors")
                       for i in range(len(self._tables))]
        self._sw_hi = [0] * len(self._tables)
        # install the inlined per-descriptor site state (see switch.py):
        # the alloc site maxes into the hub's own high-water list and the
        # flush site appends into the hub's own raw log, so the hot path
        # pays a few loads instead of a bound-method call
        strat._tel_sw_hi = self._sw_hi
        strat._tel_desc_log = self._desc_log
        strat._tel_desc_cap = self._max_spans if self._spans_on else 0
        self._tp = sim.transport
        # the §3.2.2 analytic occupancy bound the probed series compares to
        from ..canary.memory_model import model_for
        model = model_for(self.cfg)
        self.occupancy_model_bytes = float(model.occupancy_bytes)
        self.occupancy_model_descriptors = float(
            model.occupancy_bytes / self.cfg.mtu_bytes)

    def start(self) -> None:
        """Arm the probe chain (called once from ``Simulator.run``, after the
        per-app participant maps exist — ``finalize`` runs too early)."""
        sim = self.sim
        # per-app flat countdown arrays: block_left[app][block] holds how
        # many participant completions remain before the block span closes —
        # the call site decrements inline and only calls on_block_complete
        # when a block's count hits zero
        self.block_left = {}
        total_blocks = 0
        for app, left in sim.app_remaining.items():
            npart = sim.nparts[app]
            if sim.jobs[app].collective == "reduce":
                blocks, init = left, 1
            else:
                blocks, init = left // npart, npart
            self.block_left[app] = [init] * blocks
            total_blocks += blocks
        # total distinct blocks across apps: once every one has opened,
        # want_sends drops and the send site goes cold
        self._total_blocks = total_blocks
        if total_blocks == 0:
            self.want_sends = False
        # install the pre-bound site state in the layers: each hot site then
        # gates on ONE instance attribute (dict-or-None / list-or-None)
        # that is retracted when the site stops being interesting
        strat = self._strategy = sim.strategy
        strat._tel_open = self.block_open if self.want_sends else None
        strat._tel_pkt = \
            self._pkt_instants if self.want_pkt_instants else None
        strat._tel_pkt_cap = self._max_pkt
        sim.hostproto._tel_left = \
            self.block_left if self.want_completes else None
        if self._probes_on:
            self._engine.push(self._engine.now, EV_TELEMETRY_PROBE, 0, 0, None)

    def handle_probe(self, a: int, b: int, c: object) -> None:
        """Engine handler for EV_TELEMETRY_PROBE: sample, then re-arm one
        cadence ahead — unless the run is over (stop flag) or this probe is
        the only thing left queued (both heaps empty after the pop)."""
        eng = self._engine
        now = eng.now
        self.probes += 1
        self._sample(now)
        if not eng.stop and (eng.heap or eng.timer_heap):
            eng.push(now + self.probe_ns, EV_TELEMETRY_PROBE, 0, 0, None)

    def finish(self) -> None:
        """End-of-run bookkeeping, called from ``Simulator.run`` before the
        result is built — deliberately cheap (O(counters) plus one pass
        over the raw flush log), so the timed run never pays for
        consolidation: it syncs the descriptor counters from the inlined
        call sites and freezes the exact span/instant/drop totals
        ``summary_dict`` reports. Everything heavier — the closing probe
        sample, the series-extrema sync, the decode/merge/replay work — is
        deferred to :meth:`_consolidate`, which the ``spans``/``instants``/
        ``registry``/``meta``/``open_blocks`` properties trigger on first
        read. Only the engine clock is captured here, so the deferred
        closing sample lands at the run's true end time."""
        self._final_now = self._engine.now
        # collision/straggler totals come from the simulator's own counters
        # (incremented at the exact same call sites, telemetry or not) —
        # the hooks only log instants, so the hub never double-counts
        self.collisions = int(self.sim.collisions)
        self.stragglers = int(self.sim.stragglers)
        # descriptor counters from the inlined call sites (see switch.py):
        # allocs are a plain int on the strategy; flush reasons take one
        # pass over the raw log (full entries carry the reason at [2],
        # slim past-the-cap entries at [0])
        strat = self._strategy if self._strategy is not None \
            else self.sim.strategy
        self.desc_allocs = int(getattr(strat, "_tel_desc_n", 0))
        log = self._desc_log
        full = 0
        timeouts = 0
        for e in log:
            if len(e) == 5:
                full += 1
                if e[2] == "timeout":
                    timeouts += 1
            elif e[0] == "timeout":
                timeouts += 1
        self.flush_timeout = timeouts
        self.flush_complete = len(log) - timeouts
        # exact truncation/merge arithmetic, shared with _consolidate():
        # the summary totals must agree bit-for-bit with the consolidated
        # lists without forcing the consolidation. Cap priority: lifecycle
        # spans recorded during the run land first, descriptor spans merge
        # into the remaining room in flush order; the per-packet instant
        # log merges into the instants' remaining room. Every offered span
        # either lands or counts in spans_dropped — never silent.
        if self._spans_on:
            self._desc_merged = min(
                full, max(0, self._max_spans - len(self._spans)))
            self.spans_dropped += \
                (full - self._desc_merged) + (len(log) - full)
            recorded = len(self._pkt_instants)
            self.spans_dropped += \
                self.stragglers + self.collisions - recorded
            room = self._max_spans - len(self._instants)
            self._pkt_merged = min(recorded, room) if room > 0 else 0
            self.spans_dropped += recorded - self._pkt_merged
        self.spans_total = len(self._spans) + self._desc_merged
        self.instants_total = len(self._instants) + self._pkt_merged
        self._finished = True

    def _consolidate(self) -> None:
        """One-time heavy consolidation, lazily triggered by the first
        reader after :meth:`finish`: take the closing probe sample (the
        probe chain dies with the heaps, so without it the series could end
        one cadence before the final completions drained — the layers are
        inert after the run, so sampling them late reads the same state),
        raise the sampled per-switch series extrema to the exact
        event-driven gauges, decode the raw descriptor-flush log into
        ``("desc", ...)`` span tuples and the window histogram, merge and
        decode the per-packet instant log, replay the block-latency
        values, record the blocks still open when the run ended and
        snapshot the run metadata for the diagnosis layer. A run whose
        telemetry is never read never pays for any of this."""
        self._consolidated = True
        if self._probes_on:
            self._sample(self._final_now)
        # raise each sampled per-switch series' hi to the exact event-driven
        # gauge (a probe can land between an alloc and its flush and miss
        # the true peak)
        for hi, ts in zip(self._sw_hi, self._sw_ts):
            if ts.t and hi > ts.hi:
                ts.hi = float(hi)
        log = self._desc_log
        if self._spans_on:
            left = self._desc_merged
            if left:
                spans = self._spans
                for e in log:
                    if len(e) == 5:
                        # the raw record retains the descriptor itself;
                        # id/counter/alloc_ns are frozen at flush (only the
                        # children set keeps mutating, hence the captured
                        # count), so the decode reads them off the object
                        sw, d, reason, children, t1 = e
                        pid = d.id
                        spans.append(("desc", sw, pid >> APP_SHIFT,
                                      (pid >> GEN_BITS) & _BLOCK_MASK,
                                      reason, d.counter, children,
                                      d.alloc_ns, t1))
                        left -= 1
                        if left == 0:
                            break
            if self._pkt_merged:
                # decode the raw packed ids into the documented
                # ("collision"|"straggler", sw, app, block, t) shape —
                # once per kept entry, off the hot path
                self._instants.extend(
                    (kind, sw, pid >> APP_SHIFT,
                     (pid >> GEN_BITS) & _BLOCK_MASK, t)
                    for kind, sw, pid, t in
                    self._pkt_instants[:self._pkt_merged])
            self._pkt_instants = []
        # histogram replay: window durations come from the flush log (full
        # entries carry the retained descriptor and the flush time, slim
        # entries the duration itself), block latencies from the raw list
        self._win_vals.extend(
            (e[4] - e[1].alloc_ns) if len(e) == 5 else e[1] for e in log)
        self._desc_log = []
        self._win_hist.observe_many(self._win_vals)
        self._win_vals.clear()
        self._lat_hist.observe_many(self._lat_vals)
        self._lat_vals.clear()
        # blocks still open at end of run keep an explicit
        # truncated-lifecycle record, and the run metadata the attribution
        # needs to interpret spans without the live simulator is
        # snapshotted once, in the cold path
        now = self._engine.now
        self._open_blocks = [(key >> _APP_BITS_SHIFT, key & _BLOCK_MASK,
                              t0, now)
                             for key, t0 in sorted(self.block_open.items())]
        self._meta = self._snapshot_meta()

    def _ensure(self) -> None:
        if self._finished and not self._consolidated:
            self._consolidate()

    # Lazy read surface: every post-run consumer (exporters, diagnosis,
    # fleet aggregation, tests) reaches the data through these properties,
    # which trigger the one-time consolidation. Before finish() they
    # return the live raw state unchanged.
    @property
    def spans(self) -> List[Tuple]:
        self._ensure()
        return self._spans

    @property
    def instants(self) -> List[Tuple]:
        self._ensure()
        return self._instants

    @property
    def registry(self) -> MetricsRegistry:
        self._ensure()
        return self._registry

    @property
    def meta(self) -> Dict[str, object]:
        self._ensure()
        return self._meta if self._meta is not None else {}

    @property
    def open_blocks(self) -> List[Tuple[int, int, float, float]]:
        self._ensure()
        return self._open_blocks if self._open_blocks is not None else []

    def _snapshot_meta(self) -> Dict[str, object]:
        """JSON-safe run metadata for ``analysis.RunView``: per-app
        participant sets, tenants and lifecycle times, plus structural link
        names index-aligned with the ``link/{i}/*`` probe series."""
        sim = self.sim
        apps: Dict[int, dict] = {}
        for app, job in sim.jobs.items():
            apps[app] = {
                "participants": sorted(job.participants),
                "tenant": int(sim.tenant_of.get(app, app)),
                "collective": job.collective,
                "data_bytes": int(job.data_bytes),
                "submit_ns": float(sim.job_submit_ns.get(app, 0.0)),
                "finish_ns": sim.app_done_ns.get(app),
            }
        try:
            link_names = list(sim.net.link_names())
        except Exception:  # plug-in topologies predating link_names()
            link_names = [f"link/{i}" for i in range(len(self._links))]
        return {"apps": apps, "link_names": link_names,
                "topology": str(self.cfg.topology),
                "num_hosts": int(self.cfg.num_hosts)}

    def truncation_dict(self) -> Dict[str, object]:
        """Cap-hit accounting for the diagnosis layer. A truncated run
        under-records instant-driven causes, so any non-zero entry here must
        surface prominently in diagnosis output (never silently
        under-attribute — see ARCHITECTURE.md §Diagnosis)."""
        return {
            "spans_dropped": int(self.spans_dropped),
            "samples_dropped": int(self._registry.samples_dropped()),
            "pkt_instants_capped": bool(
                self._spans_on and self._max_pkt > 0
                and not self.want_pkt_instants),
        }

    # ---------------------------------------------------------------- probes
    def _sample(self, now: float) -> None:
        reg = self._registry
        # per-link queue backlog (delta-encoded: idle links record one point)
        hi = 0.0
        total = 0.0
        for link, ts in zip(self._links, self._link_ts):
            b = link.busy_until - now
            b = b * link.bytes_per_ns if b > 0.0 else 0.0
            ts.record(now, b)
            total += b
            if b > hi:
                hi = b
        reg.record("net/backlog_max_bytes", now, hi)
        reg.record("net/backlog_total_bytes", now, total)
        # per-switch descriptor occupancy + the cross-switch max the
        # OccupancyModel bound is compared against (the exact high-water
        # gauge is event-driven at on_desc_alloc; these sampled series show
        # the shape between allocs)
        if self._tables:
            occ_hi = 0
            for sts, table in zip(self._sw_ts, self._tables):
                n = len(table)
                sts.record(now, n)
                if n > occ_hi:
                    occ_hi = n
            reg.record("switch/max_descriptors", now, occ_hi)
        # per-app outstanding completions (blocks still in flight)
        for app, left in self.sim.app_remaining.items():
            reg.record(f"app/{app}/remaining", now, left)
        # transport counters -> cumulative series + per-us rates
        tp = self._tp
        if tp is not None:
            last = self._tp_last
            dt_us = self.probe_ns / 1e3
            for attr in ("ecn_marks", "cnps", "pfc_pauses", "rate_cuts",
                         "gbn_retx", "gbn_ooo"):
                v = getattr(tp, attr, None)
                if v is None:
                    continue
                reg.record(f"tp/{attr}", now, v)
                prev = last.get(attr, 0.0)
                reg.record(f"tp/{attr}_per_us", now, (v - prev) / dt_us)
                last[attr] = v
            cc = getattr(tp, "_cc", None)
            if cc is not None:  # DCQCN: per-host pacing rate in Gb/s
                for h, st in enumerate(cc):
                    reg.record(f"host/{h}/rate_gbps", now, st.rate * 8.0)

    # ------------------------------------------------------- span primitives
    def _push_span(self, entry: Tuple) -> None:
        if len(self._spans) < self._max_spans:
            self._spans.append(entry)
        else:
            self.spans_dropped += 1

    def _push_instant(self, entry: Tuple) -> None:
        if len(self._instants) < self._max_spans:
            self._instants.append(entry)
        else:
            self.spans_dropped += 1

    # ------------------------------------------------------- lifecycle hooks
    # The five hooks below run once per protocol event in the hottest loops,
    # so they inline the span/series bookkeeping instead of going through
    # _push_span / TimeSeries.record — every saved call is measurable
    # against the perf budget.

    def on_host_send(self, host: int, pkt) -> None:
        """First REDUCE contribution of a block opens its lifecycle span.
        The call site inlines the common-case rejection (block already open,
        checked against the pre-bound ``_tel_open`` dict) and only calls
        here once per distinct block; when the last block has opened the hub
        retracts ``_tel_open`` and the send site goes fully cold."""
        key = pkt.id >> GEN_BITS  # generation-free (app, block) packing
        self.block_open[key] = self._engine.now
        self.blocks_started += 1
        if self.blocks_started == self._total_blocks:
            self.want_sends = False
            self._strategy._tel_open = None

    def on_leader_done(self, host: int, app: int, block: int) -> None:
        """The leader holds the fully-reduced block; broadcast begins."""
        if self._spans_on:
            now = self._engine.now
            self._leader_done_t[(app << _APP_BITS_SHIFT) | block] = now
            ins = self._instants
            if len(ins) < self._max_spans:
                ins.append(("leader_done", app, block, host, now))
            else:
                self.spans_dropped += 1

    def on_block_complete(self, host: int, app: int, block: int) -> None:
        """The LAST participant of a block holds the final result: close the
        block span (and the leader-done -> done broadcast sub-span). The
        call site decrements ``block_left[app][block]`` inline and calls
        here only when the countdown hits zero — once per block, not once
        per participant completion."""
        key = (app << _APP_BITS_SHIFT) | block
        now = self._engine.now
        t0 = self.block_open.pop(key, None)
        t_ld = self._leader_done_t.pop(key, None)
        spans = self._spans
        if t_ld is not None and t_ld < now:
            if len(spans) < self._max_spans:
                spans.append(("bcast", app, block, t_ld, now))
            else:
                self.spans_dropped += 1
        if t0 is None:
            t0 = t_ld  # host-based paths with no recorded first send
        if t0 is not None:
            if len(spans) < self._max_spans:
                spans.append(("block", app, block, t0, now, host))
            else:
                self.spans_dropped += 1
            self._lat_vals.append(now - t0)
        self.blocks_completed += 1

    # ------------------------------------------------------ descriptor sites
    # There are no on_desc_alloc/on_desc_flush methods: both sites are
    # inlined into switch.py against hub-owned state installed at
    # finalize() — the alloc site maxes occupancy into ``_sw_hi`` (exact
    # event-driven high-water at any probe cadence: occupancy only rises
    # at an alloc, so deallocs need no site at all) and counts into
    # ``strategy._tel_desc_n``; the flush site appends the raw
    # ``(sw, desc, reason, nchildren, now)`` record — retaining the
    # descriptor object itself, which is not pooled — or a slim
    # ``(reason, duration)`` pair past the span cap, into ``_desc_log``.
    # finish() syncs the counters; _consolidate() decodes spans and
    # replays the window histogram.

    # ------------------------------------------------------ pkt-instant sites
    # There are no on_collision/on_straggler methods either: collisions and
    # especially stragglers are per-*packet* events — a congested cell emits
    # tens of thousands — so both sites are inlined into switch.py as plain
    # appends into ``_pkt_instants`` (installed as ``strategy._tel_pkt`` at
    # start()), logging the RAW packed packet id; consolidation decodes
    # app/block once per surviving entry when it merges the log. Once the
    # log reaches ``_tel_pkt_cap`` entries the site retracts itself and
    # drops ``want_pkt_instants``. The simulator already counts both events
    # at the same call sites (SimResult carries the authoritative totals,
    # finish() copies them into the hub), so nothing is lost when the site
    # goes cold.

    def on_drop(self, cause: str, where: int) -> None:
        """A packet died: ``cause`` is "wire" (iid link loss) or
        "switch_fail" (arrival at a dead switch)."""
        self._registry.inc("drops/" + cause)
        if self._spans_on:
            self._push_instant(("drop", cause, where, self._engine.now))

    def on_fault(self, kind: str, target, active: bool) -> None:
        """A fault-injection edge (repro.core.faults): ``active`` True when
        the fault lands, False at its heal. The instant pairs become
        ``RunView.fault_intervals()`` and the ``fault_recovery`` attribution
        cause."""
        self._registry.inc("faults/" + kind)
        if self._spans_on:
            self._push_instant(("fault", kind, target, active,
                                self._engine.now))

    def on_retx(self, what: str, host: int, app: int, block: int) -> None:
        """Whole-block recovery traffic: ``what`` is "request" (a host asked
        its leader) or "fail" (the leader re-issued the reduction)."""
        self._registry.inc("retx/" + what)
        if self._spans_on:
            self._push_instant(("retx", what, app, host, block,
                                self._engine.now))

    def on_cnp(self, src: int, dst: int) -> None:
        """DCQCN congestion-notification packet from receiver to sender."""
        self._registry.inc("tp/cnp_sent")
        if self._spans_on:
            self._push_instant(("cnp", dst, src, self._engine.now))

    def on_pfc(self, host: int, paused: bool) -> None:
        self._registry.inc("tp/pfc_pause" if paused else "tp/pfc_resume")
        if self._spans_on:
            self._push_instant(("pfc", host, paused, self._engine.now))

    def on_gbn(self, what: str, host: int, count: int = 1) -> None:
        """Go-back-N recovery: ``what`` is "retx" (window resent on timer)
        or "ooo" (out-of-order arrival discarded at the endpoint)."""
        self._registry.inc("tp/gbn_" + what, count)
        if self._spans_on:
            self._push_instant(("gbn", what, host, count, self._engine.now))

    # ---------------------------------------------------------------- digest
    def desc_high_water(self) -> int:
        """Exact max descriptor-table occupancy seen across all switches
        (event-driven — cross-validated against
        ``SimResult.max_descriptors_per_switch``)."""
        return max(self._sw_hi, default=0)

    def summary_dict(self) -> Dict[str, float]:
        """Flat numeric digest for ``SimResult.telemetry_summary``."""
        # deliberately reads the raw attributes, not the consolidating
        # properties: the summary is built inside Simulator.run and must not
        # trigger the lazy decode; finish() froze the exact totals already.
        # The post-finish digest is cached so later calls return the same
        # values even after consolidation adds the closing probe sample to
        # the registry — the summary describes the run, not the reader.
        if self._summary is not None:
            return self._summary
        reg = self._registry
        net = reg.series.get("net/backlog_max_bytes")
        d = {
            "probes": float(self.probes),
            "spans": float(self.spans_total if self._finished
                           else len(self._spans)),
            "instants": float(self.instants_total if self._finished
                              else len(self._instants)),
            "spans_dropped": float(self.spans_dropped),
            "series": float(len(reg.series)),
            "samples": float(reg.total_samples()),
            "samples_dropped": float(reg.samples_dropped()),
            "desc_high_water": float(self.desc_high_water()),
            "max_link_backlog_bytes":
                float(net.hi) if net is not None and len(net) else 0.0,
            "occupancy_model_bytes": self.occupancy_model_bytes,
            "occupancy_model_descriptors": self.occupancy_model_descriptors,
            "desc/flush_timeout": float(self.flush_timeout),
            "desc/flush_complete": float(self.flush_complete),
            "desc/alloc": float(self.desc_allocs),
            "switch/collisions": float(self.collisions),
            "switch/stragglers": float(self.stragglers),
            "blocks/started": float(self.blocks_started),
            "blocks/completed": float(self.blocks_completed),
        }
        if self._finished:
            self._summary = d
        return d
