"""Opt-in observability for the discrete-event core (ARCHITECTURE.md
§Telemetry).

Enable with ``SimConfig(telemetry=True)``: the facade hangs a
:class:`~.hub.Telemetry` hub off ``Simulator.telemetry`` and every layer's
hook sites light up behind their ``is not None`` guards. Off (the default)
means *no hub object exists* — the same zero-overhead contract as the trace
recorder and transport policies — and on or off, all goldens replay
bit-identical (probe ticks dispatch outside the pinned ``events`` count).

The package is jax-free and import-light; ``repro.core.canary`` only
imports it lazily when a config asks for telemetry.
"""
from .analysis import (Intervals, RunView, critical_path, hotspots,
                       load_dump, view_of)
from .attribution import (CAUSES, CONSERVATION_REL_TOL, Diagnosis,
                          attribute_app, attribute_block, diagnose)
from .export import (run_headline_cell, series_rows, to_dump, to_perfetto,
                     validate_perfetto, write_dump, write_perfetto,
                     write_series_csv, write_series_json)
from .hub import Telemetry
from .metrics import Histogram, MetricsRegistry, TimeSeries

__all__ = [
    "Telemetry", "MetricsRegistry", "Histogram", "TimeSeries",
    "to_perfetto", "write_perfetto", "validate_perfetto", "series_rows",
    "write_series_csv", "write_series_json", "to_dump", "write_dump",
    "run_headline_cell",
    # diagnosis layer (ARCHITECTURE.md §Diagnosis)
    "Intervals", "RunView", "view_of", "load_dump", "critical_path",
    "hotspots", "CAUSES", "CONSERVATION_REL_TOL", "Diagnosis",
    "attribute_block", "attribute_app", "diagnose",
]
