"""String-keyed metrics primitives for the telemetry hub.

Three metric families plus a time-series container, all deliberately dumb:

* **Counters** — monotonically increasing floats (``inc``).
* **Gauges** — last-value / high-water floats (``gauge_set`` / ``gauge_max``).
  High-water gauges are updated at the *event* that moves the value (e.g.
  descriptor allocation), so their maxima are exact even when the periodic
  probe cadence is too coarse to catch a transient peak.
* **Histograms** — power-of-two-bucketed distributions (``observe``) with
  exact count/sum/min/max, for latency-shaped values (block completion
  times, descriptor aggregation windows).
* **TimeSeries** — delta-encoded ``(t, value)`` samples: a record only
  appends when the value changed, so an idle link's backlog series is one
  point, not one per probe. Each series carries a hard sample cap; overflow
  increments ``dropped`` (never silent) while min/max stay exact.

Everything here is plain Python with no simulator imports, so the registry
is reusable by the fleet driver and the exporters, and the whole package
stays jax-free.
"""
from __future__ import annotations

import math
from typing import Dict, Iterator, List, Tuple

__all__ = ["Counter", "Histogram", "TimeSeries", "MetricsRegistry"]


class Histogram:
    """Power-of-two-bucketed distribution of non-negative values.

    Bucket ``i`` counts values ``v`` with ``2**(i-1) < v <= 2**i`` (bucket 0
    takes ``v <= 1``), i.e. the bucket index is the binary exponent of the
    value — cheap, unbounded-range, and good enough for latency shapes.
    """

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 1.0:
            i = 0
        else:
            # frexp(v) = (m, e) with 0.5 <= m < 1 and v = m * 2**e, so the
            # smallest power of two >= v is 2**e (e-1 when v is exact)
            m, e = math.frexp(v)
            i = e - 1 if m == 0.5 else e
        self.buckets[i] = self.buckets.get(i, 0) + 1

    def observe_many(self, values: List[float]) -> None:
        """Bulk ``observe``: one call replaying a whole value list, with the
        loop state held in locals. ``finish()`` replays the raw latency and
        aggregation-window lists through this — at small scales the replay
        is a measurable slice of the whole telemetry budget, and the
        per-call interpreter overhead of N ``observe`` calls dominates the
        arithmetic."""
        if not values:
            return
        frexp = math.frexp
        buckets = self.buckets
        get = buckets.get
        n = 0
        s = 0.0
        lo = self.min
        hi = self.max
        for v in values:
            n += 1
            s += v
            if v < lo:
                lo = v
            if v > hi:
                hi = v
            if v <= 1.0:
                i = 0
            else:
                m, e = frexp(v)
                i = e - 1 if m == 0.5 else e
            buckets[i] = get(i, 0) + 1
        self.count += n
        self.sum += s
        self.min = lo
        self.max = hi

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> Dict[str, object]:
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "mean": self.mean,
                "buckets": {str(k): v for k, v in sorted(self.buckets.items())}}


class TimeSeries:
    """Delta-encoded ``(t, value)`` samples with a hard cap.

    ``record`` is the hot call: it appends only when the value differs from
    the last recorded one. ``hi``/``lo`` track the exact extrema across every
    *offered* sample, so a capped series still reports true high-waters.
    """

    __slots__ = ("t", "v", "last", "hi", "lo", "dropped", "_cap")

    def __init__(self, cap: int = 200_000) -> None:
        self.t: List[float] = []
        self.v: List[float] = []
        self.last: float = math.nan  # nan != anything, so the 1st sample lands
        self.hi = -math.inf
        self.lo = math.inf
        self.dropped = 0
        self._cap = cap

    def record(self, t: float, value: float) -> None:
        if value != self.last:
            if value > self.hi:
                self.hi = value
            if value < self.lo:
                self.lo = value
            if len(self.t) < self._cap:
                self.t.append(t)
                self.v.append(value)
            else:
                self.dropped += 1
            self.last = value

    def __len__(self) -> int:
        return len(self.t)

    def points(self) -> Iterator[Tuple[float, float]]:
        return zip(self.t, self.v)


class Counter(float):
    """Marker type alias — counters live as plain floats in the registry."""


class MetricsRegistry:
    """Flat, string-keyed store of counters, gauges, histograms and series.

    Naming convention (used by probes, hooks and exporters alike):
    ``<scope>/<id>/<metric>`` — e.g. ``link/12/backlog_bytes``,
    ``switch/3/descriptors``, ``host/40/rate_gbps``, ``app/0/blocks_left``.
    Aggregates drop the id: ``net/backlog_max_bytes``.
    """

    __slots__ = ("counters", "gauges", "hists", "series", "_series_cap")

    def __init__(self, series_cap: int = 200_000) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}
        self.series: Dict[str, TimeSeries] = {}
        self._series_cap = series_cap

    # -- counters -----------------------------------------------------------
    def inc(self, name: str, delta: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + delta

    # -- gauges -------------------------------------------------------------
    def gauge_set(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def gauge_max(self, name: str, value: float) -> None:
        if value > self.gauges.get(name, -math.inf):
            self.gauges[name] = value

    # -- histograms ---------------------------------------------------------
    def hist(self, name: str) -> Histogram:
        """Resolve (creating if needed) a histogram — callers with a hot
        observe path keep the returned object instead of re-looking it up."""
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram()
        return h

    def observe(self, name: str, value: float) -> None:
        self.hist(name).observe(value)

    # -- time series ---------------------------------------------------------
    def ts(self, name: str) -> TimeSeries:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = TimeSeries(self._series_cap)
        return s

    def record(self, name: str, t: float, value: float) -> None:
        self.ts(name).record(t, value)

    # -- digests --------------------------------------------------------------
    def total_samples(self) -> int:
        return sum(len(s) for s in self.series.values())

    def samples_dropped(self) -> int:
        return sum(s.dropped for s in self.series.values())
