"""Post-run telemetry analysis: structured views, critical paths, hotspots.

This is the *reading* half of the diagnosis layer (ARCHITECTURE.md
§Diagnosis; the cause decomposition lives in ``attribution.py``). It never
touches the simulator — everything here consumes either a finished
:class:`~repro.core.telemetry.hub.Telemetry` hub (:func:`view_of`) or the
full-fidelity JSON dump the exporters write (:func:`load_dump` /
``export.write_dump``), so a diagnosis can run long after the process that
produced the telemetry is gone.

Three layers:

* :class:`Intervals` — a tiny sorted-disjoint interval-set algebra
  (union / subtract / intersect / measure over half-open ``[a, b)``
  ranges). The attribution's conservation contract rests on it: causes are
  *disjoint subsets of the block's own time axis*, so their measures can
  never sum past the measured span.
* :class:`RunView` — one run's telemetry as plain data: block lifecycle
  records (:class:`BlockRecord`), per-block descriptor windows, instant
  streams, probe series, config, metadata and truncation state, with the
  derived quantities attribution needs (wire estimate, pacing/PFC/congested
  intervals) computed lazily.
* :func:`critical_path` / :func:`hotspots` — per-job backward critical-path
  extraction over block spans (each instant of the job makespan is assigned
  to the block that was the *latest-finishing cover* for it, gaps become
  explicit idle segments) and per-link queueing-delay ranking over any
  window (per-tenant windows when run through the fleet driver).

Everything is plain Python with no simulator or jax imports.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Intervals", "BlockRecord", "DescWindow", "RunView", "Hotspot",
           "PathSegment", "view_of", "load_dump", "critical_path",
           "hotspots", "step_intervals_above", "step_integral"]


# ---------------------------------------------------------------- intervals
class Intervals:
    """Sorted, disjoint, half-open ``[a, b)`` interval set."""

    __slots__ = ("spans",)

    def __init__(self, spans: Optional[Iterable[Tuple[float, float]]] = None):
        merged: List[Tuple[float, float]] = []
        if spans:
            for a, b in sorted((float(a), float(b)) for a, b in spans):
                if b <= a:
                    continue
                if merged and a <= merged[-1][1]:
                    if b > merged[-1][1]:
                        merged[-1] = (merged[-1][0], b)
                else:
                    merged.append((a, b))
        self.spans = merged

    def measure(self) -> float:
        return sum(b - a for a, b in self.spans)

    def is_empty(self) -> bool:
        return not self.spans

    def union(self, other: "Intervals") -> "Intervals":
        return Intervals(self.spans + other.spans)

    def intersect(self, other: "Intervals") -> "Intervals":
        out, i, j = [], 0, 0
        a_sp, b_sp = self.spans, other.spans
        while i < len(a_sp) and j < len(b_sp):
            lo = max(a_sp[i][0], b_sp[j][0])
            hi = min(a_sp[i][1], b_sp[j][1])
            if hi > lo:
                out.append((lo, hi))
            if a_sp[i][1] <= b_sp[j][1]:
                i += 1
            else:
                j += 1
        r = Intervals.__new__(Intervals)
        r.spans = out
        return r

    def subtract(self, other: "Intervals") -> "Intervals":
        out = []
        j = 0
        b_sp = other.spans
        for a, b in self.spans:
            cur = a
            while j < len(b_sp) and b_sp[j][1] <= cur:
                j += 1
            k = j
            while k < len(b_sp) and b_sp[k][0] < b:
                if b_sp[k][0] > cur:
                    out.append((cur, b_sp[k][0]))
                cur = max(cur, b_sp[k][1])
                if cur >= b:
                    break
                k += 1
            if cur < b:
                out.append((cur, b))
        r = Intervals.__new__(Intervals)
        r.spans = out
        return r

    def clip(self, a: float, b: float) -> "Intervals":
        return self.intersect(Intervals([(a, b)]))

    def __repr__(self) -> str:  # debugging aid
        return f"Intervals({self.spans!r})"


# ------------------------------------------------------- step-function math
def step_intervals_above(t: Sequence[float], v: Sequence[float],
                         thresh: float, t_end: float) -> Intervals:
    """Intervals where the delta-encoded step series ``(t, v)`` exceeds
    ``thresh``. The series is right-continuous (each sample holds until the
    next) and the last value extends to ``t_end``."""
    spans = []
    open_at: Optional[float] = None
    for i, (ti, vi) in enumerate(zip(t, v)):
        if vi > thresh:
            if open_at is None:
                open_at = ti
        elif open_at is not None:
            spans.append((open_at, ti))
            open_at = None
    if open_at is not None and t_end > open_at:
        spans.append((open_at, t_end))
    return Intervals(spans)


def step_integral(t: Sequence[float], v: Sequence[float],
                  a: float, b: float) -> float:
    """``∫ v dt`` over ``[a, b]`` for a right-continuous step series whose
    last value extends past its final sample."""
    if b <= a or not t:
        return 0.0
    total = 0.0
    for i, ti in enumerate(t):
        seg_lo = max(ti, a)
        seg_hi = min(t[i + 1] if i + 1 < len(t) else b, b)
        if seg_hi > seg_lo:
            total += v[i] * (seg_hi - seg_lo)
    # before the first sample the series is implicitly 0, so nothing to add
    return total


# ------------------------------------------------------------------ records
@dataclass
class BlockRecord:
    """One block's lifecycle, reassembled from the hub's raw span tuples."""

    app: int
    block: int
    t0: float
    t1: float
    last_host: int = -1
    bcast_t0: Optional[float] = None   # leader_done -> done broadcast start
    leader: Optional[int] = None       # leader host (from leader_done)
    complete: bool = True              # False: still open at end of run

    @property
    def span_ns(self) -> float:
        return self.t1 - self.t0


@dataclass(frozen=True)
class DescWindow:
    """One descriptor aggregation window (alloc -> flush) on one switch."""

    sw: int
    reason: str      # "timeout" | "complete"
    merges: int
    t0: float
    t1: float


@dataclass
class Hotspot:
    """One link's queueing contribution over an analysis window."""

    link: int
    name: str
    mean_queue_ns: float     # time-averaged queue delay over the window
    peak_backlog_bytes: float
    busy_frac: float         # fraction of the window with backlog > 0

    def to_dict(self) -> dict:
        return {"link": self.link, "name": self.name,
                "mean_queue_ns": self.mean_queue_ns,
                "peak_backlog_bytes": self.peak_backlog_bytes,
                "busy_frac": self.busy_frac}


@dataclass(frozen=True)
class PathSegment:
    """One backward-walk segment of a job's makespan: either the portion of
    ``block``'s span that was the latest-finishing cover, or (``block is
    None``) an idle gap no recorded block span covers."""

    t0: float
    t1: float
    block: Optional[BlockRecord]

    @property
    def span_ns(self) -> float:
        return self.t1 - self.t0


# ----------------------------------------------------------------- run view
_FT_HOPS = 4          # host -> leaf -> spine -> leaf -> host
_TT_HOPS = 6          # host -> leaf -> agg -> core -> agg -> leaf -> host


class RunView:
    """One run's telemetry as plain, simulator-free data.

    Build with :func:`view_of` (live hub) or :func:`load_dump` (exported
    JSON); both produce identical views — the round trip is pinned by
    ``tests/core/test_diagnosis.py``.
    """

    def __init__(self, cfg: dict, meta: dict, spans: List[tuple],
                 instants: List[tuple], open_blocks: List[tuple],
                 series: Dict[str, Tuple[List[float], List[float]]],
                 counters: Dict[str, float], summary: Dict[str, float],
                 truncation: Dict[str, object]):
        self.cfg = cfg
        self.meta = meta or {}
        self.spans = spans
        self.instants = instants
        self.open_blocks = open_blocks
        self.series = series
        self.counters = counters
        self.summary = summary
        self.truncation = truncation or {}
        self._blocks: Optional[List[BlockRecord]] = None
        self._desc: Optional[Dict[Tuple[int, int], List[DescWindow]]] = None
        self._pfc: Optional[Intervals] = None
        self._fault_iv: Optional[Intervals] = None
        self._congested: Optional[Intervals] = None
        self._app_congested: Dict[Tuple[int, ...], Intervals] = {}
        self._pacing: Dict[Tuple[int, ...], Intervals] = {}

    # -- config-derived scalars ---------------------------------------------
    @property
    def bytes_per_ns(self) -> float:
        return float(self.cfg.get("link_gbps", 100.0)) / 8.0

    @property
    def mtu_bytes(self) -> int:
        return int(self.cfg.get("payload_bytes", 1024)) + \
            int(self.cfg.get("header_bytes", 57))

    @property
    def timeout_ns(self) -> float:
        return float(self.cfg.get("timeout_ns", 1000.0))

    @property
    def retx_timeout_ns(self) -> float:
        return float(self.cfg.get("retx_timeout_ns", 2.0e5))

    @property
    def gbn_timeout_ns(self) -> float:
        return float(self.cfg.get("gbn_timeout_ns", 2.0e5))

    @property
    def num_hosts(self) -> int:
        n = self.meta.get("num_hosts")
        if n:
            return int(n)
        return int(self.cfg.get("num_leaves", 0)) * \
            int(self.cfg.get("hosts_per_leaf", 0))

    @property
    def hops(self) -> int:
        return _TT_HOPS if str(self.cfg.get("topology")) == "three_tier" \
            else _FT_HOPS

    @property
    def wire_estimate_ns(self) -> float:
        """Uncontended time for one block packet to cross the fabric and be
        leader-processed: per-hop serialization + propagation, times the
        topology's host-to-host hop count, plus the host-side leader term."""
        ser = self.mtu_bytes / self.bytes_per_ns
        lat = float(self.cfg.get("hop_latency_ns", 300.0))
        return self.hops * (ser + lat) + \
            float(self.cfg.get("leader_aggregate_ns", 1000.0))

    @property
    def collision_detour_ns(self) -> float:
        """Cost estimate of one §3.2.1 collision: the contribution bypasses
        in-network aggregation, crosses one extra effective hop and must be
        serially host-aggregated at the leader."""
        ser = self.mtu_bytes / self.bytes_per_ns
        return float(self.cfg.get("hop_latency_ns", 300.0)) + ser + \
            float(self.cfg.get("leader_aggregate_ns", 1000.0))

    @property
    def t_end(self) -> float:
        ends = [b.t1 for b in self.blocks()]
        for _, (t, _v) in self.series.items():
            if t:
                ends.append(t[-1])
        return max(ends, default=0.0)

    @property
    def probes_on(self) -> bool:
        return self.summary.get("probes", 0.0) > 0.0

    @property
    def truncated(self) -> bool:
        return bool(self.truncation.get("spans_dropped", 0)
                    or self.truncation.get("samples_dropped", 0)
                    or self.truncation.get("pkt_instants_capped", False))

    @property
    def loss_evidence(self) -> bool:
        """Did the run record any actual packet loss? Block-level retx
        requests fire on a host timer and also trigger under pure
        congestion; without loss evidence they are a *symptom*, so the
        attribution refuses to charge their windows to ``retx_recovery``."""
        if any(s[0] == "drop" for s in self.instants):
            return True
        return any(k.startswith("drops/") and v > 0
                   for k, v in self.counters.items())

    # -- metadata ------------------------------------------------------------
    def apps(self) -> List[int]:
        meta_apps = self.meta.get("apps", {})
        if meta_apps:
            return sorted(int(a) for a in meta_apps)
        return sorted({b.app for b in self.blocks()})

    def participants(self, app: int) -> List[int]:
        info = self.meta.get("apps", {}).get(str(app)) or \
            self.meta.get("apps", {}).get(app) or {}
        return list(info.get("participants", []))

    def tenant_of(self, app: int) -> int:
        info = self.meta.get("apps", {}).get(str(app)) or \
            self.meta.get("apps", {}).get(app) or {}
        t = int(info.get("tenant", -1))
        return t if t >= 0 else app

    def link_name(self, i: int) -> str:
        names = self.meta.get("link_names") or []
        return names[i] if i < len(names) else f"link/{i}"

    # -- reassembled records -------------------------------------------------
    def blocks(self) -> List[BlockRecord]:
        """Block lifecycle records, completed spans first then open ones."""
        if self._blocks is not None:
            return self._blocks
        bcast: Dict[Tuple[int, int], float] = {}
        leader_done: Dict[Tuple[int, int], float] = {}
        leaders: Dict[Tuple[int, int], int] = {}
        for s in self.spans:
            if s[0] == "bcast":
                _, app, block, t0, _t1 = s
                bcast[(int(app), int(block))] = float(t0)
        for s in self.instants:
            if s[0] == "leader_done":
                _, app, block, leader, t = s
                key = (int(app), int(block))
                leader_done.setdefault(key, float(t))
                leaders.setdefault(key, int(leader))
        out: List[BlockRecord] = []
        for s in self.spans:
            if s[0] != "block":
                continue
            _, app, block, t0, t1, last_host = s
            key = (int(app), int(block))
            out.append(BlockRecord(
                app=key[0], block=key[1], t0=float(t0), t1=float(t1),
                last_host=int(last_host),
                bcast_t0=bcast.get(key, leader_done.get(key)),
                leader=leaders.get(key)))
        for ob in self.open_blocks:
            app, block, t0, t_end = ob
            out.append(BlockRecord(app=int(app), block=int(block),
                                   t0=float(t0), t1=float(t_end),
                                   complete=False))
        self._blocks = out
        return out

    def desc_windows(self, app: int, block: int) -> List[DescWindow]:
        if self._desc is None:
            d: Dict[Tuple[int, int], List[DescWindow]] = {}
            for s in self.spans:
                if s[0] != "desc":
                    continue
                _, sw, a, b, reason, merges, _children, t0, t1 = s
                d.setdefault((int(a), int(b)), []).append(DescWindow(
                    sw=int(sw), reason=str(reason), merges=int(merges),
                    t0=float(t0), t1=float(t1)))
            self._desc = d
        return self._desc.get((app, block), [])

    # -- instant streams -----------------------------------------------------
    def retx_instants(self, app: int, block: int) -> List[Tuple[str, float]]:
        """Block-level recovery instants: [(what, t), ...]."""
        return [(s[1], float(s[5])) for s in self.instants
                if s[0] == "retx" and int(s[2]) == app and int(s[4]) == block]

    def gbn_retx_instants(self, hosts: Optional[set] = None
                          ) -> List[Tuple[int, float]]:
        out = []
        for s in self.instants:
            if s[0] == "gbn" and s[1] == "retx":
                host = int(s[2])
                if hosts is None or not hosts or host in hosts:
                    out.append((host, float(s[4])))
        return out

    def collision_instants(self, app: int, block: int) -> List[float]:
        return [float(s[4]) for s in self.instants
                if s[0] in ("collision", "straggler") and s[0] == "collision"
                and int(s[2]) == app and int(s[3]) == block]

    # -- derived interval sets ----------------------------------------------
    def pfc_intervals(self) -> Intervals:
        """Union of PFC pause windows across all paused senders. A pause
        without a matching resume extends to the end of the run."""
        if self._pfc is not None:
            return self._pfc
        open_at: Dict[int, float] = {}
        spans: List[Tuple[float, float]] = []
        t_end = self.t_end
        for s in self.instants:
            if s[0] != "pfc":
                continue
            _, host, paused, t = s
            host, t = int(host), float(t)
            if paused:
                open_at.setdefault(host, t)
            else:
                t0 = open_at.pop(host, None)
                if t0 is not None:
                    spans.append((t0, t))
        spans.extend((t0, t_end) for t0 in open_at.values())
        self._pfc = Intervals(spans)
        return self._pfc

    def fault_intervals(self) -> Intervals:
        """Union of fault-active windows (repro.core.faults): a "fault"
        instant with ``active`` True opens a window keyed by (kind, target),
        its heal (``active`` False) closes it; an unhealed fault extends to
        the end of the run."""
        if self._fault_iv is not None:
            return self._fault_iv
        open_at: Dict[Tuple[str, object], float] = {}
        spans: List[Tuple[float, float]] = []
        t_end = self.t_end
        for s in self.instants:
            if s[0] != "fault":
                continue
            _, kind, target, active, t = s
            key = (str(kind), target)
            if active:
                open_at.setdefault(key, float(t))
            else:
                t0 = open_at.pop(key, None)
                if t0 is not None:
                    spans.append((t0, float(t)))
        spans.extend((t0, t_end) for t0 in open_at.values())
        self._fault_iv = Intervals(spans)
        return self._fault_iv

    def pacing_intervals(self, hosts: Sequence[int]) -> Intervals:
        """Union of the windows during which any of ``hosts`` was DCQCN-paced
        below line rate (from the per-host ``rate_gbps`` probe series)."""
        key = tuple(sorted(hosts))
        cached = self._pacing.get(key)
        if cached is not None:
            return cached
        line = float(self.cfg.get("link_gbps", 100.0))
        thresh = -(line * (1.0 - 1e-9))   # v > thresh  <=>  rate < line
        t_end = self.t_end
        acc = Intervals()
        for h in key:
            s = self.series.get(f"host/{h}/rate_gbps")
            if not s or not s[0]:
                continue
            t, v = s
            acc = acc.union(step_intervals_above(
                t, [-x for x in v], thresh, t_end))
        self._pacing[key] = acc
        return acc

    def congested_intervals(self, thresh_bytes: Optional[float] = None
                            ) -> Intervals:
        """Windows during which the most-backlogged fabric link held more
        than ``thresh_bytes`` (default: one MTU) of queued bytes."""
        if thresh_bytes is None and self._congested is not None:
            return self._congested
        s = self.series.get("net/backlog_max_bytes")
        if not s or not s[0]:
            return Intervals()
        thr = float(self.mtu_bytes if thresh_bytes is None else thresh_bytes)
        out = step_intervals_above(s[0], s[1], thr, self.t_end)
        if thresh_bytes is None:
            self._congested = out
        return out

    def app_congested_intervals(self, participants: Sequence[int]
                                ) -> Intervals:
        """Congested windows on links that can actually carry this app's
        traffic: the participants' own host links plus every fabric link
        (leaf/spine/agg/core). Host links of *other* hosts — e.g.
        background-traffic sinks — are excluded: their queues cannot delay
        this app, and charging their backlog would misattribute bystander
        congestion. Falls back to the global signal when no participant set
        is known."""
        key = tuple(sorted(participants))
        if not key:
            return self.congested_intervals()
        cached = self._app_congested.get(key)
        if cached is not None:
            return cached
        n = self.num_hosts
        relevant = set(key) | {n + p for p in key}
        thr = float(self.mtu_bytes)
        spans: List[Tuple[float, float]] = []
        for name, (t, v) in self.series.items():
            if not (name.startswith("link/")
                    and name.endswith("/backlog_bytes")) or not t:
                continue
            idx = int(name.split("/")[1])
            if idx < 2 * n and idx not in relevant:
                continue
            spans.extend(
                step_intervals_above(t, v, thr, self.t_end).spans)
        out = Intervals(spans)
        self._app_congested[key] = out
        return out

    def link_congested_intervals(self, link: int,
                                 thresh_bytes: Optional[float] = None
                                 ) -> Intervals:
        """Windows during which one specific link held more than
        ``thresh_bytes`` (default: one MTU) of queued bytes."""
        s = self.series.get(f"link/{link}/backlog_bytes")
        if not s or not s[0]:
            return Intervals()
        thr = float(self.mtu_bytes if thresh_bytes is None else thresh_bytes)
        return step_intervals_above(s[0], s[1], thr, self.t_end)


# ------------------------------------------------------------- constructors
def view_of(tel) -> RunView:
    """Build a :class:`RunView` from a finished live telemetry hub."""
    import dataclasses
    cfg = dataclasses.asdict(tel.cfg)
    series = {name: (list(ts.t), list(ts.v))
              for name, ts in tel.registry.series.items()}
    return RunView(cfg=cfg, meta=getattr(tel, "meta", {}) or {},
                   spans=[tuple(s) for s in tel.spans],
                   instants=[tuple(s) for s in tel.instants],
                   open_blocks=[tuple(b) for b in
                                getattr(tel, "open_blocks", [])],
                   series=series, counters=dict(tel.registry.counters),
                   summary=tel.summary_dict(),
                   truncation=tel.truncation_dict())


def load_dump(path_or_doc) -> RunView:
    """Build a :class:`RunView` from ``export.write_dump`` output (a path or
    an already-loaded document)."""
    if isinstance(path_or_doc, (str, bytes)):
        with open(path_or_doc) as f:
            doc = json.load(f)
    else:
        doc = path_or_doc
    version = doc.get("version")
    if version != 1:
        raise ValueError(f"unsupported telemetry dump version {version!r}")
    series = {name: (list(s["t"]), list(s["v"]))
              for name, s in doc.get("series", {}).items()}
    return RunView(cfg=doc.get("cfg", {}), meta=doc.get("meta", {}),
                   spans=[tuple(s) for s in doc.get("spans", [])],
                   instants=[tuple(s) for s in doc.get("instants", [])],
                   open_blocks=[tuple(b) for b in
                                doc.get("open_blocks", [])],
                   series=series, counters=doc.get("counters", {}),
                   summary=doc.get("summary", {}),
                   truncation=doc.get("truncation", {}))


# ------------------------------------------------------------ critical path
def critical_path(view: RunView, app: int) -> List[PathSegment]:
    """Backward critical-path walk over ``app``'s block spans.

    Partitions the job makespan ``[min t0, max t1]`` into segments, each
    owned by the block that was the *latest-finishing active cover* at that
    time (walking backward from the finish, always extending with the
    covering block whose span reaches furthest back). Time no block span
    covers becomes an explicit idle segment (``block is None``). Segment
    lengths sum to the makespan exactly — the job-level half of the
    conservation contract.
    """
    blocks = [b for b in view.blocks() if b.app == app]
    if not blocks:
        return []
    job_t0 = min(b.t0 for b in blocks)
    job_t1 = max(b.t1 for b in blocks)
    segments: List[PathSegment] = []
    cur = job_t1
    remaining = sorted(blocks, key=lambda b: b.t1, reverse=True)
    eps = 1e-9
    while cur > job_t0 + eps:
        covering = [b for b in remaining if b.t0 < cur and b.t1 >= cur - eps]
        if covering:
            best = min(covering, key=lambda b: b.t0)
            segments.append(PathSegment(t0=best.t0, t1=cur, block=best))
            cur = best.t0
        else:
            earlier = [b for b in remaining if b.t1 < cur]
            gap_to = max((b.t1 for b in earlier), default=job_t0)
            segments.append(PathSegment(t0=gap_to, t1=cur, block=None))
            cur = gap_to
    segments.reverse()
    return segments


def job_interval(view: RunView, app: int) -> Optional[Tuple[float, float]]:
    blocks = [b for b in view.blocks() if b.app == app]
    if not blocks:
        return None
    return (min(b.t0 for b in blocks), max(b.t1 for b in blocks))


# ----------------------------------------------------------------- hotspots
def hotspots(view: RunView, window: Optional[Intervals] = None,
             top: Optional[int] = None) -> List[Hotspot]:
    """Rank fabric links by their time-averaged queueing delay over
    ``window`` (default: the whole run). The score is the mean extra delay a
    packet crossing that link during the window would have seen —
    ``∫ backlog(t) dt / (bytes_per_ns · |window|)`` — which is exactly the
    per-link utilization signal SOAR-style bounded placement consumes."""
    if window is None:
        window = Intervals([(0.0, max(view.t_end, 1e-9))])
    dur = window.measure()
    if dur <= 0.0:
        return []
    bpn = view.bytes_per_ns
    out: List[Hotspot] = []
    for name, (t, v) in view.series.items():
        if not name.startswith("link/") or not name.endswith("/backlog_bytes"):
            continue
        if not t:
            continue
        idx = int(name.split("/")[1])
        integral = 0.0
        for a, b in window.spans:
            integral += step_integral(t, v, a, b)
        busy_iv = step_intervals_above(t, v, 0.0, view.t_end)
        busy = busy_iv.intersect(window).measure()
        # peak over the window only: each sample holds on [t[i], t[i+1])
        peak = 0.0
        for i, vi in enumerate(v):
            seg = Intervals([(t[i], t[i + 1] if i + 1 < len(t)
                              else max(view.t_end, t[i] + 1e-9))])
            if vi > peak and not seg.intersect(window).is_empty():
                peak = vi
        if integral <= 0.0 and peak <= 0.0:
            continue
        out.append(Hotspot(link=idx, name=view.link_name(idx),
                           mean_queue_ns=integral / (bpn * dur),
                           peak_backlog_bytes=peak,
                           busy_frac=busy / dur))
    out.sort(key=lambda h: h.mean_queue_ns, reverse=True)
    return out[:top] if top else out
