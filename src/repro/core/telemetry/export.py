"""Exporters for the telemetry hub: Perfetto trace JSON + flat time series.

Two wire formats (ARCHITECTURE.md §Telemetry):

* **Perfetto / Chrome trace-event JSON** (:func:`to_perfetto`): load the
  file in https://ui.perfetto.dev. Spans become async ``"b"``/``"e"``
  event pairs (they overlap freely — descriptor windows on one switch do),
  instants become ``"i"`` events, and every time series becomes a ``"C"``
  counter track. Tracks are grouped into synthetic processes: apps,
  switches, hosts, fabric. Timestamps are microseconds (the trace-event
  unit); sub-ns precision survives as fractional ts.
* **Flat series dump** (:func:`write_series_csv` / ``write_series_json``):
  one ``series,t_ns,value`` row per recorded sample, for pandas/gnuplot.

:func:`validate_perfetto` is the schema check CI runs against the emitted
JSON — it returns a list of human-readable violations (empty = valid).
"""
from __future__ import annotations

import json
from typing import Dict, Iterator, List, Tuple

__all__ = ["to_perfetto", "write_perfetto", "series_rows",
           "write_series_csv", "write_series_json", "validate_perfetto",
           "to_dump", "write_dump", "DUMP_VERSION", "run_headline_cell"]

# synthetic Perfetto processes, one per track kind
_PIDS = {"app": 1, "sw": 2, "host": 3, "net": 4}
_PROC_NAMES = {1: "apps (block lifecycle)", 2: "switches (descriptors)",
               3: "hosts (transport)", 4: "fabric (drops + counters)"}
# counter series are attached to a process by name prefix
_SERIES_PID = (("link/", 4), ("net/", 4), ("switch/", 2), ("host/", 3),
               ("tp/", 3), ("app/", 1))


def _series_pid(name: str) -> int:
    for prefix, pid in _SERIES_PID:
        if name.startswith(prefix):
            return pid
    return 4


def to_perfetto(tel) -> Dict[str, object]:
    """Render a :class:`~repro.core.telemetry.hub.Telemetry` hub as a
    Chrome trace-event document (``{"traceEvents": [...]}``)."""
    ev: List[dict] = []
    tracks = set()
    for pid, pname in _PROC_NAMES.items():
        ev.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                   "args": {"name": pname}})
    span_id = 0

    def _span(cat, track, tid, name, t0, t1, args=None):
        nonlocal span_id
        span_id += 1
        pid = _PIDS[track]
        tracks.add((pid, tid, track))
        b = {"ph": "b", "cat": cat, "id": span_id, "pid": pid, "tid": tid,
             "ts": t0 / 1e3, "name": name}
        if args:
            b["args"] = args
        ev.append(b)
        ev.append({"ph": "e", "cat": cat, "id": span_id, "pid": pid,
                   "tid": tid, "ts": t1 / 1e3, "name": name})

    def _instant(cat, track, tid, name, t, args=None):
        pid = _PIDS[track]
        tracks.add((pid, tid, track))
        e = {"ph": "i", "cat": cat, "pid": pid, "tid": tid, "ts": t / 1e3,
             "name": name, "s": "t"}
        if args:
            e["args"] = args
        ev.append(e)

    # render the hub's raw tuples (shapes documented in hub.py) — all string
    # formatting happens here, off the simulation hot path
    for s in tel.spans:
        kind = s[0]
        if kind == "block":
            _, app, block, t0, t1, last_host = s
            _span("block", "app", app, f"block {block}", t0, t1,
                  {"app": app, "block": block, "last_host": last_host})
        elif kind == "bcast":
            _, app, block, t0, t1 = s
            _span("bcast", "app", app, f"bcast {block}", t0, t1,
                  {"app": app, "block": block})
        else:  # ("desc", sw, app, block, reason, merges, children, t0, t1)
            _, sw, app, block, reason, merges, children, t0, t1 = s
            _span("desc", "sw", sw, f"desc a{app}/b{block}", t0, t1,
                  {"reason": reason, "merges": merges, "children": children})
    for s in tel.instants:
        kind = s[0]
        if kind == "leader_done":
            _, app, block, leader, t = s
            _instant("block", "app", app, f"leader_done b{block}", t,
                     {"leader": leader})
        elif kind in ("collision", "straggler"):
            _, sw, app, block, t = s
            _instant("switch", "sw", sw, f"{kind} a{app}/b{block}", t,
                     {"app": app, "block": block})
        elif kind == "drop":
            _, cause, where, t = s
            _instant("drop", "net", 0, f"drop {cause}", t, {"where": where})
        elif kind == "retx":
            _, what, app, host, block, t = s
            _instant("retx", "app", app, f"retx {what} b{block}", t,
                     {"host": host})
        elif kind == "cnp":
            _, dst, src, t = s
            _instant("tp", "host", dst, "cnp", t, {"from": src})
        elif kind == "pfc":
            _, host, paused, t = s
            _instant("tp", "host", host,
                     "pfc_pause" if paused else "pfc_resume", t)
        else:  # ("gbn", what, host, count, t)
            _, what, host, count, t = s
            _instant("tp", "host", host, f"gbn_{what}", t, {"count": count})
    for sname, ts in tel.registry.series.items():
        pid = _series_pid(sname)
        for t, v in ts.points():
            ev.append({"ph": "C", "pid": pid, "tid": 0, "ts": t / 1e3,
                       "name": sname, "args": {"value": v}})
    for pid, tid, track in sorted(tracks):
        ev.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                   "args": {"name": f"{track} {tid}"}})
    return {"traceEvents": ev, "displayTimeUnit": "ns",
            "otherData": {"generator": "repro.core.telemetry",
                          "probes": tel.probes,
                          "spans_dropped": tel.spans_dropped}}


def write_perfetto(tel, path: str) -> Dict[str, object]:
    doc = to_perfetto(tel)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


# ---------------------------------------------------------------- flat series
def series_rows(tel) -> Iterator[Tuple[str, float, float]]:
    for name in sorted(tel.registry.series):
        for t, v in tel.registry.series[name].points():
            yield name, t, v


def write_series_csv(tel, path: str) -> int:
    n = 0
    with open(path, "w") as f:
        f.write("series,t_ns,value\n")
        for name, t, v in series_rows(tel):
            f.write(f"{name},{t!r},{v!r}\n")
            n += 1
    return n


def write_series_json(tel, path: str) -> int:
    doc = {name: {"t_ns": list(ts.t), "value": list(ts.v),
                  "hi": ts.hi, "lo": ts.lo, "dropped": ts.dropped}
           for name, ts in sorted(tel.registry.series.items())}
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(len(s["t_ns"]) for s in doc.values())


# --------------------------------------------------------- full-fidelity dump
DUMP_VERSION = 1


def to_dump(tel) -> Dict[str, object]:
    """Full-fidelity telemetry dump: everything the post-run diagnosis layer
    (``analysis.load_dump`` / ``scripts/diagnose.py``) needs, as one
    strict-JSON document — raw span/instant tuples, every probe series,
    counters, histograms, run metadata and the truncation state that a
    diagnosis must surface. Unlike :func:`to_perfetto` this is lossless:
    ``analysis.load_dump(to_dump(tel))`` and ``analysis.view_of(tel)``
    produce identical views (pinned by ``tests/core/test_diagnosis.py``)."""
    import dataclasses
    reg = tel.registry
    return {
        "version": DUMP_VERSION,
        "cfg": dataclasses.asdict(tel.cfg),
        "meta": getattr(tel, "meta", {}) or {},
        "summary": tel.summary_dict(),
        "truncation": tel.truncation_dict(),
        "spans": [list(s) for s in tel.spans],
        "instants": [list(s) for s in tel.instants],
        "open_blocks": [list(b) for b in getattr(tel, "open_blocks", [])],
        "counters": dict(reg.counters),
        "series": {name: {"t": list(ts.t), "v": list(ts.v),
                          # empty series carry +-inf extrema sentinels,
                          # which strict JSON cannot represent
                          "hi": ts.hi if ts.t else 0.0,
                          "lo": ts.lo if ts.t else 0.0,
                          "dropped": ts.dropped}
                   for name, ts in sorted(reg.series.items())},
        "hists": {name: h.to_dict()
                  for name, h in sorted(reg.hists.items())},
    }


def write_dump(tel, path: str) -> Dict[str, object]:
    doc = to_dump(tel)
    with open(path, "w") as f:
        json.dump(doc, f, allow_nan=False)
    return doc


# ------------------------------------------------------------------ validator
_PHASES = {"b", "e", "i", "C", "M", "X"}


def validate_perfetto(doc) -> List[str]:
    """Schema check for the trace-event JSON. Returns a list of violations
    (empty list = the document is loadable by ui.perfetto.dev)."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be a dict with a 'traceEvents' list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    open_async: Dict[Tuple, int] = {}
    for i, e in enumerate(events):
        where = f"event {i}"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            errs.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str):
            errs.append(f"{where}: missing name")
        if not isinstance(e.get("pid"), int) or not isinstance(
                e.get("tid"), int):
            errs.append(f"{where}: pid/tid must be ints")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)):
                errs.append(f"{where}: ph {ph!r} needs a numeric ts")
        if ph in ("b", "e"):
            if "id" not in e or not isinstance(e.get("cat"), str):
                errs.append(f"{where}: async event needs id + cat")
            else:
                key = (e["cat"], e["id"])
                open_async[key] = open_async.get(key, 0) + (
                    1 if ph == "b" else -1)
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                errs.append(f"{where}: counter args must be numeric")
        elif ph == "X" and not isinstance(e.get("dur"), (int, float)):
            errs.append(f"{where}: complete event needs dur")
    for key, n in open_async.items():
        if n != 0:
            errs.append(f"async span {key} unbalanced (b-e = {n})")
    return errs


# ------------------------------------------------------------- headline cell
def run_headline_cell(scale: int = 8, data_bytes: int = 1 << 20,
                      seed: int = 3, background: bool = True,
                      **cfg_overrides):
    """Run the headline congested fat-tree cell with telemetry on: half the
    hosts allreduce under CANARY while the other half blasts background
    congestion traffic (disable with ``background=False`` for scenarios
    that need the injected bottleneck isolated), with sender-side noise so
    descriptor windows actually expire (timeout flushes). Returns the
    finished ``Simulator`` (telemetry hub at ``sim.telemetry``, result at
    ``sim.telemetry_result``).
    """
    from ..canary import Algo, AllreduceJob, Simulator, scaled_config
    base = dict(seed=seed, noise_prob=0.05, telemetry=True)
    base.update(cfg_overrides)
    cfg = scaled_config(scale, **base)
    n = cfg.num_hosts
    sim = Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), data_bytes)],
                    algo=Algo.CANARY,
                    noise_hosts=list(range(n // 2, n)) if background else [])
    sim.telemetry_result = sim.run()
    return sim
