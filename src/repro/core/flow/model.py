"""Lower one experiment cell to a flow-level bandwidth-sharing problem.

One sweep work item (same dict schema as ``benchmarks/sweep.py`` /
``repro.core.canary.backends``) becomes one :class:`FlowCell`: a small set
of *modeled links* — the per-leaf fabric links the allreduce actually
crosses, each with a foreground byte load and a background noise demand —
plus the scalar pipe/tail terms. The solver (``batch.py``) then evaluates

    T_bw  = max over links of  load / (C * max(1 - kappa*g, floor))
    T_mix = T_send * (1 + mu * g_mix)
    T     = max(T_bw, T_mix) + tail * (1 + nu * g_mix)

i.e. the epoch is bandwidth-limited by its most contended link under
max-min fair sharing with competing noise flows (T_bw), but never beats
the serialization + congested-pipe time of the host->leader stream
(T_mix); the latency tail (leaf timeout windows, leader aggregation, hops)
rides on top and crosses the same congested links.

The lowering replicates the *exact* placement the packet engine would use
(``run_allreduce``'s per-rep RNG: participants via ``rng.sample``, noise =
complement), so per-rep variation in the flow backend comes from the same
source as in the packet engine: where the hosts landed. What it does NOT
replicate is within-run randomness (flowlet hashes, adaptive LB draws,
static-root draws from the simulator RNG) — that is the documented,
calibrated-over divergence (ARCHITECTURE.md §Backends).

Everything here is pure Python (no jax, no numpy): lowering must be
importable wherever ``repro.core.canary`` is.
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.canary.types import SimConfig

from .calibrate import FamilyParams, params_for


@dataclass
class FlowCell:
    """One lowered experiment cell (plain floats/lists: jax-free)."""

    label: str
    rep: int
    # modeled links: parallel lists, one entry per link class instance
    link_load_bytes: List[float] = field(default_factory=list)
    link_noise_frac: List[float] = field(default_factory=list)
    link_names: List[str] = field(default_factory=list)   # diagnostics only
    # scalar pipe/tail terms
    t_send_ns: float = 0.0
    tail_ns: float = 0.0
    g_mix: float = 0.0
    bytes_per_ns: float = 12.5
    data_bits: float = 0.0
    # per-cell calibration scalars (resolved at lowering from the family)
    kappa: float = 1.0
    floor: float = 0.08
    mu: float = 2.0
    nu: float = 1.0

    def add_link(self, name: str, load_bytes: float, noise_frac: float):
        self.link_names.append(name)
        self.link_load_bytes.append(load_bytes)
        self.link_noise_frac.append(noise_frac)


def expected_distinct(n_draws: int, n_slots: int) -> float:
    """E[#distinct] of ``n_draws`` uniform draws over ``n_slots`` (static
    tree roots / designated switches are drawn with replacement)."""
    if n_slots <= 0:
        return 1.0
    return n_slots * (1.0 - (1.0 - 1.0 / n_slots) ** n_draws)


def _placement(cfg: SimConfig, item: dict) -> Tuple[List[int], List[int]]:
    """Replicate run_allreduce's per-rep host split exactly."""
    rng = random.Random(cfg.seed * 1000003 + item["rep"])
    chosen = rng.sample(range(cfg.num_hosts), item["num_hosts"])
    if item.get("congestion"):
        chosen_set = set(chosen)
        noise = [h for h in range(cfg.num_hosts) if h not in chosen_set]
    else:
        noise = []
    return chosen, noise


def _per_leaf_counts(cfg: SimConfig, hosts: List[int]) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for h in hosts:
        leaf = h // cfg.hosts_per_leaf
        counts[leaf] = counts.get(leaf, 0) + 1
    return counts


def _noise_split(q_leaf: int, q_total: int) -> float:
    """Fraction of one noise host's (line-rate) traffic that leaves its
    leaf: destinations are uniform over the *other* noise hosts."""
    if q_total <= 1:
        return 0.0
    return (q_total - q_leaf) / (q_total - 1)


def lower_item(item: dict) -> FlowCell:
    """Lower one sweep work item into a :class:`FlowCell`."""
    cfg = SimConfig(**item["cfg"])
    if cfg.transport != "none":
        # the flow model has no packets, queues or timers — silently ignoring
        # a transport policy would report fidelity it doesn't have
        raise ValueError(
            f"the flow backend cannot model transport={cfg.transport!r}; "
            "use backend='packet' for transport-policy experiments")
    if cfg.telemetry:
        # no packets, descriptors or probe events exist here — there is
        # nothing for the telemetry hub to observe
        raise ValueError(
            "the flow backend cannot record telemetry; "
            "use backend='packet' for telemetry runs")
    if cfg.faults:
        # no event stream exists to inject EV_FAULT/EV_HEAL into, and the
        # closed-form solver has no notion of a mid-run topology change —
        # silently dropping the schedule would fake survivability results
        raise ValueError(
            "the flow backend cannot model fault injection; "
            "use backend='packet' for fault-schedule experiments")
    if "lb" in item:
        cfg = dataclasses.replace(cfg, lb=item["lb"])
    algo = item["algo"]
    n_trees = int(item.get("n_trees", 1))
    chosen, noise = _placement(cfg, item)
    p = len(chosen)
    blocks = max(1, -(-item["data_bytes"] // cfg.payload_bytes))
    mtu = cfg.mtu_bytes
    wire = float(blocks * mtu)              # framed bytes of one full pass
    c_bps = cfg.bytes_per_ns
    fam = params_for(cfg.topology, algo)

    cell = FlowCell(label=item["label"], rep=item["rep"],
                    bytes_per_ns=c_bps,
                    data_bits=float(item["data_bytes"] * 8),
                    kappa=fam.kappa, floor=fam.floor, nu=fam.nu)

    p_leaf = _per_leaf_counts(cfg, chosen)
    q_leaf = _per_leaf_counts(cfg, noise)
    q_total = len(noise)

    if algo == "ring":
        _lower_ring(cell, cfg, fam, item, p, q_leaf, q_total)
        return cell

    # ---- serialization pipe: every host streams all B blocks once --------
    cell.t_send_ns = wire / c_bps
    cell.add_link("host_up_nic", wire, 0.0)          # participant NICs are
    cell.add_link("host_down_nic", wire, 0.0)        # private: no noise share

    if cfg.topology == "three_tier":
        g_mix = _lower_three_tier(cell, cfg, fam, algo, n_trees, wire,
                                  p, p_leaf, q_leaf, q_total)
        # cross-pod path: host-leaf-agg-core-agg-leaf-leader and back
        hops, timeout_levels = 12, 3    # descriptors at leaf+agg+core all
    else:                               # ride out the aggregation window
        g_mix = _lower_fat_tree(cell, cfg, fam, algo, n_trees, wire,
                                p, p_leaf, q_leaf, q_total)
        hops, timeout_levels = 5, 1     # leaf/spine windows overlap

    cell.g_mix = g_mix
    # mu resolved per family; static trees feel root concentration in the
    # pipe before the hard per-link bound does (mu_ntree / E[distinct])
    cell.mu = fam.mu
    if algo == "static_tree":
        slots = cfg.num_spines if cfg.topology == "fat_tree" else \
            max(1, cfg.aggs_per_pod)
        cell.mu += fam.mu_ntree / expected_distinct(n_trees, slots)

    # ---- latency tail ----------------------------------------------------
    if algo == "canary":
        # switch descriptors always ride out the aggregation window (their
        # `hosts` field counts global participants, not local fan-in), the
        # leader adds its per-block processing, and the leader's broadcast
        # of its own B/p blocks drains behind the tail of its send stream.
        own = blocks / max(1, p)
        cell.tail_ns = (timeout_levels * cfg.timeout_ns
                        + cfg.leader_aggregate_ns
                        + hops * cfg.hop_latency_ns
                        + 2.0 * own * mtu / c_bps)
    else:
        # static trees flush on exact expected counts: hops only (the
        # broadcast pipeline hides most of the return path)
        cell.tail_ns = (hops - 4 if cfg.topology == "three_tier" else hops) \
            * cfg.hop_latency_ns
    return cell


# --------------------------------------------------------------------- fat
def _lower_fat_tree(cell: FlowCell, cfg: SimConfig, fam: FamilyParams,
                    algo: str, n_trees: int, wire: float, p: int,
                    p_leaf: Dict[int, int], q_leaf: Dict[int, int],
                    q_total: int) -> float:
    """Model the 2-level leaf/spine fabric; returns g_mix."""
    spines = max(1, cfg.num_spines)
    # how many distinct leaf->spine links the foreground spreads over:
    # CANARY hashes blocks over every spine; N static trees concentrate on
    # E[distinct roots] designated spine links per leaf.
    if algo == "canary":
        spread = float(spines)
    else:
        spread = expected_distinct(n_trees, spines)

    g_sum, g_w = 0.0, 0.0
    for leaf, np_ in p_leaf.items():
        q = q_leaf.get(leaf, 0)
        # noise demand crossing this leaf's up/down fabric links, as a
        # fraction of one link's capacity (spread over all spine links)
        g_fab = q * _noise_split(q, q_total) / spines
        infl = 1.0 + fam.sigma * min(1.0, g_fab) if algo == "canary" else 1.0
        _fabric_links(cell, f"leaf{leaf}", wire * infl, spread,
                      float(spines), g_fab, fam.pool)
        g_sum += np_ * min(1.0, g_fab)
        g_w += np_
    return (g_sum / g_w) if g_w else 0.0


# Mean noise share beyond which a link tier behaves as saturated: flowlet
# noise arrives in line-rate bursts, so instantaneous overload (and with it
# unbounded FIFO backlog) sets in well before the time-average hits 1.0.
# The packet engine shows N static trees already flat in N at g ~ 0.93 on
# the oversubscribed folded Clos.
SATURATION_POOL_G = 0.85


def _fabric_links(cell: FlowCell, name: str, fg_bytes: float, spread: float,
                  n_links: float, g: float, pool: float = 1.0) -> None:
    """Emit the up/down fabric-link pair for one leaf (or pod).

    Unsaturated: the foreground concentrates on its ``spread`` designated
    links while noise spreads over all of them — the designated link is the
    bottleneck. Saturated (``g >= SATURATION_POOL_G``): FIFO backlog grows
    on every link of the tier and service equalizes, so concentrating vs
    spreading the foreground loses most of its meaning. How much of it
    survives is scale-dependent (short epochs ride the noise backlog
    transient — fully flat in N; epochs long enough to reach the fair-share
    steady state keep part of the 1/spread benefit), so the saturated
    per-link load blends the two regimes with the fitted ``pool``:
    ``fg * (pool/n_links + (1-pool)/spread)``. ``pool=1`` is fully pooled
    (N static trees flat on the oversubscribed folded Clos at FAST scale);
    smaller values restore part of the designated-link spreading."""
    if g >= SATURATION_POOL_G:
        eff = fg_bytes * (pool / n_links + (1.0 - pool) / spread)
        cell.add_link(f"{name}_up", eff, g)
        cell.add_link(f"{name}_down", eff, g)
    else:
        cell.add_link(f"{name}_up", fg_bytes / spread, g)
        cell.add_link(f"{name}_down", fg_bytes / spread, g)


# ------------------------------------------------------------------- 3tier
def _lower_three_tier(cell: FlowCell, cfg: SimConfig, fam: FamilyParams,
                      algo: str, n_trees: int, wire: float, p: int,
                      p_leaf: Dict[int, int], q_leaf: Dict[int, int],
                      q_total: int) -> float:
    """Model the folded-Clos fabric (leaf/agg/core); returns g_mix.

    The structural difference from the fat tree: leaves are oversubscribed
    (``aggs_per_pod`` up-links for ``hosts_per_leaf`` hosts), so noise can
    exceed leaf uplink capacity — the noise carried into the agg/core tier
    is capped by what the leaf uplinks actually admit (a one-step max-min
    waterfall), and static trees funnel each pod through a single
    designated agg (§3.1: the tree is static), which is the link the packet
    engine shows saturating.
    """
    aggs = max(1, cfg.aggs_per_pod)
    cores = max(1, cfg.num_cores)
    leaves_per_pod = max(1, cfg.num_leaves // max(1, cfg.num_pods))

    def pod_of(leaf: int) -> int:
        return leaf // leaves_per_pod

    p_pod: Dict[int, int] = {}
    for leaf, np_ in p_leaf.items():
        p_pod[pod_of(leaf)] = p_pod.get(pod_of(leaf), 0) + np_
    q_pod: Dict[int, int] = {}
    for leaf, nq in q_leaf.items():
        q_pod[pod_of(leaf)] = q_pod.get(pod_of(leaf), 0) + nq

    if algo == "canary":
        leaf_spread = float(aggs)
        agg_spread = float(aggs * cores)
    else:
        # one designated agg per (tree, pod); one core root per tree
        leaf_spread = expected_distinct(n_trees, aggs)
        agg_spread = expected_distinct(n_trees, aggs * cores)

    # noise admitted into the fabric by each leaf (capacity-capped)
    admitted_up: Dict[int, float] = {}
    g_sum, g_w = 0.0, 0.0
    for leaf in set(list(p_leaf) + list(q_leaf)):
        q = q_leaf.get(leaf, 0)
        demand = q * _noise_split(q, q_total)          # in link-capacities
        admitted_up[leaf] = min(demand, float(aggs))
        g_fab = demand / aggs
        np_ = p_leaf.get(leaf, 0)
        if np_:
            infl = (1.0 + fam.sigma * min(1.0, g_fab)
                    if algo == "canary" else 1.0)
            _fabric_links(cell, f"leaf{leaf}", wire * infl, leaf_spread,
                          float(aggs), g_fab, fam.pool)
            g_sum += np_ * min(1.0, g_fab)
            g_w += np_

    # agg<->core tier, per pod: cross-pod noise share of what the leaves
    # admitted, spread over the pod's aggs*cores uplinks
    for pod in set(pod_of(l) for l in p_leaf):
        qp = q_pod.get(pod, 0)
        cross = (q_total - qp) / max(1, q_total - 1) if q_total > 1 else 0.0
        up_frac_mean = _noise_split(1, q_total) or 1.0
        admitted = sum(a for l, a in admitted_up.items() if pod_of(l) == pod)
        noise_cross = admitted * (cross / up_frac_mean if up_frac_mean else 0)
        g_core = min(noise_cross, admitted) / (aggs * cores)
        pp = p_pod.get(pod, 0)
        # cross-pod share of the foreground: blocks led outside this pod
        share = 1.0 - (pp / max(1, p)) if algo == "canary" else 1.0
        infl = (1.0 + fam.sigma * min(1.0, g_core)
                if algo == "canary" else 1.0)
        _fabric_links(cell, f"pod{pod}_agg", wire * share * infl,
                      agg_spread, float(aggs * cores), g_core, fam.pool)
        if pp:
            g_sum += pp * min(1.0, g_core)
            g_w += pp
    return (g_sum / g_w) if g_w else 0.0


# -------------------------------------------------------------------- ring
def _lower_ring(cell: FlowCell, cfg: SimConfig, fam: FamilyParams,
                item: dict, p: int, q_leaf: Dict[int, int],
                q_total: int) -> None:
    """Host-based ring: 2(p-1) serialized chunk exchanges per host.

    Uncalibrated against the packet engine (ring is not on the fig7
    acceptance grid); structural only — bandwidth-optimal wire time plus a
    per-step latency ladder, congestion entering through the mean fabric
    noise share like every other family.
    """
    chunk = -(-item["data_bytes"] // max(1, p))
    pkts = max(1, -(-chunk // cfg.payload_bytes))
    steps = 2 * (p - 1)
    wire = float(steps * pkts * cfg.mtu_bytes)
    cell.t_send_ns = wire / cell.bytes_per_ns
    cell.add_link("host_up_nic", wire, 0.0)
    fabric = max(1, cfg.num_spines if cfg.topology == "fat_tree"
                 else cfg.aggs_per_pod)
    if q_leaf:
        g = sum(q * _noise_split(q, q_total) / fabric
                for q in q_leaf.values()) / len(q_leaf)
    else:
        g = 0.0
    cell.g_mix = g
    cell.mu = fam.mu
    # neighbours are random hosts: ~every step crosses the fabric
    hops = 3 if cfg.topology == "fat_tree" else 4
    cell.tail_ns = steps * cfg.hop_latency_ns * hops
    cell.add_link("ring_fabric", wire, g)


def solve_cell(cell: FlowCell) -> Tuple[float, float]:
    """Pure-Python reference solver (mirrors ``batch.py``'s jitted math
    exactly; used by tests and anywhere jax is unavailable). Returns
    ``(runtime_ns, goodput_gbps)``."""
    t_bw = 0.0
    for load, g in zip(cell.link_load_bytes, cell.link_noise_frac):
        avail = min(1.0, max(1.0 - cell.kappa * g, cell.floor))
        t_bw = max(t_bw, load / (cell.bytes_per_ns * avail))
    t_mix = cell.t_send_ns * (1.0 + cell.mu * cell.g_mix)
    t = max(t_bw, t_mix) + cell.tail_ns * (1.0 + cell.nu * cell.g_mix)
    return t, cell.data_bits / t if t > 0 else 0.0
