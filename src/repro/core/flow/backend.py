"""The ``"flow"`` entry of the simulation-backend registry.

``FlowBackend.run_cells`` is the drop-in counterpart of the packet
backend's: same work-item dicts in, same cell-dict schema out
(label/rep/goodput_gbps/runtime_us/correct/wall_s) — with flow-specific
diagnostics instead of event counts: which bound held (``bw`` vs ``mix``),
the mixed noise share the cell saw, and the batch-level jit accounting
(``jit_calls``/``jit_traces``) that the sweep JSON records as evidence the
matrix ran as one XLA dispatch.

``correct`` is reported as True by construction: the flow model does not
move payload bits, so there is no end-to-end sum to check — correctness of
the *predictions* is what ``validate.py`` enforces against the packet
engine instead.
"""
from __future__ import annotations

import time
from typing import List

from . import batch
from .model import lower_item, solve_cell


class FlowBackend:
    name = "flow"

    def __init__(self) -> None:
        self.jit_calls = 0

    def run_cells(self, items: List[dict]) -> List[dict]:
        t0 = time.perf_counter()
        cells = [lower_item(it) for it in items]
        lower_s = time.perf_counter() - t0
        traces0 = batch.trace_count()
        t1 = time.perf_counter()
        runtimes_ns, goodputs = batch.run_batch(cells)
        solve_s = time.perf_counter() - t1
        self.jit_calls += 1
        traces = batch.trace_count() - traces0
        per_cell_wall = (lower_s + solve_s) / max(1, len(items))
        out = []
        for item, cell, t_ns, gp in zip(items, cells, runtimes_ns, goodputs):
            t_py, _ = solve_cell(cell)
            bound = "bw" if t_py > 0 and _bw_bound(cell) >= \
                cell.t_send_ns * (1.0 + cell.mu * cell.g_mix) else "mix"
            out.append(dict(label=item["label"], rep=item["rep"],
                            goodput_gbps=gp,
                            runtime_us=t_ns / 1e3,
                            correct=True,
                            backend="flow", bound=bound,
                            g_mix=round(cell.g_mix, 4),
                            t_send_us=cell.t_send_ns / 1e3,
                            jit_traces=traces,
                            wall_s=per_cell_wall))
        return out


def _bw_bound(cell) -> float:
    t = 0.0
    for load, g in zip(cell.link_load_bytes, cell.link_noise_frac):
        avail = min(1.0, max(1.0 - cell.kappa * g, cell.floor))
        t = max(t, load / (cell.bytes_per_ns * avail))
    return t
