"""Flow-vs-packet validation harness: the divergence contract, enforced.

The flow backend is useful exactly to the extent its predictions track the
packet engine, so the tolerance is not a comment — it is executable. This
module pins a validation grid (the fig7 suite: CANARY vs 1/2/4/8 static
trees, with and without congestion, on both fabrics), runs every cell
through BOTH backends interleaved (flow lowering next to the packet run it
is checked against, so a drift in either surfaces at the same commit), and
fails if any per-label mean runtime or goodput diverges beyond the
documented tolerance.

Tolerances (documented in ARCHITECTURE.md §Backends):

* ``MID_TOLERANCE = 0.15`` — the acceptance contract, at the default bench
  scale (64 hosts, 1 MiB): per-label rep-mean runtime and goodput within
  ±15% of the packet engine on every fig7 cell of both topologies.
* ``FAST_TOLERANCE = 0.60`` — the CI smoke bound, at BENCH_FAST scale
  (16/32 hosts, 128 KiB): congested cells at scale-4 are dominated by
  placement luck (two reps of the *packet engine itself* differ by up to
  ~70% there), so the smoke grid only guards against gross model breakage;
  the ±15% claim is made — and checked — at mid scale.

A label whose *packet* reps spread further apart than the tolerance itself
(``max/min - 1 > tolerance``) is reported but exempt from the gate: when the
reference disagrees with itself by more than the allowed error, its 2-rep
mean is noise, not a standard (at FAST scale, fat-tree static4/cong=1 is
exactly this cell — packet reps 30.9us vs 53.6us). The exemption is
tolerance-scaled, so tightening the bound never silently widens it, and
every exempt label carries ``reference_unstable: true`` in the report.

Usage::

    PYTHONPATH=src python -m repro.core.flow.validate            # mid scale
    BENCH_FAST=1 PYTHONPATH=src python -m repro.core.flow.validate
    # reuse a recorded packet sweep for the expensive side:
    ... validate --packet-ref three_tier=sweep_fig7_three_tier.json

The run writes ``flow_validation.json`` (``--out`` to move it) with every
per-cell pair, so the divergence trajectory is a recorded artifact.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Dict, List

MID_TOLERANCE = 0.15
FAST_TOLERANCE = 0.60
REPS = 2          # pinned: the grid compares per-label means over 2 reps

# The three-tier mid grid is pinned at 512 KiB, not the fat-tree's 1 MiB:
# at 1 MiB the congested three-tier cells blow the packet engine's OWN
# livelock valve (SimConfig.max_events = 200M — the event count goes over a
# cliff between 512 KiB and 1 MiB as timeout-flush cascades compound), so
# 512 KiB is the largest size at which a packet reference for this fabric
# exists at all. A reference the reference engine cannot produce cannot
# anchor a tolerance.
THREE_TIER_MID_BYTES = 512 * 1024


def validation_items(topology: str, fast: bool) -> List[dict]:
    """The pinned grid: fig7 on one fabric at the bench scale implied by
    the BENCH_* env (``benchmarks.sweep.expand_suite`` reads it), except
    the three-tier mid grid's message size (see THREE_TIER_MID_BYTES)."""
    from benchmarks.sweep import expand_suite
    items = expand_suite("fig7", topology, REPS)
    if topology == "three_tier" and not fast:
        for it in items:
            it["data_bytes"] = THREE_TIER_MID_BYTES
    return items


def _label_means(cells: List[dict]) -> Dict[str, Dict[str, float]]:
    by: Dict[str, List[dict]] = {}
    for c in cells:
        by.setdefault(c["label"], []).append(c)
    return {label: dict(
        runtime_us=statistics.mean(c["runtime_us"] for c in cs),
        goodput_gbps=statistics.mean(c["goodput_gbps"] for c in cs))
        for label, cs in by.items()}


def run_validation(topologies=("fat_tree", "three_tier"),
                   tolerance: float = None, fast: bool = None,
                   packet_refs: Dict[str, dict] = None) -> dict:
    """Run the pinned grid through both backends; returns the report dict
    (``ok``, per-cell pairs, per-label divergences). Raises nothing —
    callers check ``report["ok"]``.

    ``packet_refs`` maps a topology to a *recorded* packet-backend sweep
    document (``benchmarks/sweep.py`` JSON) to use in place of live packet
    runs — the way to validate against an expensive reference (the 3-tier
    mid grid costs packet-engine hours) without re-simulating it. The doc
    must be a packet run of the same suite/topology/reps; every grid cell
    must be present in it."""
    import os

    from repro.core.canary import get_backend
    if fast is None:
        fast = bool(int(os.environ.get("BENCH_FAST", "0")))
    if tolerance is None:
        tolerance = FAST_TOLERANCE if fast else MID_TOLERANCE
    packet_refs = packet_refs or {}
    packet = get_backend("packet")
    flow = get_backend("flow")
    grids = []
    worst = 0.0
    ok = True
    for topo in topologies:
        items = validation_items(topo, fast)
        ref = packet_refs.get(topo)
        if ref is not None:
            if ref.get("backend", "packet") != "packet" or \
                    ref.get("topology") != topo or \
                    ref.get("suite") != "fig7" or ref.get("reps") != REPS:
                raise ValueError(
                    f"packet ref for {topo!r} is not a packet fig7/"
                    f"reps={REPS} sweep of that topology")
            recorded = {(c["label"], c["rep"]): c for c in ref["results"]}
        # interleaved: each packet cell immediately followed by its flow
        # counterpart, so both see the identical work item
        pairs = []
        flow_cells = flow.run_cells(items)      # one batched call
        for item, fc in zip(items, flow_cells):
            if ref is not None:
                pc = recorded[(item["label"], item["rep"])]
            else:
                pc = packet.run_cell(item)
            pairs.append(dict(label=item["label"], rep=item["rep"],
                              packet_runtime_us=pc["runtime_us"],
                              flow_runtime_us=fc["runtime_us"],
                              packet_goodput=pc["goodput_gbps"],
                              flow_goodput=fc["goodput_gbps"]))
        p_means = _label_means([dict(label=p["label"],
                                     runtime_us=p["packet_runtime_us"],
                                     goodput_gbps=p["packet_goodput"])
                                for p in pairs])
        f_means = _label_means([dict(label=p["label"],
                                     runtime_us=p["flow_runtime_us"],
                                     goodput_gbps=p["flow_goodput"])
                                for p in pairs])
        p_reps: Dict[str, List[float]] = {}
        for p in pairs:
            p_reps.setdefault(p["label"], []).append(p["packet_runtime_us"])
        labels = {}
        for label in p_means:
            rt_err = (f_means[label]["runtime_us"]
                      - p_means[label]["runtime_us"]) \
                / p_means[label]["runtime_us"]
            gp_err = (f_means[label]["goodput_gbps"]
                      - p_means[label]["goodput_gbps"]) \
                / p_means[label]["goodput_gbps"]
            err = max(abs(rt_err), abs(gp_err))
            spread = max(p_reps[label]) / min(p_reps[label]) - 1.0
            unstable = spread > tolerance
            within = err <= tolerance or unstable
            if not unstable:
                worst = max(worst, err)
            ok &= within
            labels[label] = dict(
                packet_runtime_us=p_means[label]["runtime_us"],
                flow_runtime_us=f_means[label]["runtime_us"],
                runtime_err=rt_err, goodput_err=gp_err,
                packet_rep_spread=spread, reference_unstable=unstable,
                within=within)
        grids.append(dict(topology=topo, labels=labels, pairs=pairs))
    return dict(ok=ok, tolerance=tolerance, fast=fast, worst_err=worst,
                grids=grids)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--topology", action="append", default=None,
                    help="repeatable; default: fat_tree + three_tier")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override the scale-implied tolerance")
    ap.add_argument("--packet-ref", action="append", default=[],
                    metavar="TOPOLOGY=SWEEP.json",
                    help="use a recorded packet sweep document for this "
                         "topology instead of running the packet engine")
    ap.add_argument("--out", default="flow_validation.json")
    args = ap.parse_args(argv)
    topos = tuple(args.topology) if args.topology else \
        ("fat_tree", "three_tier")
    refs = {}
    for spec in args.packet_ref:
        topo, _, path = spec.partition("=")
        with open(path) as fh:
            refs[topo] = json.load(fh)
    report = run_validation(topologies=topos, tolerance=args.tolerance,
                            packet_refs=refs)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=1, sort_keys=True)
        fh.write("\n")
    for grid in report["grids"]:
        print(f"== {grid['topology']}")
        for label, row in sorted(grid["labels"].items()):
            mark = "ok  " if row["within"] else "FAIL"
            if row["reference_unstable"]:
                mark = "ref?"
            print(f"  [{mark}] {label:20s} packet={row['packet_runtime_us']:9.1f}us "
                  f"flow={row['flow_runtime_us']:9.1f}us "
                  f"rt_err={row['runtime_err'] * 100:+6.1f}% "
                  f"gp_err={row['goodput_err'] * 100:+6.1f}%"
                  + (f"  (packet reps spread "
                     f"{row['packet_rep_spread'] * 100:.0f}% — exempt)"
                     if row["reference_unstable"] else ""))
    print(f"# worst divergence {report['worst_err'] * 100:.1f}% vs tolerance "
          f"{report['tolerance'] * 100:.0f}% "
          f"({'FAST' if report['fast'] else 'mid'} scale) -> {args.out}")
    if not report["ok"]:
        print("# VALIDATION FAILED: flow model diverges beyond the "
              "documented tolerance", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
