"""Flow-level fast path: predict allreduce runtime without dispatching packets.

The packet engine (``repro.core.canary``) is the exact reference: every
packet is a discrete event, so a paper-scale (1024-host, 4 MiB) cell costs
tens of millions of Python events. This package trades exactness for
orders-of-magnitude speed: each experiment cell is *lowered* to a small
bandwidth-sharing problem over the aggregation tree's link classes
(``model.py``), the whole sweep matrix is stacked into padded arrays, and
one ``jit``-ted, ``vmap``-ed JAX computation solves every cell x rep at
once (``batch.py``). Calibration constants pinning the model to the packet
engine live in ``calibrate.py``; the divergence contract is enforced by
``validate.py`` (see ARCHITECTURE.md §Backends for the equations and the
documented tolerance).

Import contract: ``import repro.core.flow`` must NOT import jax — the
lowering and calibration are pure Python, and only :class:`FlowBackend` /
``run_batch`` pull jax on first use (PEP 562, same pattern as
``repro.models``). This keeps ``repro.core.canary``'s backend registry —
which maps ``"flow"`` to this package — jax-free until someone actually
selects the flow backend.
"""
from .calibrate import CALIBRATION, FamilyParams, params_for
from .model import FlowCell, lower_item

_LAZY_BACKEND = ("FlowBackend", "run_batch", "trace_count")

__all__ = ["CALIBRATION", "FamilyParams", "FlowBackend", "FlowCell",
           "lower_item", "params_for", "run_batch", "trace_count"]


def __getattr__(name: str):
    if name in ("run_batch", "trace_count"):
        from . import batch
        return getattr(batch, name)
    if name == "FlowBackend":
        from .backend import FlowBackend
        return FlowBackend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
