"""Calibration constants pinning the flow model to the packet engine.

The flow model (``model.py``) has free constants that packet-level effects
determine but a fluid model cannot derive from first principles — how much
worse than the time-average a FIFO link treats a foreground flow when
flowlet-routed noise arrives in bursts (``kappa``), how strongly congestion
stretches the host->leader pipe (``mu``) and the latency tail (``nu``), and
how many extra timeout-flush partials a congested CANARY epoch emits
(``sigma``, the §3.2 per-round tree-reshaping term). They are fitted, per
(topology family, algorithm family), against pinned packet-engine reference
sweeps — the fig7 grid at FAST (scale-4 / 128 KiB) and default bench
(scale-8 / 1 MiB) scale on both fabrics — by ``scripts/fit_flow_model.py``,
and the result is pinned here. Refitting is a deliberate act (run the
script, review the per-cell residuals it prints, commit the new table);
nothing refits at import or run time.

``validate.py`` is the enforcement side: it replays flow vs packet on the
pinned grid and fails beyond the documented tolerance, so a drift in either
the engine or the model surfaces as a test failure, not silent skew.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FamilyParams:
    """Fitted constants for one (topology, algorithm-family) pair.

    * ``kappa``  — noise amplification on shared links: a link with raw
      time-average noise demand fraction ``g`` serves foreground traffic at
      ``C * max(1 - kappa*g, floor)``. ``kappa > 1`` captures burstiness
      (flowlet noise overshoots its mean on the link it currently rides),
      ``kappa < 1`` captures congestion-aware load balancing steering the
      foreground around hot links.
    * ``floor``  — minimum service share on a saturated link (FIFO never
      starves a flow completely; packets already queued do drain).
    * ``mu``     — pipe-stretch: congestion multiplies the serialization
      time ``T_send`` by ``(1 + mu * g_mix)``.
    * ``mu_ntree`` — extra pipe-stretch ``mu_ntree / E[distinct roots]``
      for static trees: fewer trees concentrate load on fewer designated
      links, which the mixing term feels before the hard bandwidth bound.
    * ``nu``     — tail-stretch: the latency tail (timeouts, leader
      aggregation, hops) crosses the same congested links, so it stretches
      by ``(1 + nu * g_mix)``.
    * ``sigma``  — CANARY timeout-flush inflation: congested epochs emit
      ``(1 + sigma * g_mix)`` partial aggregates per block instead of 1
      (stragglers split the aggregation tree per round).
    * ``pool``   — saturated-tier pooling blend in [0, 1]: 1 means a
      saturated tier fully equalizes (spreading the foreground over more
      trees buys nothing — the FAST-scale behaviour), smaller values keep
      part of the designated-link 1/spread benefit (longer epochs reach
      the fair-share steady state). See ``model._fabric_links``.
    """

    kappa: float = 1.0
    floor: float = 0.08
    mu: float = 2.0
    mu_ntree: float = 0.0
    nu: float = 1.0
    sigma: float = 0.0
    pool: float = 1.0


# Pinned by scripts/fit_flow_model.py against the packet-engine reference
# grids (see module docstring). Keyed by (topology, algo family); "ring" is
# carried with structural defaults only — it is not part of the fig7
# acceptance grid and is documented as uncalibrated in ARCHITECTURE.md.
CALIBRATION = {
    ("fat_tree", "canary"): FamilyParams(
        kappa=0.6, floor=0.04, mu=1.8, mu_ntree=0.0, nu=1.0, sigma=0.0,
        pool=1.0),
    ("fat_tree", "static_tree"): FamilyParams(
        kappa=0.9, floor=0.04, mu=2.4, mu_ntree=0.8, nu=1.0, sigma=0.0,
        pool=1.0),
    ("fat_tree", "ring"): FamilyParams(),
    ("three_tier", "canary"): FamilyParams(
        kappa=0.6, floor=0.05, mu=1.0, mu_ntree=0.0, nu=2.0, sigma=0.5,
        pool=1.0),
    ("three_tier", "static_tree"): FamilyParams(
        kappa=0.9, floor=0.08, mu=1.4, mu_ntree=0.0, nu=1.0, sigma=0.0,
        pool=0.85),
    ("three_tier", "ring"): FamilyParams(),
}


def params_for(topology: str, algo: str) -> FamilyParams:
    """Look up fitted constants; unknown fabrics fall back to the fat-tree
    row of the same family (documented: plug-in topologies start
    uncalibrated)."""
    key = (topology, algo)
    if key in CALIBRATION:
        return CALIBRATION[key]
    fallback = ("fat_tree", algo)
    if fallback in CALIBRATION:
        return CALIBRATION[fallback]
    return FamilyParams()
