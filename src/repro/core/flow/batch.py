"""Batched JAX evaluation of lowered flow cells.

The whole sweep matrix — every (algorithm, congestion, rep) cell — becomes
ONE ``jit``-ted, ``vmap``-ed call over padded arrays: link loads and noise
shares are stacked to ``[cells, max_links]`` (padding with zero load, which
can never win the max), scalars to ``[cells]``. At paper scale that is a
~[40, 130] float32 problem — the cost of the flow backend is the Python
lowering, not the solve, and the solve count is what the compile-count
contract pins: ``trace_count()`` increments only while JAX is *tracing* the
cell function, so a whole matrix must cost exactly one trace
(``tests/flow/test_flow_backend.py``).

This module is the only part of the flow package that imports jax, and it
is imported lazily (``repro.core.flow.__getattr__``).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .model import FlowCell

_TRACE_COUNT = 0


def trace_count() -> int:
    """How many times the cell solver has been traced (== compiled) in this
    process. The batching contract: one call per sweep matrix, however many
    cells x reps it holds."""
    return _TRACE_COUNT


def _solve_one(load, g, kappa, floor, mu, nu, t_send, tail, g_mix,
               bytes_per_ns, data_bits):
    """Solve one cell; vmapped over the leading axis of every argument.

    Mirrors ``model.solve_cell`` exactly — keep the two in lockstep (pinned
    by the parity test in tests/flow/).
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1            # Python side effect: runs per TRACE only
    avail = jnp.clip(1.0 - kappa * g, floor, 1.0)
    t_bw = jnp.max(load / (bytes_per_ns * avail))
    t_mix = t_send * (1.0 + mu * g_mix)
    t = jnp.maximum(t_bw, t_mix) + tail * (1.0 + nu * g_mix)
    return t, data_bits / t


_solve_batch = jax.jit(jax.vmap(_solve_one))


def pack(cells: List[FlowCell]):
    """Stack lowered cells into padded arrays (pad links with load=0,
    avail=1: a zero-load link can never be the bottleneck)."""
    m = max(len(c.link_load_bytes) for c in cells)
    load = jnp.asarray([c.link_load_bytes + [0.0] * (m - len(c.link_load_bytes))
                        for c in cells], dtype=jnp.float32)
    g = jnp.asarray([c.link_noise_frac + [0.0] * (m - len(c.link_noise_frac))
                     for c in cells], dtype=jnp.float32)
    scal = {name: jnp.asarray([getattr(c, name) for c in cells],
                              dtype=jnp.float32)
            for name in ("kappa", "floor", "mu", "nu", "t_send_ns",
                         "tail_ns", "g_mix", "bytes_per_ns", "data_bits")}
    return load, g, scal


def run_batch(cells: List[FlowCell]) -> Tuple[List[float], List[float]]:
    """Solve every cell in one jitted call. Returns (runtime_ns[], goodput
    _gbps[]) as plain Python floats, cell order preserved."""
    if not cells:
        return [], []
    load, g, s = pack(cells)
    t, gp = _solve_batch(load, g, s["kappa"], s["floor"], s["mu"], s["nu"],
                         s["t_send_ns"], s["tail_ns"], s["g_mix"],
                         s["bytes_per_ns"], s["data_bits"])
    return [float(x) for x in t], [float(x) for x in gp]
