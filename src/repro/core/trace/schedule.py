"""Schedule compiler: lower a recorded dynamic tree to a round-based program.

A :class:`~repro.core.trace.recorder.BlockTree` is an *event history*; this
module lowers it into a deterministic, data-parallel communication schedule
over a logical device mesh:

* **reduce rounds** — round ``r`` holds one :class:`ReduceStep` per tree node
  whose height is ``r``: the node accumulates all of its children's buffers.
  Steps within a round touch disjoint destinations and only read buffers
  produced in earlier rounds, so a round is a single segment-sum — exactly
  the shape :func:`repro.kernels.packet_accum.packet_accumulate` executes on
  the MXU.
* **broadcast rounds** — the mirror image (root to leaves), matching §3.1.2:
  the broadcast rides the recorded tree back down.

The compiler is pure Python (no jax): schedules are inspectable/serializable
artifacts; :mod:`~repro.core.trace.executor` turns them into tensor programs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from .recorder import HOST_SEND, BlockTree, TraceRecorder


@dataclass(frozen=True)
class ReduceStep:
    """``dst`` accumulates the sum of every buffer in ``srcs``."""

    dst: int            # node id
    srcs: tuple         # child node ids, merge order


@dataclass(frozen=True)
class CopyStep:
    """``src``'s buffer is replicated into every node in ``dsts``."""

    src: int
    dsts: tuple


@dataclass
class Schedule:
    """Round-based replay program for one block's recorded tree."""

    app: int
    block: int
    gen: int
    root: int                                  # root node id
    hosts: List[int]                           # participants, sorted
    leaf_host: Dict[int, int]                  # leaf node id -> host id
    reduce_rounds: List[List[ReduceStep]] = field(default_factory=list)
    bcast_rounds: List[List[CopyStep]] = field(default_factory=list)
    # provenance stats carried over from the recorded tree
    timeout_flushes: int = 0
    complete_flushes: int = 0

    # ---- derived metrics ---------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self.reduce_rounds)

    @property
    def num_reduce_steps(self) -> int:
        return sum(len(r) for r in self.reduce_rounds)

    @property
    def max_fanin(self) -> int:
        return max((len(s.srcs) for r in self.reduce_rounds for s in r),
                   default=0)

    def message_count(self) -> int:
        """Logical point-to-point transfers (reduce edges + broadcast edges)."""
        up = sum(len(s.srcs) for r in self.reduce_rounds for s in r)
        down = sum(len(s.dsts) for r in self.bcast_rounds for s in r)
        return up + down

    def bytes_moved(self, block_bytes: int) -> int:
        return self.message_count() * block_bytes

    def summary(self) -> str:
        return (f"app={self.app} block={self.block} depth={self.depth} "
                f"steps={self.num_reduce_steps} max_fanin={self.max_fanin} "
                f"messages={self.message_count()}")


def compile_block(tree: BlockTree) -> Schedule:
    """Lower one recorded :class:`BlockTree` into a :class:`Schedule`."""
    # height of each node above its deepest leaf (leaves are 0)
    height: Dict[int, int] = {}

    def _height(nid: int) -> int:
        h = height.get(nid)
        if h is not None:
            return h
        node = tree.nodes[nid]
        h = 0 if not node.children else 1 + max(_height(c)
                                                for c in node.children)
        height[nid] = h
        return h

    max_h = _height(tree.root)
    reduce_rounds: List[List[ReduceStep]] = [[] for _ in range(max_h)]
    for nid, node in sorted(tree.nodes.items()):
        if node.children:
            reduce_rounds[height[nid] - 1].append(
                ReduceStep(dst=nid, srcs=tuple(node.children)))

    # broadcast mirrors the reduce tree root-to-leaves by node depth
    depth: Dict[int, int] = {tree.root: 0}
    order = [tree.root]
    for nid in order:
        for c in tree.nodes[nid].children:
            depth[c] = depth[nid] + 1
            order.append(c)
    max_d = max(depth.values(), default=0)
    bcast_rounds: List[List[CopyStep]] = [[] for _ in range(max_d)]
    for nid, node in sorted(tree.nodes.items()):
        if node.children:
            bcast_rounds[depth[nid]].append(
                CopyStep(src=nid, dsts=tuple(node.children)))

    leaf_host = {nid: n.where for nid, n in tree.nodes.items()
                 if n.kind == HOST_SEND}
    return Schedule(app=tree.app, block=tree.block, gen=tree.gen,
                    root=tree.root, hosts=list(tree.participants),
                    leaf_host=leaf_host,
                    reduce_rounds=reduce_rounds, bcast_rounds=bcast_rounds,
                    timeout_flushes=tree.timeout_flushes(),
                    complete_flushes=tree.complete_flushes())


def compile_app(recorder: TraceRecorder, app: int) -> List[Schedule]:
    """Compile every completed block of ``app``, ordered by block index."""
    return [compile_block(t) for t in recorder.trees(app)]


def schedule_report(schedules: List[Schedule], block_bytes: int) -> dict:
    """Aggregate schedule-shape metrics for a set of compiled blocks."""
    depths = [s.depth for s in schedules]
    return {
        "blocks": len(schedules),
        "depth_max": max(depths, default=0),
        "depth_mean": (sum(depths) / len(depths)) if depths else 0.0,
        "reduce_steps": sum(s.num_reduce_steps for s in schedules),
        "messages": sum(s.message_count() for s in schedules),
        "bytes_moved": sum(s.bytes_moved(block_bytes) for s in schedules),
        "timeout_flushes": sum(s.timeout_flushes for s in schedules),
        "complete_flushes": sum(s.complete_flushes for s in schedules),
        "max_fanin": max((s.max_fanin for s in schedules), default=0),
    }
