"""JAX executor: replay a compiled schedule as a real tensor program.

Each reduce round of a :class:`~repro.core.trace.schedule.Schedule` is one
segment-sum — every step's source buffers are stacked into a packet matrix
and scatter-accumulated into per-destination slots by
:func:`repro.kernels.packet_accum.packet_accumulate` (the MXU one-hot-matmul
kernel the software-switch benchmarks use), exactly the per-switch
aggregation of §3.1.1. The broadcast phase replicates the root buffer down
the mirrored tree (§3.1.2).

Two numeric modes:

* **float32** — matches a plain ``sum(inputs)`` up to re-association error
  (the tree decides the association order, so different recorded trees give
  slightly different floats — the non-determinism the paper inherits from
  floating point).
* **int32 fixed point** — inputs are quantized via
  :mod:`repro.kernels.fixedpoint` and accumulated as int32. Integer addition
  is associative, so the result is **bit-identical for every tree shape the
  timeouts produced** — the beyond-paper determinism claim, demonstrated on
  trees the simulator actually formed under congestion.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.kernels.fixedpoint import dequantize, quantize
from repro.kernels.ops import fixed_point_scale
from repro.kernels.packet_accum import accumulate_dtype, packet_accumulate

from .schedule import Schedule


def replay_block(schedule: Schedule, inputs: jnp.ndarray, *,
                 interpret: bool = True) -> jnp.ndarray:
    """Replay one block's schedule over per-host input rows.

    ``inputs``: ``(P, D)`` — row ``r`` is the contribution of
    ``schedule.hosts[r]``. Returns ``(P, D)``: every host's post-broadcast
    buffer (all rows identical — the reduced block). int32 inputs are
    accumulated in int32 (associative), floats in float32.
    """
    hosts = schedule.hosts
    if inputs.shape[0] != len(hosts):
        raise ValueError(f"inputs has {inputs.shape[0]} rows for "
                         f"{len(hosts)} participants")
    rank = {h: r for r, h in enumerate(hosts)}
    inputs = inputs.astype(accumulate_dtype(inputs.dtype))

    buffers = {}
    for nid, host in schedule.leaf_host.items():
        buffers[nid] = inputs[rank[host]]

    for rnd in schedule.reduce_rounds:
        slot_ids = []
        payloads = []
        for slot, step in enumerate(rnd):
            for src in step.srcs:
                slot_ids.append(slot)
                payloads.append(buffers[src])
        acc = packet_accumulate(jnp.asarray(slot_ids, jnp.int32),
                                jnp.stack(payloads), len(rnd),
                                interpret=interpret)
        for slot, step in enumerate(rnd):
            buffers[step.dst] = acc[slot]

    # broadcast: every step of the mirrored tree is a copy of the root
    # buffer, so the per-host rows materialize directly
    total = buffers[schedule.root]
    return jnp.broadcast_to(total, (len(hosts),) + total.shape)


def replay_app(schedules: Sequence[Schedule], inputs: jnp.ndarray, *,
               interpret: bool = True) -> jnp.ndarray:
    """Replay a whole app: ``inputs`` is ``(P, B, D)`` (one row of blocks per
    participant, in ``schedules[b].hosts`` order); returns ``(P, B, D)``."""
    if inputs.shape[1] != len(schedules):
        raise ValueError(f"inputs has {inputs.shape[1]} blocks for "
                         f"{len(schedules)} schedules")
    outs = [replay_block(s, inputs[:, b], interpret=interpret)
            for b, s in enumerate(schedules)]
    return jnp.stack(outs, axis=1)


def fixed_point_replay(schedules: Sequence[Schedule], x: jnp.ndarray, *,
                       bits: int = 24, interpret: bool = True):
    """Fixed-point replay: quantize -> int32 tree accumulation -> dequantize.

    ``x``: ``(P, B, D)`` float inputs. Returns ``(result, q_result)`` where
    ``q_result`` is the raw ``(P, B, D)`` int32 accumulation — bit-identical
    across any set of recorded tree shapes for the same ``x`` — and
    ``result`` is its dequantized float32 view. The scale is the shared
    :func:`repro.kernels.ops.fixed_point_scale` (same convention as
    ``fixed_point_allreduce_wrap``): a global max with headroom for ``P``
    summands so int32 never overflows.
    """
    gmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = fixed_point_scale(gmax, bits=bits, world=x.shape[0])
    q = quantize(x, scale, interpret=interpret)
    q_result = replay_app(schedules, q, interpret=interpret)
    return dequantize(q_result, scale, interpret=interpret), q_result


def reference_allreduce(x: jnp.ndarray) -> jnp.ndarray:
    """The float oracle: every participant receives ``sum_r x[r]``."""
    total = jnp.sum(x.astype(jnp.float32), axis=0)
    return jnp.broadcast_to(total, x.shape)
