"""TraceRecorder: aggregation-provenance capture for simulator runs.

Canary's trees are *emergent* — switches allocate descriptors best-effort and
flush them on timeouts (§3.1.1), so no component of the system ever holds the
tree a block rode. The recorder reconstructs it by observing the dataplane:

* every host REDUCE send becomes a **leaf** :class:`TraceNode`;
* every switch descriptor becomes an **internal** node; merging a packet into
  a descriptor records a child edge (and the in-port, matching the children
  bitmap of §4.2);
* flushing a descriptor (timeout vs. complete) transfers the node onto the
  outgoing partial-aggregate packet;
* the leader's per-generation accumulation is the **root** node (for
  STATIC_TREE the root switch plays this role).

Packets and descriptors carry an inert ``trace_node`` tag (see
``canary/types.py``) that threads identity through the event loop; the
recorder allocates the tags and owns all derived state.

**Observation-only contract**: hooks never draw from the simulator RNG, never
push events and never mutate protocol state, so a traced run produces a
bit-identical :class:`~repro.core.canary.types.SimResult` to an untraced one
(pinned by the traced golden-replay test).

This module is jax-free — only :mod:`~repro.core.trace.executor` needs jax.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from ..canary.types import block_key, id_app, id_block, id_gen, make_id

# Node kinds
HOST_SEND = "host_send"    # a host's REDUCE send (tree leaf)
SWITCH_DESC = "switch_desc"  # a switch descriptor (internal aggregation node)
LEADER = "leader"          # leader-host accumulation (CANARY root)
STATIC_ROOT = "static_root"  # root-switch accumulation (STATIC_TREE root)

# Flush reasons for SWITCH_DESC nodes
FLUSH_COMPLETE = "complete"  # counter reached hosts-1 (§3.1.4)
FLUSH_TIMEOUT = "timeout"    # aggregation window expired (§3.1.1)


@dataclass
class TraceNode:
    """One aggregation point in a block's dynamic tree."""

    node_id: int
    kind: str                  # HOST_SEND | SWITCH_DESC | LEADER | STATIC_ROOT
    where: int                 # host id (leaves/leader) or global switch id
    pid: int                   # full block id incl. generation
    t_start: float
    children: List[int] = field(default_factory=list)   # node ids, merge order
    in_ports: List[int] = field(default_factory=list)   # per child edge (-1 at hosts)
    contribs: Counter = field(default_factory=Counter)  # host -> times aggregated
    flush_reason: Optional[str] = None                  # SWITCH_DESC only
    t_flush: float = -1.0

    @property
    def app(self) -> int:
        return id_app(self.pid)

    @property
    def block(self) -> int:
        return id_block(self.pid)

    @property
    def gen(self) -> int:
        return id_gen(self.pid)


@dataclass
class BlockTree:
    """The completed reduction tree of one ``(app, block)``.

    ``nodes`` maps node id -> :class:`TraceNode` for every node that
    contributed to the completed generation (stale-generation and dropped
    partials are excluded — they were rejected, so they are not part of the
    aggregation that produced the final value).
    """

    app: int
    block: int
    gen: int
    root: int                         # root node id
    nodes: Dict[int, TraceNode]
    participants: List[int]

    # ---- structure ---------------------------------------------------------
    def leaves(self) -> List[TraceNode]:
        return [n for n in self.nodes.values() if n.kind == HOST_SEND]

    def switch_nodes(self) -> List[TraceNode]:
        return [n for n in self.nodes.values() if n.kind == SWITCH_DESC]

    def depth(self) -> int:
        """Longest leaf-to-root path, in aggregation hops."""
        return self._level(self.root)

    def _level(self, nid: int) -> int:
        node = self.nodes[nid]
        if not node.children:
            return 0
        return 1 + max(self._level(c) for c in node.children)

    def timeout_flushes(self) -> int:
        return sum(1 for n in self.switch_nodes()
                   if n.flush_reason == FLUSH_TIMEOUT)

    def complete_flushes(self) -> int:
        return sum(1 for n in self.switch_nodes()
                   if n.flush_reason == FLUSH_COMPLETE)

    def max_fanin(self) -> int:
        return max((len(n.children) for n in self.nodes.values()), default=0)

    # ---- invariants --------------------------------------------------------
    def contributions(self) -> Counter:
        """host -> number of times its contribution reached the root."""
        return self.nodes[self.root].contribs

    def check_conservation(self) -> None:
        """Every participant aggregated exactly once — no loss, no
        double-count (the invariant that distinguishes Canary's best-effort
        trees from bounded-aggregation schemes)."""
        want = Counter({h: 1 for h in self.participants})
        got = self.contributions()
        if got != want:
            missing = sorted(h for h in want if got.get(h, 0) == 0)
            dupes = sorted(h for h, c in got.items() if c > 1)
            extra = sorted(h for h in got if h not in want)
            raise AssertionError(
                f"conservation violated for app={self.app} block={self.block} "
                f"gen={self.gen}: missing={missing} double={dupes} "
                f"foreign={extra}")

    def summary(self) -> str:
        return (f"app={self.app} block={self.block} gen={self.gen} "
                f"depth={self.depth()} switches={len(self.switch_nodes())} "
                f"timeout_flush={self.timeout_flushes()} "
                f"complete_flush={self.complete_flushes()} "
                f"max_fanin={self.max_fanin()}")


class TraceRecorder:
    """Collects :class:`TraceNode` provenance during one simulator run.

    Constructed by the :class:`~repro.core.canary.simulator.Simulator` facade
    when ``SimConfig.trace`` is set; the protocol layers call the ``on_*``
    hooks (guarded by ``sim.trace is not None``, so untraced runs pay one
    attribute load per hook site).

    Covers the in-network strategies (CANARY, STATIC_TREE) for every
    collective flavour. Host-based strategies (RING) bypass the hooked paths
    entirely and record nothing.
    """

    def __init__(self, sim):
        self.sim = sim
        self.nodes: List[TraceNode] = []
        # (app, block, gen) -> leader/static-root node id
        self._roots: Dict[Tuple[int, int, int], int] = {}
        # (app, block) -> (root node id, generation) of the completed reduction
        self.completed: Dict[Tuple[int, int], Tuple[int, int]] = {}
        # (app, block) -> hosts the reduced value was delivered to
        self.delivered: Dict[Tuple[int, int], Set[int]] = {}
        # broadcast fan-outs: (app, block) -> [(switch, ports, t)]
        self.bcast_fanouts: Dict[Tuple[int, int],
                                 List[Tuple[int, Tuple[int, ...], float]]] = {}
        # restoration fan-outs: (app, block) -> [(switch, ports)]
        self.restores: Dict[Tuple[int, int],
                            List[Tuple[int, Tuple[int, ...]]]] = {}
        # event counters (trace-local; SimResult counters are untouched)
        self.collisions = 0
        self.stragglers = 0
        self.timeout_flushes = 0
        self.complete_flushes = 0
        # block_tree memo — a completed generation's subtree never mutates
        # (the leader/root stops merging once complete), so reconstruction
        # is cacheable; keyed on the completed root so a later generation
        # completing the same block invalidates naturally
        self._tree_cache: Dict[Tuple[int, int, int], BlockTree] = {}

    # ------------------------------------------------------------ node mgmt
    def _new_node(self, kind: str, where: int, pid: int) -> TraceNode:
        node = TraceNode(node_id=len(self.nodes), kind=kind, where=where,
                         pid=pid, t_start=self.sim.now)
        self.nodes.append(node)
        return node

    def _node_of_packet(self, pkt) -> TraceNode:
        if pkt.trace_node < 0:
            # Defensive: a REDUCE packet from an unhooked creation site.
            # Synthesize a leaf so the tree stays connected (src < 0 would
            # mean a switch-made packet — those are always tagged on flush).
            node = self._new_node(HOST_SEND, pkt.src, pkt.id)
            if pkt.src >= 0:
                node.contribs[pkt.src] += 1
            pkt.trace_node = node.node_id
        return self.nodes[pkt.trace_node]

    def _merge(self, parent: TraceNode, in_port: int, pkt) -> None:
        child = self._node_of_packet(pkt)
        parent.children.append(child.node_id)
        parent.in_ports.append(in_port)
        parent.contribs.update(child.contribs)

    # ------------------------------------------------------ host-side hooks
    def on_host_send(self, host: int, pkt) -> None:
        """A host emitted a REDUCE contribution (first send or a new
        generation after a §3.3 failure round)."""
        node = self._new_node(HOST_SEND, host, pkt.id)
        node.contribs[host] += 1
        pkt.trace_node = node.node_id

    def on_leader_merge(self, host: int, pkt) -> None:
        """The leader accepted a (partial) aggregate for the current
        generation (§3.1.4)."""
        key = (id_app(pkt.id), id_block(pkt.id), id_gen(pkt.id))
        nid = self._roots.get(key)
        if nid is None:
            node = self._new_node(LEADER, host, pkt.id)
            self._roots[key] = node.node_id
        else:
            node = self.nodes[nid]
        self._merge(node, -1, pkt)

    def on_leader_complete(self, host: int, app: int, block: int,
                           gen: int) -> None:
        """The leader's counter reached hosts-1: the reduction of this
        generation is complete. The leader's own contribution never crossed
        the wire (§3.1.4) — attach it as a local leaf."""
        key = (app, block, gen)
        nid = self._roots.get(key)
        if nid is None:  # single-contributor degenerate case
            node = self._new_node(LEADER, host, make_id(app, block, gen))
            self._roots[key] = nid = node.node_id
        node = self.nodes[nid]
        if self.sim.strategy.leader_skips_self:
            self_leaf = self._new_node(HOST_SEND, host,
                                       make_id(app, block, gen))
            self_leaf.contribs[host] += 1
            node.children.append(self_leaf.node_id)
            node.in_ports.append(-1)
            node.contribs.update(self_leaf.contribs)
        node.t_flush = self.sim.now
        self.completed[(app, block)] = (node.node_id, gen)

    def on_host_complete(self, host: int, app: int, block: int) -> None:
        self.delivered.setdefault((app, block), set()).add(host)

    def on_restore(self, pid: int, sw: int, ports: Tuple[int, ...]) -> None:
        self.restores.setdefault(block_key(pid), []).append((sw, ports))

    # ---------------------------------------------------- switch-side hooks
    def on_desc_alloc(self, sw: int, desc, in_port: int, pkt) -> None:
        node = self._new_node(SWITCH_DESC, sw, pkt.id)
        desc.trace_node = node.node_id
        self._merge(node, in_port, pkt)

    def on_switch_merge(self, sw: int, desc, in_port: int, pkt) -> None:
        if desc.trace_node < 0:  # descriptor allocated before tracing began
            node = self._new_node(SWITCH_DESC, sw, pkt.id)
            desc.trace_node = node.node_id
        self._merge(self.nodes[desc.trace_node], in_port, pkt)

    def on_desc_flush(self, sw: int, desc, out_pkt, reason: str) -> None:
        """The descriptor forwarded its aggregate (timeout or complete);
        from here on the outgoing packet *is* this node."""
        if desc.trace_node < 0:
            node = self._new_node(SWITCH_DESC, sw, desc.id)
            desc.trace_node = node.node_id
        node = self.nodes[desc.trace_node]
        node.flush_reason = reason
        node.t_flush = self.sim.now
        out_pkt.trace_node = node.node_id
        if reason == FLUSH_TIMEOUT:
            self.timeout_flushes += 1
        else:
            self.complete_flushes += 1

    def on_static_root_done(self, sw: int, desc) -> None:
        """STATIC_TREE: the root switch completed the reduction — it is the
        tree root (there is no leader-host aggregation)."""
        if desc.trace_node < 0:
            return
        node = self.nodes[desc.trace_node]
        node.kind = STATIC_ROOT
        node.t_flush = self.sim.now
        key = (node.app, node.block)
        self.completed[key] = (node.node_id, node.gen)

    def on_collision(self, sw: int, in_port: int, pkt) -> None:
        self.collisions += 1

    def on_straggler(self, sw: int, in_port: int, pkt) -> None:
        # The descriptor already fired: the packet continues to the leader
        # unmerged, so its edge is recorded there, not here (§3.1.1). The
        # broadcast still fans out to this port via desc.children.
        self.stragglers += 1

    def on_bcast_fanout(self, sw: int, pkt, ports) -> None:
        self.bcast_fanouts.setdefault(block_key(pkt.id), []).append(
            (sw, tuple(sorted(ports)), self.sim.now))

    # ------------------------------------------------------------- analysis
    def block_keys(self) -> List[Tuple[int, int]]:
        return sorted(self.completed)

    def block_tree(self, app: int, block: int) -> BlockTree:
        """Reconstruct the completed reduction tree of ``(app, block)``."""
        try:
            root, gen = self.completed[(app, block)]
        except KeyError:
            raise KeyError(
                f"no completed reduction recorded for app={app} "
                f"block={block} (host-based algorithms are not traced)"
            ) from None
        cached = self._tree_cache.get((app, block, root))
        if cached is not None:
            return cached
        nodes: Dict[int, TraceNode] = {}
        stack = [root]
        while stack:
            nid = stack.pop()
            if nid in nodes:
                continue
            node = self.nodes[nid]
            nodes[nid] = node
            stack.extend(node.children)
        tree = BlockTree(app=app, block=block, gen=gen, root=root,
                         nodes=nodes, participants=sorted(
                             self.sim.partset[app]))
        self._tree_cache[(app, block, root)] = tree
        return tree

    def trees(self, app: int) -> List[BlockTree]:
        return [self.block_tree(a, b) for a, b in self.block_keys()
                if a == app]

    def deepest_tree(self) -> Optional[BlockTree]:
        best: Optional[BlockTree] = None
        best_depth = -1
        for a, b in self.block_keys():
            t = self.block_tree(a, b)
            d = t.depth()
            if d > best_depth:
                best, best_depth = t, d
        return best

    def summary(self) -> str:
        n_blocks = len(self.completed)
        deepest = self.deepest_tree()
        lines = [f"trace: {n_blocks} completed blocks, "
                 f"{len(self.nodes)} nodes, "
                 f"timeout_flushes={self.timeout_flushes} "
                 f"complete_flushes={self.complete_flushes} "
                 f"collisions={self.collisions} stragglers={self.stragglers}"]
        if deepest is not None:
            lines.append(f"deepest tree: {deepest.summary()}")
        return "\n".join(lines)
