"""Trace-and-replay subsystem: record, compile and execute dynamic trees.

Three stages (see ``ARCHITECTURE.md``):

1. :mod:`~.recorder` — :class:`TraceRecorder`, attached by the simulator when
   ``SimConfig(trace=True)``; reconstructs the dynamic tree every block
   actually rode (observation-only: traced runs stay golden-identical).
2. :mod:`~.schedule` — lowers a recorded :class:`BlockTree` into a
   deterministic round-based :class:`Schedule` (reduce rounds = segment-sums,
   broadcast rounds = mirrored copies).
3. :mod:`~.executor` — replays a schedule on real arrays with the Pallas
   kernels (``packet_accum`` for per-switch accumulation, ``fixedpoint`` for
   the bit-identical int32 mode).

The recorder and compiler are jax-free (importable next to the simulator);
the executor pulls in jax lazily via module ``__getattr__``.

Typical round trip::

    cfg = scaled_config(4, trace=True)
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    sim.run()
    scheds = compile_app(sim.trace, app=0)
    out, q = fixed_point_replay(scheds, x)     # bit-identical int32 result
"""
from .recorder import (FLUSH_COMPLETE, FLUSH_TIMEOUT, HOST_SEND, LEADER,
                       STATIC_ROOT, SWITCH_DESC, BlockTree, TraceNode,
                       TraceRecorder)
from .schedule import (CopyStep, ReduceStep, Schedule, compile_app,
                       compile_block, schedule_report)

_EXECUTOR_SYMBOLS = ("replay_block", "replay_app", "fixed_point_replay",
                     "reference_allreduce")

__all__ = [
    "BlockTree", "CopyStep", "FLUSH_COMPLETE", "FLUSH_TIMEOUT", "HOST_SEND",
    "LEADER", "ReduceStep", "STATIC_ROOT", "SWITCH_DESC", "Schedule",
    "TraceNode", "TraceRecorder", "compile_app", "compile_block",
    "schedule_report", *_EXECUTOR_SYMBOLS,
]


def __getattr__(name: str):
    if name in _EXECUTOR_SYMBOLS:
        from . import executor
        return getattr(executor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
