"""Binomial reduction trees over a mesh axis, built from ``lax.ppermute``.

This is the TPU-native analogue of the paper's switch trees: at every round a
device receives its partner's partial sum and aggregates — the device *is*
the switch. A tree is parameterized by its ``root``; Canary's "dynamic trees"
become per-block root assignments (see ``canary_allreduce``), and the
reduce-phase tree is retraced in reverse for the broadcast phase, exactly as
in §3.1.2.

Topology note (DESIGN.md §4): on a ring/torus ICI, hop ``j`` of a binomial
tree moves data across ``2^j`` links; the multi-root schedule spreads those
hot hops across the ring. A bandwidth-optimal reduce-scatter/all-gather is
also provided as the "host-based ring" reference point and as the §Perf
beyond-paper optimization target.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _rounds(n: int) -> int:
    return max(1, math.ceil(math.log2(n)))


def tree_reduce_broadcast(x: jnp.ndarray, axis_name: str, axis_size: int,
                          root: int) -> jnp.ndarray:
    """Allreduce ``x`` along ``axis_name`` with a binomial tree rooted at
    ``root``: log2(N) aggregation rounds toward the root, then the recorded
    tree is traversed in reverse to broadcast (paper §3.1.1-§3.1.2)."""
    if axis_size == 1:
        return x
    idx = lax.axis_index(axis_name)
    rel = (idx - root) % axis_size
    acc = x
    R = _rounds(axis_size)
    # ---- reduce phase: partial sums climb toward rel=0 ----------------------
    for j in range(R):
        stride = 1 << j
        perm = [(i, (i - stride) % axis_size) for i in range(axis_size)]
        shifted = lax.ppermute(acc, axis_name, perm)
        receives = ((rel % (stride * 2)) == 0) & (rel + stride < axis_size)
        acc = jnp.where(receives, acc + shifted, acc)
    # ---- broadcast phase: retrace the tree in reverse ------------------------
    for j in reversed(range(R)):
        stride = 1 << j
        perm = [(i, (i + stride) % axis_size) for i in range(axis_size)]
        shifted = lax.ppermute(acc, axis_name, perm)
        takes = ((rel % (stride * 2)) == stride) & (rel - stride >= 0)
        acc = jnp.where(takes, shifted, acc)
    return acc


def multi_root_tree_allreduce(x: jnp.ndarray, axis_name: str, axis_size: int,
                              roots: Sequence[int]) -> jnp.ndarray:
    """Blockwise multi-tree allreduce — the Canary schedule.

    ``x`` (any shape) is flattened and split into ``len(roots)`` blocks;
    block ``k`` is reduced along the tree rooted at ``roots[k]``. All blocks
    share each round's single ``ppermute`` (the permutation is
    root-independent; only the aggregation masks differ), so the number of
    collective ops stays 2*log2(N) regardless of block count.
    """
    if axis_size == 1:
        return x
    k = len(roots)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % k
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(k, -1)
    idx = lax.axis_index(axis_name)
    roots_arr = jnp.asarray(list(roots), jnp.int32)
    rel = (idx - roots_arr) % axis_size                    # (k,)
    acc = blocks
    R = _rounds(axis_size)
    for j in range(R):
        stride = 1 << j
        perm = [(i, (i - stride) % axis_size) for i in range(axis_size)]
        shifted = lax.ppermute(acc, axis_name, perm)
        receives = ((rel % (stride * 2)) == 0) & (rel + stride < axis_size)
        acc = jnp.where(receives[:, None], acc + shifted, acc)
    for j in reversed(range(R)):
        stride = 1 << j
        perm = [(i, (i + stride) % axis_size) for i in range(axis_size)]
        shifted = lax.ppermute(acc, axis_name, perm)
        takes = ((rel % (stride * 2)) == stride) & (rel - stride >= 0)
        acc = jnp.where(takes[:, None], shifted, acc)
    out = acc.reshape(-1)
    if pad:
        out = out[:flat.shape[0] - pad]
    return out.reshape(x.shape)


def _rs_dtype(x: jnp.ndarray) -> jnp.ndarray:
    """XLA:CPU's AllReducePromotion pass crashes on bf16 reduce-scatter
    ("Invalid binary instruction opcode copy"); upcast around the collective
    on the CPU backend only — TPU keeps native bf16 collectives."""
    if jax.default_backend() == "cpu" and x.dtype == jnp.bfloat16:
        return x.astype(jnp.float32)
    return x


def ring_allreduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bandwidth-optimal reduce-scatter + all-gather (the paper's host-based
    ring reference), via XLA's native collectives."""
    flat = _rs_dtype(x.reshape(-1))
    n = lax.axis_size(axis_name)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    scattered = lax.psum_scatter(flat, axis_name, scatter_dimension=0,
                                 tiled=True)
    gathered = lax.all_gather(scattered, axis_name, axis=0, tiled=True)
    if pad:
        gathered = gathered[:flat.shape[0] - pad]
    return gathered.reshape(x.shape).astype(x.dtype)


def hierarchical_allreduce(x: jnp.ndarray, inner_axis: str, outer_axis: str
                           ) -> jnp.ndarray:
    """Two-level reduction: reduce-scatter inside the pod, allreduce of the
    scattered shards across pods, all-gather inside the pod. The in-switch
    aggregation analogue: intra-pod traffic is aggregated *before* it crosses
    the (scarcer) cross-pod links, which see only 1/pod_size of the bytes."""
    flat = _rs_dtype(x.reshape(-1))
    n = lax.axis_size(inner_axis)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    scattered = lax.psum_scatter(flat, inner_axis, scatter_dimension=0,
                                 tiled=True)
    scattered = lax.psum(scattered, outer_axis)
    gathered = lax.all_gather(scattered, inner_axis, axis=0, tiled=True)
    if pad:
        gathered = gathered[:flat.shape[0] - pad]
    return gathered.reshape(x.shape).astype(x.dtype)
