"""Public allreduce API: Canary-style gradient synchronization for pytrees.

``canary_allreduce_tree``: reduce a whole gradient pytree along the data
axes, Canary-style — the tree is flattened into blocks, each block rides its
own reduction tree (root chosen by the congestion oracle), and multi-axis
meshes reduce hierarchically (pod-local trees, then cross-pod exchange).

Optional fixed-point mode quantizes blocks to int32 before reduction
(paper §6: switch ALUs are integer-only). Integer addition is associative,
so the result is bit-identical no matter which dynamic tree shape the blocks
took — a beyond-paper determinism guarantee.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .congestion import CongestionOracle, round_robin_roots
from .trees import (hierarchical_allreduce, multi_root_tree_allreduce,
                    ring_allreduce, tree_reduce_broadcast)

DEFAULT_BLOCKS = 16


def _psum_safe(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """psum with the XLA:CPU bf16 AllReducePromotion crash workaround
    (see trees._rs_dtype); native bf16 on TPU."""
    from .trees import _rs_dtype
    return lax.psum(_rs_dtype(x), axis).astype(x.dtype)


def _leaf_allreduce(x, axis_name: str, axis_size: int, roots: Sequence[int],
                    mode: str, outer_axis: Optional[str]) -> jnp.ndarray:
    if mode == "canary":
        y = multi_root_tree_allreduce(x, axis_name, axis_size, roots)
        if outer_axis is not None:
            y = _psum_safe(y, outer_axis)
        return y
    if mode == "ring":
        y = ring_allreduce(x, axis_name)
        if outer_axis is not None:
            y = _psum_safe(y, outer_axis)
        return y
    if mode == "hierarchical":
        if outer_axis is None:
            return ring_allreduce(x, axis_name)
        return hierarchical_allreduce(x, axis_name, outer_axis)
    if mode == "psum":
        y = _psum_safe(x, axis_name)
        if outer_axis is not None:
            y = _psum_safe(y, outer_axis)
        return y
    raise ValueError(f"unknown grad-sync mode {mode}")


def canary_allreduce_tree(grads: Any, *, axis_name: str, axis_size: int,
                          roots: Optional[Sequence[int]] = None,
                          num_blocks: int = DEFAULT_BLOCKS,
                          mode: str = "canary",
                          outer_axis: Optional[str] = None,
                          fixed_point: bool = False,
                          fp_bits: int = 24) -> Any:
    """Allreduce every leaf of ``grads`` along ``axis_name`` (+``outer_axis``).

    mode: canary (multi-root trees) | ring (RS+AG) | hierarchical | psum.
    """
    if roots is None:
        roots = round_robin_roots(num_blocks, axis_size)

    def one(x):
        if fixed_point and mode == "canary":
            from repro.kernels.ops import fixed_point_allreduce_wrap
            gmax = lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
            world = axis_size
            if outer_axis is not None:
                gmax = lax.pmax(gmax, outer_axis)
                world *= lax.axis_size(outer_axis)
            return fixed_point_allreduce_wrap(
                x, lambda q: _leaf_allreduce(q, axis_name, axis_size, roots,
                                             mode, outer_axis),
                gmax, bits=fp_bits, world=world)
        return _leaf_allreduce(x, axis_name, axis_size, roots, mode,
                               outer_axis)

    return jax.tree.map(one, grads)
