"""TPU-native adaptation of Canary: multi-root tree collectives over mesh
axes with congestion-oracle block scheduling (DESIGN.md §4)."""
from ...compat import patch_jax as _patch_jax

_patch_jax()

from .api import canary_allreduce_tree
from .congestion import CongestionOracle, round_robin_roots, tree_link_load
from .trees import (hierarchical_allreduce, multi_root_tree_allreduce,
                    ring_allreduce, tree_reduce_broadcast)

__all__ = ["CongestionOracle", "canary_allreduce_tree",
           "hierarchical_allreduce", "multi_root_tree_allreduce",
           "ring_allreduce", "round_robin_roots", "tree_link_load",
           "tree_reduce_broadcast"]
