"""Congestion oracle: block -> tree(root) assignment planning.

The paper picks paths per packet from switch queue depths. A compiled XLA
program cannot re-route per packet, so the TPU adaptation moves the decision
one level up (DESIGN.md §4, changed assumption 2): between steps, the planner
re-assigns reduction blocks to tree roots using

* an **analytic link-load model** of binomial trees on a ring (hop ``j`` of a
  tree rooted at ``r`` crosses the ring links in ``[r - 2^(j+1), r - 2^j)``
  with weight 1), and
* **measured step-time feedback** (multiplicative weights over candidate
  assignments) standing in for queue-occupancy telemetry.

``round_robin`` (the paper's §3.1.3 policy) is the faithful baseline;
``balanced`` is the congestion-aware refinement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


def round_robin_roots(num_blocks: int, axis_size: int) -> List[int]:
    """Paper §3.1.3: 'the hosts could select the roots in a round-robin way'."""
    return [k % axis_size for k in range(num_blocks)]


def tree_link_load(root: int, axis_size: int) -> np.ndarray:
    """Ring-link load (per direction) of one binomial tree rooted at ``root``.

    Hop ``j`` sends partials from relative index ``2^j + m*2^(j+1)`` to
    ``m*2^(j+1)``; on a ring each such transfer crosses ``2^j`` consecutive
    links. Returns an (axis_size,) array of link weights.
    """
    load = np.zeros(axis_size)
    rounds = max(1, math.ceil(math.log2(axis_size)))
    for j in range(rounds):
        stride = 1 << j
        senders = [s for s in range(stride, axis_size, 2 * stride)]
        for rel in senders:
            src = (root + rel) % axis_size
            # data travels from src toward src - stride (down-ring)
            for step in range(stride):
                load[(src - 1 - step) % axis_size] += 1.0
    return load * 2.0  # broadcast retraces the same links in reverse


@dataclass
class CongestionOracle:
    """Stateful planner. ``plan()`` returns the root per block; ``feedback()``
    folds a measured step time back into the estimate."""

    axis_size: int
    num_blocks: int
    policy: str = "balanced"            # round_robin | balanced
    external_load: Optional[np.ndarray] = None  # modeled non-collective traffic
    _weights: np.ndarray = field(default=None, repr=False)  # type: ignore
    _history: List[float] = field(default_factory=list)

    def __post_init__(self):
        if self._weights is None:
            self._weights = np.ones(self.axis_size)

    def plan(self) -> List[int]:
        if self.policy == "round_robin":
            return round_robin_roots(self.num_blocks, self.axis_size)
        # balanced: greedy min-max assignment over modeled link load
        base = np.zeros(self.axis_size)
        if self.external_load is not None:
            base = base + np.asarray(self.external_load, dtype=float)
        per_root = [tree_link_load(r, self.axis_size) * self._weights[r]
                    for r in range(self.axis_size)]
        total = base.copy()
        roots: List[int] = []
        for _ in range(self.num_blocks):
            best, best_peak = 0, float("inf")
            for r in range(self.axis_size):
                peak = float(np.max(total + per_root[r]))
                if peak < best_peak - 1e-12:
                    best, best_peak = r, peak
            roots.append(best)
            total += per_root[best]
        return roots

    def feedback(self, step_time_s: float) -> None:
        """Multiplicative-weights update: a slower-than-median step inflates
        the weight of the roots used most recently, discouraging them."""
        self._history.append(step_time_s)
        if len(self._history) < 3:
            return
        med = float(np.median(self._history[-16:]))
        ratio = step_time_s / max(med, 1e-12)
        # uniform decay toward 1 keeps the oracle stable
        self._weights = np.clip(self._weights * (0.9 + 0.1 * ratio), 0.5, 2.0)
