"""Pluggable transport policies for the packet-level simulator.

String-keyed registry mirroring the algorithm (``switch.ALGORITHMS``),
topology (``topology.TOPOLOGIES``) and backend (``backends.BACKENDS``)
registries. Built-ins:

* ``none``  — the default. Resolved to ``None`` (not an object): every hook
  site in the canary layers short-circuits on one identity check and the
  golden replays stay bit-identical.
* ``gbn``   — go-back-N loss recovery (per-flow sequence numbers, cumulative
  ACKs, block-level re-request flows). See :mod:`.gbn`.
* ``dcqcn`` — RED/ECN marking at egress queues, CNP notification, the DCQCN
  rate-control state machine pacing the host pump, and PFC priority pause.
  See :mod:`.dcqcn`.

Registering a policy::

    from repro.core.transport import register_transport
    from repro.core.transport.base import TransportPolicy

    @register_transport("my_policy")
    class MyPolicy(TransportPolicy):
        ...

then run with ``SimConfig(transport="my_policy")``. This package imports
only the jax-free canary core (the subprocess import test pins that).
"""
from __future__ import annotations

from typing import Dict, Optional, Type

from .base import TX_ABSORBED, TX_PAUSED, TransportPolicy

__all__ = ["TRANSPORTS", "register_transport", "make_transport",
           "TransportPolicy", "TX_PAUSED", "TX_ABSORBED"]

TRANSPORTS: Dict[str, Type[TransportPolicy]] = {}


def register_transport(name: str):
    """Class decorator: bind a policy class to its registry key."""

    def deco(cls: Type[TransportPolicy]) -> Type[TransportPolicy]:
        cls.name = name
        TRANSPORTS[name] = cls
        return cls

    return deco


def make_transport(name, sim) -> Optional[TransportPolicy]:
    """Instantiate the policy registered under ``name`` (``"none"`` ->
    ``None``, the hook-free fast path)."""
    key = str(name)
    if key == "none":
        return None
    try:
        cls = TRANSPORTS[key]
    except KeyError:
        raise ValueError(
            f"no transport policy registered under {name!r}; registered: "
            f"{['none'] + sorted(TRANSPORTS)}") from None
    return cls(sim)


from . import dcqcn as _dcqcn  # noqa: E402,F401  (registers "dcqcn")
from . import gbn as _gbn      # noqa: E402,F401  (registers "gbn")
