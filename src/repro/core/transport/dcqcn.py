"""DCQCN-style congestion control: RED/ECN marking, CNPs, rate control, PFC.

The policy models the RoCEv2 congestion-control stack the NetReduce line of
work assumes underneath in-network reduction:

* **RED/ECN at egress** (:meth:`on_egress`): every serialize onto a link
  observes the backlog ahead of the packet; the mark probability ramps
  linearly from 0 at ``ecn_kmin_bytes`` to ``ecn_pmax`` at ``ecn_kmax_bytes``
  and is 1 above. Marks use the policy's **own** RNG stream — the core RNG's
  draw sequence is pinned by the golden contract.
* **CNP notification** (:meth:`on_receive`): a receiver seeing an ECN mark
  sends at most one CNP per ``cnp_interval_ns`` per (receiver, sender) pair.
  CNP/ACK control packets are modelled as a lossless priority class: they
  are never paced, paused, or marked.
* **DCQCN rate machine**: on CNP, ``target = rate; rate *= 1 - alpha/2;
  alpha = (1-g)*alpha + g; stage = 0`` and the rate-increase timer is armed.
  Each ``dcqcn_timer_ns`` tick decays alpha, runs fast recovery
  (``rate = (rate+target)/2``) for ``dcqcn_f`` stages and then additive
  increase (``target += dcqcn_rai_gbps``), snapping back to (and disarming
  at) line rate. The current rate paces the host pump via inter-packet gaps
  (``before_send`` returning a float release time).
* **PFC priority pause**: crossing ``pfc_pause_bytes`` of backlog pauses the
  *culprit sender* (a deliberate simplification of per-ingress-port pause —
  the simulator has no per-port ingress queues to backpressure): an
  ``EV_PFC_PAUSE`` lands one hop latency later, and the matching
  ``EV_PFC_RESUME`` is scheduled at the closed-form drain time of the
  backlog down to ``pfc_resume_bytes``. Deeper crossings supersede earlier
  resumes (``pause_until`` max-tracking; stale resumes carry their scheduled
  time and are dropped on mismatch).
"""
from __future__ import annotations

import random
from typing import Dict

from ..canary.engine import EV_PFC_PAUSE, EV_PFC_RESUME, EV_RATE_TIMER
from ..canary.types import PacketKind
from . import register_transport
from .base import TX_PAUSED, TransportPolicy

_K_CNP = int(PacketKind.CNP)  # CNP/ACK: the lossless control class (>= CNP)


class _HostCC:
    """Per-host DCQCN sender state."""

    __slots__ = ("rate", "target", "alpha", "stage", "timer_epoch",
                 "timer_armed", "next_free", "paused", "pause_pending",
                 "pause_until", "pause_start")

    def __init__(self, line_rate: float) -> None:
        self.rate = line_rate    # current send rate, bytes/ns
        self.target = line_rate
        self.alpha = 1.0
        self.stage = 0
        self.timer_epoch = 0
        self.timer_armed = False
        self.next_free = 0.0     # pacing: earliest next transmission
        self.paused = False      # PFC pause in effect
        self.pause_pending = False
        self.pause_until = 0.0   # latest scheduled resume time
        self.pause_start = 0.0


@register_transport("dcqcn")
class Dcqcn(TransportPolicy):
    """ECN marking + CNPs + DCQCN rate control + PFC pause."""

    def __init__(self, sim):
        super().__init__(sim)
        cfg = sim.cfg
        self._engine = sim.engine
        self._push = sim.engine.push
        self._push_timer = sim.engine.push_timer
        self._pool = sim.pool
        self._pool_free = sim.pool.free
        self._hp = sim.hostproto
        # policy-private RNG: the core stream's draw order is golden-pinned
        self._rng = random.Random(cfg.seed ^ 0x5DEECE66D)
        self._line = cfg.bytes_per_ns
        self._kmin = float(cfg.ecn_kmin_bytes)
        self._kmax = float(cfg.ecn_kmax_bytes)
        self._pmax = cfg.ecn_pmax
        span = self._kmax - self._kmin
        self._ramp = self._pmax / span if span > 0 else 0.0
        self._cnp_gap = cfg.cnp_interval_ns
        self._g = cfg.dcqcn_g
        self._rai = cfg.dcqcn_rai_gbps / 8.0          # Gb/s -> bytes/ns
        self._timer_ns = cfg.dcqcn_timer_ns
        self._min_rate = cfg.dcqcn_min_rate_gbps / 8.0
        self._fstages = cfg.dcqcn_f
        self._xoff = float(cfg.pfc_pause_bytes)
        self._xon = float(cfg.pfc_resume_bytes)
        self._cc = [_HostCC(self._line) for _ in range(cfg.num_hosts)]
        self._telemetry = None  # observation-only; bound in finalize()
        self._last_cnp: Dict[tuple, float] = {}  # (receiver, sender) -> t
        self._cnp_bytes = cfg.header_bytes + 8
        self.ecn_marks = 0
        self.cnps = 0
        self.rate_cuts = 0
        self.pfc_pauses = 0
        self.pfc_pause_ns = 0.0

    def finalize(self) -> None:
        # the telemetry hub is constructed after the transport layer
        self._telemetry = self.sim.telemetry

    # ------------------------------------------------------------ send path
    def before_send(self, host: int, pkt):
        if pkt.kind >= _K_CNP:
            return None  # control class: never paused or paced
        st = self._cc[host]
        if st.paused:
            return TX_PAUSED  # resume event re-pumps
        if st.rate >= self._line:
            return None
        nf = st.next_free
        if nf > self._engine.now:
            return nf  # paced: hold until the inter-packet gap elapses
        return None

    def after_send(self, host: int, pkt, nic_free: float) -> float:
        st = self._cc[host]
        if st.rate >= self._line or pkt.kind >= _K_CNP:
            return nic_free
        now = self._engine.now
        base = st.next_free if st.next_free > now else now
        st.next_free = nf = base + pkt.size_bytes / st.rate
        return nf if nf > nic_free else nic_free

    # ---------------------------------------------------------- fabric egress
    def on_egress(self, link, pkt, qdelay_ns: float) -> None:
        backlog = qdelay_ns * link.bytes_per_ns
        kind = pkt.kind
        if backlog > self._kmin and kind < _K_CNP and not pkt.ecn:
            # RED ramp; >= Kmax marks deterministically
            if backlog >= self._kmax \
                    or self._rng.random() < (backlog - self._kmin) * self._ramp:
                pkt.ecn = True
                self.ecn_marks += 1
        if backlog >= self._xoff and pkt.src >= 0 and kind < _K_CNP:
            st = self._cc[pkt.src]
            now = self._engine.now
            lat = link.latency_ns
            resume_t = now + (backlog - self._xon) / link.bytes_per_ns + lat
            if resume_t > st.pause_until:
                if not st.pause_pending and not st.paused:
                    st.pause_pending = True
                    self._push(now + lat, EV_PFC_PAUSE, pkt.src, 0, None)
                st.pause_until = resume_t
                self._push(resume_t, EV_PFC_RESUME, pkt.src, 0, resume_t)

    # --------------------------------------------------------- receive path
    def on_receive(self, host: int, pkt):
        kind = pkt.kind
        if kind == _K_CNP:
            self._rate_cut(host)
            self._pool_free(pkt)
            return None
        if pkt.ecn and pkt.src >= 0 and kind < _K_CNP:
            key = (host, pkt.src)
            now = self._engine.now
            if now - self._last_cnp.get(key, -1e18) >= self._cnp_gap:
                self._last_cnp[key] = now
                cnp = self._pool.alloc()
                cnp.kind = PacketKind.CNP
                cnp.dest = pkt.src
                cnp.id = 0
                cnp.value = 0
                cnp.size_bytes = self._cnp_bytes
                cnp.src = host
                self._hp.hosts[host].queue.append(cnp)
                self._hp.schedule_pump(host, now)
                self.cnps += 1
                if self._telemetry is not None:
                    self._telemetry.on_cnp(host, pkt.src)
        return pkt

    # ------------------------------------------------------- DCQCN rate logic
    def _rate_cut(self, host: int) -> None:
        st = self._cc[host]
        st.target = st.rate
        st.rate *= 1.0 - st.alpha / 2.0
        if st.rate < self._min_rate:
            st.rate = self._min_rate
        st.alpha = (1.0 - self._g) * st.alpha + self._g
        st.stage = 0
        self.rate_cuts += 1
        if not st.timer_armed:
            st.timer_armed = True
            st.timer_epoch += 1
            self._push_timer(self._engine.now + self._timer_ns, EV_RATE_TIMER,
                             host, 0, st.timer_epoch)

    def handle_rate_timer(self, a: int, b: int, c: object) -> None:
        st = self._cc[a]
        if c != st.timer_epoch or not st.timer_armed:
            return  # lazily-cancelled stale timer
        st.alpha *= 1.0 - self._g
        st.stage += 1
        if st.stage > self._fstages:
            st.target += self._rai  # additive increase past fast recovery
            if st.target > self._line:
                st.target = self._line
        st.rate = (st.rate + st.target) / 2.0  # fast recovery toward target
        if st.rate >= 0.999 * self._line:
            st.rate = self._line
            st.timer_armed = False
            return
        st.timer_epoch += 1
        self._push_timer(self._engine.now + self._timer_ns, EV_RATE_TIMER,
                         a, 0, st.timer_epoch)

    # ----------------------------------------------------------- PFC events
    def handle_pfc_pause(self, a: int, b: int, c: object) -> None:
        st = self._cc[a]
        st.pause_pending = False
        if not st.paused:
            st.paused = True
            st.pause_start = self._engine.now
            self.pfc_pauses += 1
            if self._telemetry is not None:
                self._telemetry.on_pfc(a, True)

    def handle_pfc_resume(self, a: int, b: int, c: object) -> None:
        st = self._cc[a]
        if c < st.pause_until:
            return  # superseded by a deeper later crossing
        if st.paused:
            st.paused = False
            self.pfc_pause_ns += self._engine.now - st.pause_start
            self._hp.schedule_pump(a, self._engine.now)
            if self._telemetry is not None:
                self._telemetry.on_pfc(a, False)
        st.pause_pending = False

    # ------------------------------------------------------------- telemetry
    def telemetry(self):
        now = self._engine.now
        pause_ns = self.pfc_pause_ns
        rates = {}
        for h, st in enumerate(self._cc):
            if st.paused:  # residual: run ended mid-pause
                pause_ns += now - st.pause_start
            if st.rate < self._line:
                rates[h] = st.rate * 8.0  # bytes/ns -> Gb/s
        return {"ecn_marks": float(self.ecn_marks),
                "cnps": float(self.cnps),
                "rate_cuts": float(self.rate_cuts),
                "pfc_pauses": float(self.pfc_pauses),
                "pfc_pause_ns": pause_ns,
                "host_rate_gbps": rates}
