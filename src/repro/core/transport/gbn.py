"""Go-back-N transport policy: sequenced flows + block re-request flows.

Replaces the core's bare whole-block ``EV_RETX`` timer with NetReduce-style
go-back-N recovery, at two granularities:

* **Packet flows** — point-to-point sequenced traffic (the RING collective's
  per-neighbor streams). Each ``(sender, dest)`` flow stamps per-packet
  sequence numbers at first transmission, keeps an in-window ``unacked``
  snapshot map for retransmission, absorbs window overflow into a ``stalled``
  queue (so one stalled flow never blocks the host's other traffic), and
  runs a single per-flow timeout that retransmits the whole outstanding
  window in order — classic go-back-N. Receivers deliver strictly in order,
  discard anything else (counted in ``gbn_ooo``), and answer with cumulative
  ACKs (every ``gbn_ack_every`` deliveries, plus an immediate duplicate ACK
  on each discard so the sender re-syncs quickly).

* **Block flows** — the aggregated collectives (CANARY/STATIC_TREE), where
  a "flow" toward the leader is consumed in-network and per-packet sequencing
  is meaningless. Each ``(host, app)`` flow tracks the set of sent-but-
  incomplete blocks and re-requests up to ``gbn_window`` of them per
  ``retx_timeout_ns`` round via :meth:`HostProtocol.gbn_request_block` —
  superseding both EV_RETX arm sites (the cursor walk and the FAIL resend).

Both flow kinds share ``EV_GBN_TIMER`` (payload ``(tag, key, epoch)``,
lazy-cancelled by epoch mismatch: one live heap entry per armed flow).
No randomness is used, so runs stay deterministic per seed.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Set, Tuple

from ..canary.engine import EV_GBN_TIMER
from ..canary.types import Packet, PacketKind
from . import register_transport
from .base import TX_ABSORBED, TransportPolicy

_K_RING = int(PacketKind.RING)
_K_ACK = int(PacketKind.ACK)


class _PktFlow:
    """Sender-side go-back-N state for one (host, dest) sequenced flow."""

    __slots__ = ("base", "next_seq", "unacked", "stalled", "epoch",
                 "timer_armed")

    def __init__(self) -> None:
        self.base = 0       # lowest unacknowledged sequence number
        self.next_seq = 0   # next sequence number to stamp
        # seq -> (dest, value, size_bytes, chunk, step, id) retx snapshot
        self.unacked: Dict[int, tuple] = {}
        self.stalled: Deque[Packet] = deque()  # window-overflow, FIFO by seq
        self.epoch = 0      # lazy timer cancellation
        self.timer_armed = False


class _BlockFlow:
    """Per-(host, app) set of sent-but-incomplete blocks to re-request."""

    __slots__ = ("outstanding", "epoch", "timer_armed")

    def __init__(self) -> None:
        self.outstanding: Set[int] = set()
        self.epoch = 0
        self.timer_armed = False


@register_transport("gbn")
class GoBackN(TransportPolicy):
    """Go-back-N recovery for both sequenced and aggregated flows."""

    owns_block_retx = True

    def __init__(self, sim):
        super().__init__(sim)
        cfg = sim.cfg
        self._engine = sim.engine
        self._push_timer = sim.engine.push_timer
        self._pool = sim.pool
        self._pool_free = sim.pool.free
        self._hp = sim.hostproto
        self._window = cfg.gbn_window
        self._timeout = cfg.gbn_timeout_ns
        self._block_timeout = cfg.retx_timeout_ns
        self._ack_every = cfg.gbn_ack_every
        self._ack_bytes = cfg.header_bytes + 8
        self._telemetry = None  # observation-only; bound in finalize()
        self._flows: Dict[Tuple[int, int], _PktFlow] = {}
        self._bflows: Dict[Tuple[int, int], _BlockFlow] = {}
        self._expected: Dict[Tuple[int, int], int] = {}  # (host, src) -> seq
        self._ack_due: Dict[Tuple[int, int], int] = {}
        self.gbn_retx = 0
        self.gbn_acks = 0
        self.gbn_ooo = 0

    def finalize(self) -> None:
        # the telemetry hub is constructed after the transport layer
        self._telemetry = self.sim.telemetry

    # ------------------------------------------------------------ send path
    def before_send(self, host: int, pkt):
        if pkt.kind != _K_RING:
            return None  # only sequenced point-to-point traffic is windowed
        key = (host, pkt.dest)
        f = self._flows.get(key)
        if f is None:
            f = self._flows[key] = _PktFlow()
        seq = pkt.seq
        if seq < 0:
            pkt.seq = seq = f.next_seq
            f.next_seq = seq + 1
        elif seq in f.unacked:
            return None  # timeout retransmission of a live packet
        elif seq < f.base:
            # stale retx clone raced the cumulative ACK: already delivered
            self._pool_free(pkt)
            return TX_ABSORBED
        # first transmission (fresh stamp, or released from the stall queue)
        if f.stalled or seq >= f.base + self._window:
            f.stalled.append(pkt)
            return TX_ABSORBED
        f.unacked[seq] = (pkt.dest, pkt.value, pkt.size_bytes, pkt.chunk,
                          pkt.step, pkt.id)
        if not f.timer_armed:
            f.timer_armed = True
            f.epoch += 1
            self._push_timer(self._engine.now + self._timeout, EV_GBN_TIMER,
                             host, 0, ("p", pkt.dest, f.epoch))
        return None

    # --------------------------------------------------------- receive path
    def on_receive(self, host: int, pkt):
        kind = pkt.kind
        if kind == _K_ACK:
            self.gbn_acks += 1
            self._process_ack(host, pkt)
            self._pool_free(pkt)
            return None
        if kind == _K_RING:
            seq = pkt.seq
            if seq < 0:
                return pkt  # unsequenced (pre-policy traffic): deliver as-is
            key = (host, pkt.src)
            exp = self._expected.get(key, 0)
            if seq == exp:
                self._expected[key] = exp + 1
                self._maybe_ack(host, pkt.src, exp)
                return pkt
            # out of order: a gap after a loss, or a duplicate behind the
            # cursor — go-back-N receivers discard both, and the immediate
            # duplicate cumulative ACK re-syncs the sender's window
            self.gbn_ooo += 1
            if self._telemetry is not None:
                self._telemetry.on_gbn("ooo", host, 1)
            if exp > 0:
                self._send_ack(host, pkt.src, exp - 1)
            self._pool_free(pkt)
            return None
        return pkt

    def _process_ack(self, host: int, pkt) -> None:
        f = self._flows.get((host, pkt.src))
        if f is None:
            return
        cum = pkt.seq
        if cum < f.base:
            return  # duplicate ACK behind the window base
        unacked = f.unacked
        for s in range(f.base, cum + 1):
            unacked.pop(s, None)
        f.base = cum + 1
        # window slid: release stalled packets back into the send queue
        stalled = f.stalled
        limit = f.base + self._window
        released = False
        if stalled:
            hq = self._hp.hosts[host].queue
            while stalled and stalled[0].seq < limit:
                hq.append(stalled.popleft())
                released = True
        if not unacked and not stalled:
            f.epoch += 1  # lazy-cancel the flow timer: nothing outstanding
            f.timer_armed = False
        if released:
            self._hp.schedule_pump(host, self._engine.now)

    def _maybe_ack(self, host: int, src: int, cum: int) -> None:
        key = (host, src)
        due = self._ack_due.get(key, 0) + 1
        if due >= self._ack_every:
            self._ack_due[key] = 0
            self._send_ack(host, src, cum)
        else:
            self._ack_due[key] = due

    def _send_ack(self, host: int, src: int, cum: int) -> None:
        ack = self._pool.alloc()
        ack.kind = PacketKind.ACK
        ack.dest = src
        ack.id = 0
        ack.value = 0
        ack.size_bytes = self._ack_bytes
        ack.src = host
        ack.seq = cum
        self._hp.hosts[host].queue.append(ack)
        self._hp.schedule_pump(host, self._engine.now)

    # ------------------------------------------------------------ block flows
    def on_block_sent(self, host: int, app: int, block: int) -> None:
        sim = self.sim
        if sim.have.get((app, host)) is None:
            # pure contributor (reduce collective): nothing to wait for here;
            # the root's own block flow drives any recovery
            return
        key = (host, app)
        bf = self._bflows.get(key)
        if bf is None:
            bf = self._bflows[key] = _BlockFlow()
        bf.outstanding.add(block)
        if not bf.timer_armed:
            bf.timer_armed = True
            bf.epoch += 1
            self._push_timer(self._engine.now + self._block_timeout,
                             EV_GBN_TIMER, host, 0, ("b", app, bf.epoch))

    def on_block_complete(self, host: int, app: int, block: int) -> None:
        bf = self._bflows.get((host, app))
        if bf is None:
            return
        bf.outstanding.discard(block)
        if not bf.outstanding:
            bf.epoch += 1  # lazy-cancel the armed timer
            bf.timer_armed = False

    # ---------------------------------------------------------------- timers
    def handle_gbn_timer(self, a: int, b: int, c: object) -> None:
        tag, key, epoch = c
        if tag == "p":
            f = self._flows.get((a, key))
            if f is None or epoch != f.epoch:
                return  # lazily cancelled
            if not f.unacked:
                f.timer_armed = False
                return
            # go-back-N: retransmit the whole outstanding window in order
            hq = self._hp.hosts[a].queue
            alloc = self._pool.alloc
            for s in sorted(f.unacked):
                dest, value, size, chunk, step, pid = f.unacked[s]
                pkt = alloc()
                pkt.kind = PacketKind.RING
                pkt.dest = dest
                pkt.id = pid
                pkt.value = value
                pkt.size_bytes = size
                pkt.src = a
                pkt.chunk = chunk
                pkt.step = step
                pkt.seq = s
                hq.append(pkt)
                self.gbn_retx += 1
            if self._telemetry is not None:
                self._telemetry.on_gbn("retx", a, len(f.unacked))
            self._push_timer(self._engine.now + self._timeout, EV_GBN_TIMER,
                             a, 0, ("p", key, epoch))
            self._hp.schedule_pump(a, self._engine.now)
            return
        # tag == "b": block re-request round
        bf = self._bflows.get((a, key))
        if bf is None or epoch != bf.epoch:
            return
        sim = self.sim
        flags = sim.have.get((key, a))
        if flags is not None:
            done = [blk for blk in bf.outstanding if flags[blk]]
            for blk in done:
                bf.outstanding.discard(blk)
        if not bf.outstanding or sim.apps_active == 0:
            bf.timer_armed = False
            return
        for blk in sorted(bf.outstanding)[:self._window]:
            self._hp.gbn_request_block(a, key, blk)
        self._push_timer(self._engine.now + self._block_timeout, EV_GBN_TIMER,
                         a, 0, ("b", key, bf.epoch))

    # ------------------------------------------------------------- telemetry
    def telemetry(self):
        return {"gbn_retx": float(self.gbn_retx),
                "gbn_acks": float(self.gbn_acks),
                "gbn_ooo": float(self.gbn_ooo)}
