"""Transport-policy contract (see ``ARCHITECTURE.md`` §Transport).

A :class:`TransportPolicy` is the endpoint + fabric reaction layer the core
protocol stack deliberately omits: loss recovery beyond the whole-block
``EV_RETX`` timer, congestion signalling (ECN/CNP) and congestion response
(rate control, PFC pause). The canary layers call a fixed set of hooks at
their natural choke points; every hook site is guarded by a single
``transport is not None`` identity check, so the default ``none`` policy
(represented as ``None``, never an object) leaves the golden event streams
bit-identical.

Hook map (caller -> hook):

* ``hostproto.handle_pump``  -> :meth:`before_send` / :meth:`after_send`
* ``hostproto.handle_arrive``-> :meth:`on_receive`
* ``topology.tx_*`` (every egress serialize) -> :meth:`on_egress`
* strategy cursor walk / FAIL resend -> :meth:`on_block_sent`
* ``hostproto.complete_at_host`` -> :meth:`on_block_complete`
* engine events ``EV_PFC_PAUSE``/``EV_PFC_RESUME``/``EV_RATE_TIMER``/
  ``EV_GBN_TIMER`` -> the ``handle_*`` methods (wired by the facade's
  handler table).

``before_send`` is the only hook with a non-trivial return protocol: None
lets the packet go out; :data:`TX_PAUSED` parks it (the policy must re-pump
on its resume event); :data:`TX_ABSORBED` transfers packet ownership to the
policy; a float parks it until that release time (rate pacing).

Policies needing randomness must draw from their **own** ``random.Random``
stream, never ``sim.rng`` — the core RNG's draw sequence is pinned by the
golden contract.
"""
from __future__ import annotations

from typing import Dict

from ..canary.hostproto import TX_ABSORBED, TX_PAUSED

__all__ = ["TransportPolicy", "TX_PAUSED", "TX_ABSORBED"]


class TransportPolicy:
    """Base policy: every hook is a no-op pass-through.

    Subclasses register with :func:`repro.core.transport.register_transport`
    and are constructed by the facade as ``cls(sim)`` after the switch,
    hostproto and workload layers exist (the strategy does not yet);
    :meth:`finalize` runs after the whole layer graph is bound.
    """

    name = "base"
    # True when the policy replaces the per-block EV_RETX timers with its own
    # recovery (go-back-N): strategies then report sends via on_block_sent
    # instead of arming timers, and FAIL resends bypass plan-driven fabrics.
    owns_block_retx = False

    def __init__(self, sim):
        self.sim = sim
        self.cfg = sim.cfg

    def finalize(self) -> None:
        """Called once by the facade after all layers are bound."""

    # ---- host send path ---------------------------------------------------
    def before_send(self, host: int, pkt) -> object:
        """Gate a packet about to leave ``host``'s NIC. Return None to send,
        TX_PAUSED / TX_ABSORBED / a float release time otherwise."""
        return None

    def after_send(self, host: int, pkt, nic_free: float) -> float:
        """Observe a completed send; return the next pump time (>= the
        NIC-free time for pure observation, later to pace the host)."""
        return nic_free

    # ---- host receive path ------------------------------------------------
    def on_receive(self, host: int, pkt):
        """First look at every host arrival. Return the packet to hand it to
        the protocol stack, or None after consuming (and recycling) it."""
        return pkt

    # ---- fabric egress ----------------------------------------------------
    def on_egress(self, link, pkt, qdelay_ns: float) -> None:
        """Observe a packet serialized onto ``link`` with ``qdelay_ns`` of
        queue ahead of its arrival (backlog bytes = qdelay_ns *
        link.bytes_per_ns, this packet included). ECN marking and PFC
        watermark checks live here."""

    # ---- block-level reliability (owns_block_retx policies) ----------------
    def on_block_sent(self, host: int, app: int, block: int) -> None:
        """A host sent its REDUCE contribution for ``block``."""

    def on_block_complete(self, host: int, app: int, block: int) -> None:
        """``host`` completed ``block`` (result delivered and verified)."""

    # ---- engine event handlers ---------------------------------------------
    def handle_pfc_pause(self, a: int, b: int, c: object) -> None:
        pass

    def handle_pfc_resume(self, a: int, b: int, c: object) -> None:
        pass

    def handle_rate_timer(self, a: int, b: int, c: object) -> None:
        pass

    def handle_gbn_timer(self, a: int, b: int, c: object) -> None:
        pass

    # ---- telemetry ---------------------------------------------------------
    def telemetry(self) -> Dict[str, object]:
        """Counters for ``SimResult.transport_stats``. The special key
        ``host_rate_gbps`` (dict host -> Gb/s) is split out by the facade."""
        return {}
