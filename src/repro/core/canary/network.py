"""Two-level fat-tree network model (§5.2).

Topology (paper defaults): 32 leaf switches with 64 ports each (32 down to
hosts, 32 up — one to each spine), 32 spine switches with 32 ports (one per
leaf). 100 Gb/s everywhere, 300 ns per hop.

Node addressing
---------------
* hosts:   ``0 .. num_hosts-1``; host ``h`` hangs off leaf ``h // hosts_per_leaf``.
* switches (global index): leaves ``0 .. L-1``, spines ``L .. L+S-1``.

Port numbering (matches the children-bitmap semantics of §4.2)
---------------------------------------------------------------
* leaf ``l``:  port ``p < hosts_per_leaf``  -> host ``l*hosts_per_leaf + p`` (down)
               port ``hosts_per_leaf + s``  -> spine ``s``                  (up)
* spine ``s``: port ``l``                   -> leaf ``l``                   (down)

Links are unidirectional servers with a FIFO-queue fluid model: a link keeps
``busy_until`` — the time its output is committed through — and the backlog at
time ``t`` is ``(busy_until - t) * bytes_per_ns``. This gives exact
serialization + queueing delay for FIFO ports without per-byte events, and is
what the adaptive load-balancing policy (§5.2: "up port with the smallest
number of enqueued bytes") inspects.
"""
from __future__ import annotations

import random
from typing import List, Optional, Tuple

from .types import SimConfig


class Link:
    """A unidirectional link with serialization, propagation and a FIFO queue."""

    __slots__ = ("busy_until", "bytes_sent", "bytes_per_ns", "latency_ns", "capacity")

    def __init__(self, bytes_per_ns: float, latency_ns: float, capacity: int):
        self.busy_until = 0.0
        self.bytes_sent = 0
        self.bytes_per_ns = bytes_per_ns
        self.latency_ns = latency_ns
        self.capacity = capacity

    def backlog_bytes(self, now: float) -> float:
        b = (self.busy_until - now) * self.bytes_per_ns
        return b if b > 0.0 else 0.0

    def occupancy(self, now: float) -> float:
        return self.backlog_bytes(now) / self.capacity

    def transmit(self, now: float, size_bytes: int) -> float:
        """Enqueue ``size_bytes`` at ``now``; return arrival time at the far end."""
        start = self.busy_until if self.busy_until > now else now
        self.busy_until = start + size_bytes / self.bytes_per_ns
        self.bytes_sent += size_bytes
        return self.busy_until + self.latency_ns


class FatTree:
    """Topology + routing. Switch indices are global (leaves then spines)."""

    def __init__(self, cfg: SimConfig):
        cfg.validate()
        self.cfg = cfg
        self.L = cfg.num_leaves
        self.S = cfg.num_spines
        self.H = cfg.hosts_per_leaf
        bpn, lat, cap = cfg.bytes_per_ns, cfg.hop_latency_ns, cfg.buffer_bytes

        def mk() -> Link:
            return Link(bpn, lat, cap)

        # host <-> leaf
        self.host_up = [mk() for _ in range(cfg.num_hosts)]    # host -> leaf
        self.host_down = [mk() for _ in range(cfg.num_hosts)]  # leaf -> host
        # leaf <-> spine (full bipartite)
        self.leaf_up = [[mk() for _ in range(self.S)] for _ in range(self.L)]
        self.leaf_down = [[mk() for _ in range(self.S)] for _ in range(self.L)]
        # flowlet tables: (leaf, flow key) -> committed spine [37]
        self.flowlets: dict = {}

    # ---- helpers -----------------------------------------------------------
    def leaf_of(self, host: int) -> int:
        return host // self.H

    def is_leaf(self, sw: int) -> bool:
        return sw < self.L

    def spine_index(self, sw: int) -> int:
        return sw - self.L

    # Port maps (see module docstring).
    def leaf_port_of_host(self, host: int) -> int:
        return host % self.H

    def leaf_port_of_spine(self, spine: int) -> int:
        return self.H + spine

    def spine_port_of_leaf(self, leaf: int) -> int:
        return leaf

    # ---- LB: pick the up-port (spine) for a packet leaving ``leaf`` --------
    def pick_spine(self, leaf: int, now: float, flow_hash: int,
                   rng: Optional[random.Random] = None,
                   dest_leaf: int = -1, policy: Optional[str] = None) -> int:
        """Congestion-aware up-port selection (§2.1, §5.2).

        The paper's premise is an existing congestion-aware load-balancing
        substrate (CONGA [37], DRILL [41], ...). CONGA-style schemes measure
        *path* congestion, so when the destination leaf is known the metric
        is the up-link backlog **plus** the spine->dest-leaf down-link
        backlog; purely local schemes would leave destination-side hotspots
        invisible.
        """
        cfg = self.cfg
        default = flow_hash % self.S
        lb = policy if policy is not None else cfg.lb
        if lb == "ecmp":
            return default
        ups = self.leaf_up[leaf]
        path_aware = cfg.path_aware_lb

        def path_backlog(s: int) -> float:
            b = ups[s].backlog_bytes(now)
            if path_aware and dest_leaf >= 0 and dest_leaf != leaf:
                b += self.leaf_down[dest_leaf][s].backlog_bytes(now)
            return b

        if lb == "adaptive":
            thr = cfg.lb_threshold * cfg.buffer_bytes
            if path_backlog(default) <= thr:
                return default
        # least-loaded path (ties broken by default ordering for determinism)
        best, best_b = default, path_backlog(default)
        for s in range(self.S):
            b = path_backlog(s)
            if b < best_b - 1e-9:
                best, best_b = s, b
        return best

    def pick_spine_flowlet(self, leaf: int, now: float, flow_hash: int,
                           flow_key: object, rng=None,
                           dest_leaf: int = -1,
                           policy: Optional[str] = None) -> int:
        """Flowlet-sticky variant: decide once per flow key, then stick [37]."""
        key = (leaf, flow_key)
        cached = self.flowlets.get(key)
        if cached is not None:
            return cached
        spine = self.pick_spine(leaf, now, flow_hash, rng, dest_leaf=dest_leaf,
                                policy=policy)
        self.flowlets[key] = spine
        return spine

    # ---- utilization accounting ---------------------------------------------
    def all_links(self) -> List[Link]:
        out: List[Link] = []
        out.extend(self.host_up)
        out.extend(self.host_down)
        for row in self.leaf_up:
            out.extend(row)
        for row in self.leaf_down:
            out.extend(row)
        return out

    def utilizations(self, duration_ns: float) -> List[float]:
        if duration_ns <= 0:
            return [0.0 for _ in self.all_links()]
        denom = duration_ns * self.cfg.bytes_per_ns
        return [min(1.0, l.bytes_sent / denom) for l in self.all_links()]
