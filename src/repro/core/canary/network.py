"""Two-level fat-tree network model (§5.2) — the paper's topology.

Topology (paper defaults): 32 leaf switches with 64 ports each (32 down to
hosts, 32 up — one to each spine), 32 spine switches with 32 ports (one per
leaf). 100 Gb/s everywhere, 300 ns per hop.

This is the ``fat_tree`` implementation of the :class:`~.topology.Topology`
protocol (see ``topology.py`` for the protocol and the registry, and
``ARCHITECTURE.md`` for the layer map). Routing — including the
congestion-aware up-port selection the paper assumes as its substrate (§2.1)
— lives here; the switch dataplane and host protocol layers never touch a
link directly.

Node addressing
---------------
* hosts:   ``0 .. num_hosts-1``; host ``h`` hangs off leaf ``h // hosts_per_leaf``.
* switches (global index): leaves ``0 .. L-1``, spines ``L .. L+S-1``.

Port numbering (matches the children-bitmap semantics of §4.2)
---------------------------------------------------------------
* leaf ``l``:  port ``p < hosts_per_leaf``  -> host ``l*hosts_per_leaf + p`` (down)
               port ``hosts_per_leaf + s``  -> spine ``s``                  (up)
* spine ``s``: port ``l``                   -> leaf ``l``                   (down)
"""
from __future__ import annotations

import random
from heapq import heappush as _heappush
from typing import Dict, List, Optional

from .engine import EV_LINK_ARRIVE_HOST, EV_LINK_ARRIVE_SWITCH
from .topology import (LINK_DOWN_HORIZON, Link, Topology, pick_min_backlog,
                       register_topology)
from .types import Packet, PacketKind, SimConfig

__all__ = ["FatTree", "Link"]

_K_NOISE = int(PacketKind.NOISE)
_K_RING = int(PacketKind.RING)
_EV_SW = EV_LINK_ARRIVE_SWITCH  # staged-arrival kinds used by the inline tx
_EV_HOST = EV_LINK_ARRIVE_HOST


@register_topology("fat_tree")
class FatTree(Topology):
    """Topology + routing. Switch indices are global (leaves then spines)."""

    def __init__(self, cfg: SimConfig):
        cfg.validate()
        self.cfg = cfg
        self.L = cfg.num_leaves
        self.S = cfg.num_spines
        self.H = cfg.hosts_per_leaf
        self.num_hosts = cfg.num_hosts
        self.num_switches = self.L + self.S
        bpn, lat, cap = cfg.bytes_per_ns, cfg.hop_latency_ns, cfg.buffer_bytes

        def mk() -> Link:
            return Link(bpn, lat, cap)

        # host <-> leaf
        self.host_up = [mk() for _ in range(cfg.num_hosts)]    # host -> leaf
        self.host_down = [mk() for _ in range(cfg.num_hosts)]  # leaf -> host
        # leaf <-> spine (full bipartite)
        self.leaf_up = [[mk() for _ in range(self.S)] for _ in range(self.L)]
        self.leaf_down = [[mk() for _ in range(self.S)] for _ in range(self.L)]
        # flowlet tables: (leaf, flow key) -> committed spine [37]
        self.flowlets: dict = {}
        # hot-path LB/routing state, resolved once per fabric build
        # (ARCHITECTURE.md §Performance): policy strings, the adaptive
        # threshold in bytes, and per-host leaf/port maps as flat tuples.
        self._lb = str(cfg.lb)
        self._noise_lb = str(cfg.noise_lb)
        self._thr = cfg.lb_threshold * cfg.buffer_bytes
        self._flowlet = cfg.flowlet_lb
        self._path_aware = cfg.path_aware_lb
        self._dp = cfg.drop_prob
        # policy fast-path codes: 0 = ecmp (hash default, no metric),
        # 1 = adaptive (default while under threshold), 2 = full scan
        _codes = {"ecmp": 0, "adaptive": 1}
        self._lb_code = _codes.get(self._lb, 2)
        self._noise_code = _codes.get(self._noise_lb, 2)
        self._host_leaf = tuple(h // self.H for h in range(cfg.num_hosts))
        # bound in bind() (facade wiring): the engine (for inline event
        # pushes), its RNG draw, and the packet pool
        self._engine = None
        self._rngr = None

    def bind(self, sim) -> None:
        super().bind(sim)
        self._engine = sim.engine
        self._rngr = sim.rng.random

    # ---- helpers -----------------------------------------------------------
    @classmethod
    def config_num_switches(cls, cfg: SimConfig) -> int:
        return cfg.num_leaves + cfg.num_spines

    def leaf_of(self, host: int) -> int:
        return host // self.H

    def is_leaf(self, sw: int) -> bool:
        return sw < self.L

    def spine_index(self, sw: int) -> int:
        return sw - self.L

    def is_up_port(self, sw: int, port: int) -> bool:
        return self.is_leaf(sw) and port >= self.H

    # Port maps (see module docstring).
    def leaf_port_of_host(self, host: int) -> int:
        return host % self.H

    def leaf_port_of_spine(self, spine: int) -> int:
        return self.H + spine

    def spine_port_of_leaf(self, leaf: int) -> int:
        return leaf

    # ---- LB: pick the up-port (spine) for a packet leaving ``leaf`` --------
    def pick_spine(self, leaf: int, now: float, flow_hash: int,
                   rng: Optional[random.Random] = None,
                   dest_leaf: int = -1, policy: Optional[str] = None) -> int:
        """Congestion-aware up-port selection (§2.1, §5.2).

        The paper's premise is an existing congestion-aware load-balancing
        substrate (CONGA [37], DRILL [41], ...). CONGA-style schemes measure
        *path* congestion, so when the destination leaf is known the metric
        is the up-link backlog **plus** the spine->dest-leaf down-link
        backlog (the ``remote`` leg); purely local schemes would leave
        destination-side hotspots invisible. The policy arithmetic itself is
        the shared :func:`~.topology.pick_min_backlog`, so the two fabrics
        can never drift apart.
        """
        default = flow_hash % self.S
        lb = str(policy) if policy is not None else self._lb
        remote = self.leaf_down[dest_leaf] \
            if self._path_aware and dest_leaf >= 0 and dest_leaf != leaf \
            else None
        return pick_min_backlog(self.leaf_up[leaf], default, now, lb,
                                self._thr, remote)

    # NOTE: flowlet-sticky decisions live inline in forward_toward_host (the
    # only consumer), keyed by the flat (leaf, kind, src, dest, chunk/step)
    # shape — any second entry point must share that key shape or the same
    # flowlet could commit to two different spines.

    # ---- transmit ----------------------------------------------------------
    # The hot sends below deliberately replicate the Topology.tx_to_switch /
    # tx_to_host sequence inline (serialize -> iid drop -> schedule arrival,
    # dropped linear packets recycled) with the engine pre-bound — this is
    # the innermost packet loop of the whole repo. The canonical semantics
    # live in Topology.tx_*; the golden replays pin the equivalence.
    def send_from_host(self, sim, host: int, pkt: Packet) -> float:
        link = self.host_up[host]
        eng = self._engine
        now = eng.now
        bu = link.busy_until
        if bu >= LINK_DOWN_HORIZON:  # poisoned by a fault (topology.py)
            sim.faults.on_tx_down(link, pkt, self._host_leaf[host])
            return now + pkt.size_bytes / link.bytes_per_ns
        start = bu if bu > now else now
        link.busy_until = busy = start + pkt.size_bytes / link.bytes_per_ns
        link.bytes_sent += pkt.size_bytes
        tp = self._transport
        if tp is not None:
            tp.on_egress(link, pkt, busy - now)
        if self._dp and self._rngr() < self._dp:
            sim.dropped += 1
            if not pkt.multicast:
                self._pool_free(pkt)
        else:
            eng._seq = seq = eng._seq + 1
            arrival = busy + link.latency_ns
            q = link.inflight
            q.append((arrival, seq, pkt))
            if len(q) == 1:
                _heappush(eng.heap, (arrival, seq, _EV_SW,
                                     self._host_leaf[host], host % self.H,
                                     link))
        return busy

    def _send_leaf_up(self, sim, leaf: int, spine: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.leaf_up[leaf][spine], pkt, self.L + spine,
                          self.spine_port_of_leaf(leaf))

    def _send_spine_down(self, sim, spine: int, leaf: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.leaf_down[leaf][spine], pkt, leaf,
                          self.leaf_port_of_spine(spine))

    def _send_leaf_to_host(self, sim, host: int, pkt: Packet) -> None:
        self.tx_to_host(sim, self.host_down[host], pkt, host)

    # ---- routing -----------------------------------------------------------
    def forward_toward_host(self, sim, sw: int, pkt: Packet) -> None:
        dest = pkt.dest
        H = self.H
        dleaf = dest // H
        eng = self._engine
        size = pkt.size_bytes
        if sw >= self.L:                         # spine: one hop down
            link = self.leaf_down[dleaf][sw - self.L]
            now = eng.now
            bu = link.busy_until
            if bu >= LINK_DOWN_HORIZON:
                sim.faults.on_tx_down(link, pkt, dleaf)
                return
            start = bu if bu > now else now
            link.busy_until = busy = start + size / link.bytes_per_ns
            link.bytes_sent += size
            tp = self._transport
            if tp is not None:
                tp.on_egress(link, pkt, busy - now)
            if self._dp and self._rngr() < self._dp:
                sim.dropped += 1
                if not pkt.multicast:
                    self._pool_free(pkt)
            else:
                eng._seq = seq = eng._seq + 1
                arrival = busy + link.latency_ns
                q = link.inflight
                q.append((arrival, seq, pkt))
                if len(q) == 1:
                    _heappush(eng.heap, (arrival, seq, _EV_SW, dleaf,
                                         H + sw - self.L, link))
            return
        if dleaf == sw:                          # leaf: deliver to the host
            link = self.host_down[dest]
            now = eng.now
            bu = link.busy_until
            if bu >= LINK_DOWN_HORIZON:
                sim.faults.on_tx_down(link, pkt, dest)
                return
            start = bu if bu > now else now
            link.busy_until = busy = start + size / link.bytes_per_ns
            link.bytes_sent += size
            tp = self._transport
            if tp is not None:
                tp.on_egress(link, pkt, busy - now)
            if self._dp and self._rngr() < self._dp:
                sim.dropped += 1
                if not pkt.multicast:
                    self._pool_free(pkt)
            else:
                eng._seq = seq = eng._seq + 1
                arrival = busy + link.latency_ns
                q = link.inflight
                q.append((arrival, seq, pkt))
                if len(q) == 1:
                    _heappush(eng.heap, (arrival, seq, _EV_HOST, dest, 0,
                                         link))
            return
        # Default up-port: Topology.flow_hash — same-block partials converge
        # on one spine, blocks spread, retransmitted generations re-route
        # (§3.1.3/§3.3). Background congestion rides its own policy (§2.1);
        # with flowlet_lb the seed monolith dropped that policy — passing it
        # is an intentional (non-golden-covered) behaviour fix that keeps
        # noise_lb semantics identical across fabrics.
        kind = pkt.kind
        if kind == _K_NOISE:
            fh = hash(dest)
            policy = self._noise_lb
            code = self._noise_code
        elif kind == _K_RING:
            fh = hash((dest, pkt.step))
            policy = self._lb
            code = self._lb_code
        else:
            fh = hash((dest, pkt.id))
            policy = self._lb
            code = self._lb_code
        if self._flowlet and (kind == _K_NOISE or kind == _K_RING):
            # point-to-point traffic moves at flowlet granularity [37].
            # Flat inline form of (sw, flowlet_key(pkt)) — this fabric's
            # flowlet cache is only ever keyed here, so the shape is private.
            key = (sw, kind, pkt.src, dest,
                   pkt.chunk if kind == _K_NOISE else pkt.step)
            spine = self.flowlets.get(key)
            if spine is None or \
                    self.leaf_up[sw][spine].busy_until >= LINK_DOWN_HORIZON:
                # no commitment yet, or the committed spine died mid-run:
                # (re-)pick and (re-)pin
                remote = self.leaf_down[dleaf] \
                    if self._path_aware and dleaf >= 0 else None
                spine = pick_min_backlog(self.leaf_up[sw], fh % self.S,
                                         eng.now, policy, self._thr, remote)
                self.flowlets[key] = spine
        elif code == 0:  # ecmp: the hash default, no metric
            spine = fh % self.S
            if self.leaf_up[sw][spine].busy_until >= LINK_DOWN_HORIZON:
                # dead ECMP member: the backlog scan sees the poisoned link
                # as infinite backlog and routes around it
                spine = pick_min_backlog(self.leaf_up[sw], spine, eng.now,
                                         policy, self._thr, None)
        else:
            # inline the pick_min_backlog fast path: adaptive stays on the
            # default while its (per-leg clamped) path backlog is under the
            # threshold; anything else falls through to the full scan
            spine = -1
            links = self.leaf_up[sw]
            default = fh % self.S
            now = eng.now
            remote = self.leaf_down[dleaf] \
                if self._path_aware and dleaf >= 0 else None
            if code == 1:
                l0 = links[default]
                m = (l0.busy_until - now) * l0.bytes_per_ns
                if m < 0.0:
                    m = 0.0
                if remote is not None:
                    r0 = remote[default]
                    rb = (r0.busy_until - now) * r0.bytes_per_ns
                    if rb > 0.0:
                        m += rb
                if m <= self._thr:
                    spine = default
            if spine < 0:
                spine = pick_min_backlog(links, default, now, policy,
                                         self._thr, remote)
        link = self.leaf_up[sw][spine]
        now = eng.now
        bu = link.busy_until
        if bu >= LINK_DOWN_HORIZON:
            # every LB path above avoids dead members where an alternative
            # exists; reaching here means the whole group is down
            sim.faults.on_tx_down(link, pkt, self.L + spine)
            return
        start = bu if bu > now else now
        link.busy_until = busy = start + size / link.bytes_per_ns
        link.bytes_sent += size
        tp = self._transport
        if tp is not None:
            tp.on_egress(link, pkt, busy - now)
        if self._dp and self._rngr() < self._dp:
            sim.dropped += 1
            if not pkt.multicast:
                self._pool_free(pkt)
        else:
            eng._seq = seq = eng._seq + 1
            arrival = busy + link.latency_ns
            q = link.inflight
            q.append((arrival, seq, pkt))
            if len(q) == 1:
                _heappush(eng.heap, (arrival, seq, _EV_SW, self.L + spine,
                                     sw, link))

    def forward_toward_switch(self, sim, sw: int, pkt: Packet) -> None:
        target = pkt.dest_switch
        if self.is_leaf(sw):
            if self.is_leaf(target):
                fh = hash(target)
                spine = self.pick_spine(sw, sim.now, fh, sim.rng,
                                        dest_leaf=target)
                self._send_leaf_up(sim, sw, spine, pkt)
            else:
                self._send_leaf_up(sim, sw, self.spine_index(target), pkt)
        else:
            if self.is_leaf(target):
                self._send_spine_down(sim, self.spine_index(sw), target, pkt)
            else:
                # spine -> spine requires bouncing off any leaf; route via leaf 0
                self._send_spine_down(sim, self.spine_index(sw), 0, pkt)

    def out_port_send(self, sim, sw: int, port: int, pkt: Packet) -> None:
        # broadcast fan-out hot path: resolve the link, then the same inline
        # tx sequence as above (see the transmit section note)
        H = self.H
        if sw < self.L:
            if port < H:
                host = sw * H + port
                link = self.host_down[host]
                ev_kind, a, b = _EV_HOST, host, 0
            else:
                spine = port - H
                link = self.leaf_up[sw][spine]
                ev_kind, a, b = _EV_SW, self.L + spine, sw
        else:
            link = self.leaf_down[port][sw - self.L]
            ev_kind, a, b = _EV_SW, port, H + sw - self.L
        eng = self._engine
        now = eng.now
        bu = link.busy_until
        size = pkt.size_bytes
        if bu >= LINK_DOWN_HORIZON:
            sim.faults.on_tx_down(link, pkt, a)
            return
        start = bu if bu > now else now
        link.busy_until = busy = start + size / link.bytes_per_ns
        link.bytes_sent += size
        tp = self._transport
        if tp is not None:
            tp.on_egress(link, pkt, busy - now)
        if self._dp and self._rngr() < self._dp:
            sim.dropped += 1
            if not pkt.multicast:
                self._pool_free(pkt)
        else:
            eng._seq = seq = eng._seq + 1
            arrival = busy + link.latency_ns
            q = link.inflight
            q.append((arrival, seq, pkt))
            if len(q) == 1:
                _heappush(eng.heap, (arrival, seq, ev_kind, a, b, link))

    # ---- static-tree support ----------------------------------------------
    def root_candidates(self) -> List[int]:
        return [self.L + s for s in range(self.S)]

    def static_expected(self, parts: List[int], root: int) -> Dict[int, int]:
        plan: Dict[int, int] = {}
        for h in parts:
            leaf = self.leaf_of(h)
            plan[leaf] = plan.get(leaf, 0) + 1
        plan[root] = len(plan)
        return plan

    def static_send_up(self, sim, sw: int, root: int, pkt: Packet) -> None:
        self._send_leaf_up(sim, sw, self.spine_index(root), pkt)

    # ---- fault-injection support --------------------------------------------
    def links_into(self, sw: int) -> List[Link]:
        if sw < self.L:
            return ([self.host_up[h]
                     for h in range(sw * self.H, (sw + 1) * self.H)]
                    + [self.leaf_down[sw][s] for s in range(self.S)])
        s = sw - self.L
        return [self.leaf_up[leaf][s] for leaf in range(self.L)]

    # ---- utilization accounting ---------------------------------------------
    def all_links(self) -> List[Link]:
        out: List[Link] = []
        out.extend(self.host_up)
        out.extend(self.host_down)
        for row in self.leaf_up:
            out.extend(row)
        for row in self.leaf_down:
            out.extend(row)
        return out

    def link_names(self) -> List[str]:
        out = [f"host{h}->leaf{h // self.H}" for h in range(self.num_hosts)]
        out += [f"leaf{h // self.H}->host{h}" for h in range(self.num_hosts)]
        for leaf in range(self.L):
            out += [f"leaf{leaf}->spine{s}" for s in range(self.S)]
        for leaf in range(self.L):
            out += [f"spine{s}->leaf{leaf}" for s in range(self.S)]
        return out
