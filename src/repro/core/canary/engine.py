"""Discrete-event engine: clock, heap and event dispatch.

This is the bottom layer of the simulator stack (see ``ARCHITECTURE.md``):
it knows nothing about networks, switches or collectives — it orders
``(time, seq, kind, a, b, c)`` tuples and hands them to per-kind handlers.
The ``seq`` tiebreaker makes simultaneous events FIFO in push order, which is
what makes whole runs bit-reproducible for the golden-replay tests.

Hot-path notes (see ARCHITECTURE.md §Performance):

* The dispatch loop takes a *pre-resolved handler table* — a sequence
  indexed by event kind, built once per run — and keeps the heaps, the pop
  function and the event counter in locals. ``events`` is written back on
  every exit path so external observers (``SimResult.events``, the golden
  contract) always see the true dispatch count.
* **Split heaps.** Timer-class events (descriptor timers, retransmission
  checks) are pushed far into the future and mostly never fire — they used
  to dominate heap volume, making every pop sift through tens of thousands
  of dormant entries. ``push_timer`` routes them to a second heap; the loop
  pops the global minimum of both tops. Because ``seq`` is a single shared
  counter and ``(t, seq)`` is a total order, the dispatch sequence is
  bit-identical to the single-heap engine — the split only changes *where*
  an entry waits, never *when* it pops.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Sequence, Tuple

# Event kinds (heap entries are (time, seq, kind, a, b, c) tuples).
EV_ARRIVE_SWITCH = 0  # a=global switch idx, b=in port, c=packet
EV_ARRIVE_HOST = 1    # a=host, c=packet
EV_TIMER = 2          # a=switch, b=timer_seq, c=packet id
EV_PUMP = 3           # a=host
EV_RETX = 4           # a=host, c=(app, block, gen)
EV_FAIL_SWITCH = 5    # a=switch
EV_LEADER_DONE = 6    # a=leader host, c=(app, block, total)
EV_JOB_ARRIVE = 7     # a=app (open-loop job arrival; fleet subsystem)
# Transport-policy events (repro.core.transport). Under the default
# ``transport="none"`` policy none of these is ever pushed, so the golden
# replays see the exact pre-transport event stream. PFC pause/resume are a
# pair: the pause lands one propagation delay after the egress queue crosses
# its high watermark, the resume at the (closed-form) time the queue drains
# to the low watermark.
EV_PFC_PAUSE = 8      # a=host (sender being paused)
EV_PFC_RESUME = 9     # a=host, c=scheduled resume time (supersede guard)
EV_RATE_TIMER = 10    # a=host, c=timer epoch (DCQCN rate-increase timer)
EV_GBN_TIMER = 11     # a=host, c=("p"|"b", flow key, epoch)
# Staged link arrivals (ARCHITECTURE.md §Performance): ``c`` is a *staging
# source* (a Link) whose ``inflight`` deque holds ``(t, seq, packet)``
# entries in FIFO order — one heap entry per busy link instead of one per
# in-flight packet. The loop pops the head packet, re-arms the link's next
# head, and dispatches the same handlers as kinds 0/1 with ``c = packet``.
# These must stay a CONTIGUOUS band above the protocol kinds: the run loop
# detects them with a ``kind >= EV_LINK_ARRIVE_SWITCH`` /
# ``kind <= EV_LINK_ARRIVE_HOST`` compare pair. Renumbering kinds is
# golden-safe — heap order is (t, seq) only; kind never orders events.
EV_LINK_ARRIVE_SWITCH = 12  # a=global switch idx, b=in port, c=Link
EV_LINK_ARRIVE_HOST = 13    # a=host, c=Link
# Telemetry probe (repro.core.telemetry): a periodic observation-only sample
# tick. Dispatched by the loop's third branch WITHOUT incrementing the
# ``events`` counter — the counter is a golden-pinned field, and probes are
# pure observation, so telemetry-on runs report the identical dispatch count
# as telemetry-off runs. Never pushed unless telemetry is enabled.
EV_TELEMETRY_PROBE = 14     # c=Telemetry hub (re-arms itself)
# Fault-injection events (repro.core.faults): scheduled mid-run failures and
# recoveries. Dispatched by the loop's third branch WITHOUT incrementing the
# ``events`` counter — like telemetry probes they are orchestration, not
# protocol traffic, and the counter is a golden-pinned field. Never pushed
# unless ``SimConfig.faults`` is non-empty, so fault-free runs (including
# every golden) see the identical dispatch stream.
EV_FAULT = 15               # a=fault index, c=FaultSchedule
EV_HEAL = 16                # a=fault index, c=FaultSchedule
N_EVENT_KINDS = 17

Handler = Callable[[int, int, object], None]

_Entry = Tuple[float, int, int, int, int, object]


class EventLoop:
    """A monotonic event heap with a stable FIFO tiebreak.

    ``stop`` replaces a per-event ``done()`` callback: the owner sets it
    (synchronously, from inside a handler) when the termination condition
    becomes true, and the loop checks it before every dispatch — the same
    timing a polled predicate had, without a Python call per event.
    """

    __slots__ = ("heap", "timer_heap", "now", "events", "stop", "_seq")

    def __init__(self) -> None:
        self.heap: List[_Entry] = []
        self.timer_heap: List[_Entry] = []
        self.now = 0.0
        self.events = 0
        self.stop = False
        self._seq = 0

    def push(self, t: float, kind: int, a: int, b: int, c: object,
             _heappush=heapq.heappush) -> None:
        self._seq = seq = self._seq + 1
        _heappush(self.heap, (t, seq, kind, a, b, c))

    def push_timer(self, t: float, kind: int, a: int, b: int, c: object,
                   _heappush=heapq.heappush) -> None:
        """Like :meth:`push`, but onto the timer heap — for far-future,
        usually-dormant events (EV_TIMER, EV_RETX). Ordering against ``push``
        events is preserved exactly (shared ``seq``; the run loop pops the
        global minimum of both heaps)."""
        self._seq = seq = self._seq + 1
        _heappush(self.timer_heap, (t, seq, kind, a, b, c))

    def run(self, handlers: Sequence[Handler], max_events: int,
            _heappop=heapq.heappop) -> None:
        """Drain both heaps, dispatching by event kind, until ``stop`` is
        set or both heaps are empty.

        ``handlers`` is a pre-resolved table indexed by event kind (a list or
        tuple of length :data:`N_EVENT_KINDS`). ``max_events`` is a livelock
        safety valve, counted over the whole loop's lifetime (the counter
        survives across ``run`` calls); the budget is checked *before* each
        dispatch, so exactly ``max_events`` events are ever handled.
        """
        handlers = tuple(handlers)
        heap = self.heap
        timers = self.timer_heap
        events = self.events
        _heappush = heapq.heappush
        _LINK = EV_LINK_ARRIVE_SWITCH  # loop-local: no global load per event
        _LINK_HOST = EV_LINK_ARRIVE_HOST
        try:
            while True:
                if heap:
                    src = timers if timers and timers[0] < heap[0] else heap
                elif timers:
                    src = timers
                else:
                    break
                if self.stop:
                    break
                if events >= max_events:
                    raise RuntimeError("event budget exceeded — livelock?")
                t, _, kind, a, b, c = _heappop(src)
                self.now = t
                if kind < _LINK:
                    events += 1
                    handlers[kind](a, b, c)
                elif kind <= _LINK_HOST:
                    events += 1
                    # staged link arrival: deliver the FIFO head, re-arm the
                    # link's next head (its (t, seq) were assigned at
                    # transmit time, so global ordering is preserved)
                    q = c.inflight
                    entry = q.popleft()
                    if q:
                        head = q[0]
                        _heappush(heap, (head[0], head[1], kind, a, b, c))
                    p = entry[2]
                    # ``None`` marks a head neutralized by a link-down fault
                    # drain (repro.core.faults): the slot stays in the deque
                    # because it owns this heap entry, but carries no packet.
                    if p is not None:
                        handlers[kind](a, b, p)
                else:
                    # EV_TELEMETRY_PROBE / EV_FAULT / EV_HEAL: observation
                    # and orchestration, excluded from the golden ``events``
                    # count and the livelock budget
                    handlers[kind](a, b, c)
        finally:
            self.events = events
