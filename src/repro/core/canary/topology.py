"""Topology layer: links, the :class:`Topology` protocol, and the registry.

The simulator core is topology-agnostic. A concrete topology owns every
:class:`Link` in the fabric and implements routing as "send this packet one
step and schedule its arrival" operations against the simulator facade (which
exposes ``now``, ``rng``, ``maybe_drop`` and the two arrival schedulers).

Implementations:

* ``fat_tree``   — the paper's two-level full-bisection leaf/spine fabric
                   (:class:`repro.core.canary.network.FatTree`).
* ``three_tier`` — a folded-Clos leaf/agg/core fabric
                   (:class:`ThreeTierFatTree`, below) that exercises the
                   load-balancing policies on 4-hop paths.

Registering a new topology::

    @register_topology("my_fabric")
    class MyFabric(Topology):
        ...

and select it with ``SimConfig(topology="my_fabric")`` — no engine, switch or
host-protocol changes needed.
"""
from __future__ import annotations

from collections import deque
from heapq import heappush as _heappush
from typing import Dict, List, Optional, Type

from .engine import EV_LINK_ARRIVE_HOST, EV_LINK_ARRIVE_SWITCH
from .types import Packet, PacketKind, SimConfig

# Dead-link sentinel (repro.core.faults): a downed link is "poisoned" by
# setting ``busy_until`` to this horizon. Everything falls out of the one
# representation: backlog-metric LB policies see an effectively infinite
# queue and route around it, the ECMP/hash fast paths check the already
# loaded ``busy_until`` against the horizon (one float compare, no extra
# memory traffic on fault-free runs), and the shared tx helpers turn sends
# on a poisoned link into charged drops. Finite (not ``inf``) so telemetry
# backlog series stay plottable. Healing simply rewinds ``busy_until`` to
# ``now`` — pre-fault backlog was already drained or dropped.
LINK_DOWN_HORIZON = 1e15


class Link:
    """A unidirectional link with serialization, propagation and a FIFO queue.

    A link keeps ``busy_until`` — the time its output is committed through —
    and the backlog at time ``t`` is ``(busy_until - t) * bytes_per_ns``. This
    gives exact serialization + queueing delay for FIFO ports without per-byte
    events, and is what the adaptive load-balancing policy (§5.2: "up port
    with the smallest number of enqueued bytes") inspects.

    ``inflight`` is the staged-arrival FIFO (ARCHITECTURE.md §Performance):
    ``(arrival_t, seq, packet)`` entries in transmit order. Only the head has
    an event in the engine heap (kind ``EV_LINK_ARRIVE_*``, ``c`` = this
    link); the engine re-arms the next head when it pops. Per-link arrivals
    are monotone in ``(t, seq)``, so staging changes where an entry *waits*,
    never its dispatch order — the golden replays pin this.
    """

    __slots__ = ("busy_until", "bytes_sent", "bytes_per_ns", "latency_ns",
                 "capacity", "inflight")

    def __init__(self, bytes_per_ns: float, latency_ns: float, capacity: int):
        self.busy_until = 0.0
        self.bytes_sent = 0
        self.bytes_per_ns = bytes_per_ns
        self.latency_ns = latency_ns
        self.capacity = capacity
        self.inflight = deque()

    def backlog_bytes(self, now: float) -> float:
        b = (self.busy_until - now) * self.bytes_per_ns
        return b if b > 0.0 else 0.0

    def occupancy(self, now: float) -> float:
        return self.backlog_bytes(now) / self.capacity

    def transmit(self, now: float, size_bytes: int) -> float:
        """Enqueue ``size_bytes`` at ``now``; return arrival time at the far end."""
        start = self.busy_until if self.busy_until > now else now
        self.busy_until = start + size_bytes / self.bytes_per_ns
        self.bytes_sent += size_bytes
        return self.busy_until + self.latency_ns


class Topology:
    """Routing/fabric protocol the simulator layers program against.

    ``sim`` in every signature is the :class:`~.simulator.Simulator` facade;
    topologies use only its ``engine`` (clock + ``push`` scheduler), its
    ``rng``/``cfg`` state, the drop state (``_drop_prob``/``_rng_random``,
    the inlined form of ``maybe_drop()``), the packet ``pool`` and its
    ``dropped`` counter. Stubs driving a topology directly (tests) must
    provide those attributes.
    """

    name: str = ""

    # --- identity ----------------------------------------------------------
    cfg: SimConfig
    L: int                 # number of leaf (host-facing) switches
    num_switches: int
    num_hosts: int

    # Pre-resolved hot-path binding (None until :meth:`bind`): topologies
    # built standalone (tests, shape checks) stay usable for routing/shape
    # queries; driving ``tx_*`` requires a bound facade (or stub).
    _pool_free = None
    # Transport-policy egress hook (repro.core.transport): every tx site
    # calls ``_transport.on_egress(link, pkt, qdelay_ns)`` after serializing
    # — the ECN-marking / PFC-watermark observation point. None (the default
    # ``transport="none"`` policy, and stub sims without the attribute)
    # costs one identity check per send and nothing else.
    _transport = None
    # Telemetry hub (repro.core.telemetry): only consulted inside the rare
    # wire-drop branch, so the common send path pays nothing even when on.
    _telemetry = None

    def bind(self, sim) -> None:
        """Pre-resolve per-run callables (ARCHITECTURE.md §Performance).
        Called once by the :class:`~.simulator.Simulator` facade after all
        layers exist. Subclasses extend this to bind their own hot-path
        state (the engine for inline pushes, the RNG draw)."""
        self._pool_free = sim.pool.free
        self._transport = getattr(sim, "transport", None)
        self._telemetry = getattr(sim, "telemetry", None)

    @classmethod
    def config_num_switches(cls, cfg: SimConfig) -> int:
        """Switch count implied by ``cfg`` without building the fabric.
        Override with a closed-form count; the default builds an instance
        (correct for any topology, but allocates links)."""
        return cls(cfg).num_switches

    def leaf_of(self, host: int) -> int:
        raise NotImplementedError

    def is_leaf(self, sw: int) -> bool:
        raise NotImplementedError

    def is_up_port(self, sw: int, port: int) -> bool:
        """True when ``port`` points away from the hosts (toward the core)."""
        raise NotImplementedError

    # --- flow identity (shared by all fabrics so they never diverge) -------
    def flow_hash(self, pkt: Packet) -> int:
        """Default up-path hash. Same-block partials share the hash and so
        converge on one up-path (maximizing aggregation); different blocks
        spread ("each block in a different root", §3.1.3); a retransmitted
        generation gets a different id and hence a different default path
        (§3.3). Background noise hashes on destination only."""
        kind = pkt.kind
        if kind == PacketKind.NOISE:
            return hash(pkt.dest)
        if kind == PacketKind.RING:
            return hash((pkt.dest, pkt.step))
        return hash((pkt.dest, pkt.id))

    @staticmethod
    def flowlet_key(pkt: Packet) -> tuple:
        """Identity of a point-to-point flowlet [37] (NOISE/RING traffic)."""
        return (int(pkt.kind), pkt.src, pkt.dest,
                pkt.chunk if pkt.kind == PacketKind.NOISE else pkt.step)

    # --- shared transmit + drop accounting ---------------------------------
    # Every link send follows the same sequence: serialize on the link (bytes
    # count even for packets dropped in flight), roll the iid drop, schedule
    # the arrival. Topologies must route through these two helpers so drop
    # semantics can never diverge between fabrics. A packet dropped in flight
    # is at end-of-life: linear (non-multicast) ones go back to the pool.
    def tx_to_switch(self, sim, link: Link, pkt: Packet, sw: int,
                     port: int) -> float:
        eng = sim.engine
        now = eng.now
        bu = link.busy_until
        if bu >= LINK_DOWN_HORIZON:
            # poisoned by a fault schedule (only ever true when sim.faults
            # exists): the send is a charged drop, the link stays poisoned
            sim.faults.on_tx_down(link, pkt, sw)
            return now + pkt.size_bytes / link.bytes_per_ns
        start = bu if bu > now else now
        link.busy_until = busy = start + pkt.size_bytes / link.bytes_per_ns
        link.bytes_sent += pkt.size_bytes
        tp = self._transport
        if tp is not None:
            tp.on_egress(link, pkt, busy - now)
        if sim._drop_prob and sim._rng_random() < sim._drop_prob:
            sim.dropped += 1
            tel = self._telemetry
            if tel is not None:
                tel.on_drop("wire", sw)
            if not pkt.multicast:
                sim.pool.free(pkt)
        else:
            eng._seq = seq = eng._seq + 1
            arrival = busy + link.latency_ns
            q = link.inflight
            q.append((arrival, seq, pkt))
            if len(q) == 1:
                _heappush(eng.heap, (arrival, seq, EV_LINK_ARRIVE_SWITCH,
                                     sw, port, link))
        return busy

    def tx_to_host(self, sim, link: Link, pkt: Packet, host: int) -> float:
        eng = sim.engine
        now = eng.now
        bu = link.busy_until
        if bu >= LINK_DOWN_HORIZON:
            sim.faults.on_tx_down(link, pkt, host)
            return now + pkt.size_bytes / link.bytes_per_ns
        start = bu if bu > now else now
        link.busy_until = busy = start + pkt.size_bytes / link.bytes_per_ns
        link.bytes_sent += pkt.size_bytes
        tp = self._transport
        if tp is not None:
            tp.on_egress(link, pkt, busy - now)
        if sim._drop_prob and sim._rng_random() < sim._drop_prob:
            sim.dropped += 1
            tel = self._telemetry
            if tel is not None:
                tel.on_drop("wire", host)
            if not pkt.multicast:
                sim.pool.free(pkt)
        else:
            eng._seq = seq = eng._seq + 1
            arrival = busy + link.latency_ns
            q = link.inflight
            q.append((arrival, seq, pkt))
            if len(q) == 1:
                _heappush(eng.heap, (arrival, seq, EV_LINK_ARRIVE_HOST,
                                     host, 0, link))
        return busy

    # --- data movement -----------------------------------------------------
    def send_from_host(self, sim, host: int, pkt: Packet) -> float:
        """Transmit on the host NIC; returns the time the NIC frees up."""
        raise NotImplementedError

    def forward_toward_host(self, sim, sw: int, pkt: Packet) -> None:
        """One routing step of a host-destined packet (LB happens here)."""
        raise NotImplementedError

    def forward_toward_switch(self, sim, sw: int, pkt: Packet) -> None:
        """One routing step of a switch-destined (RESTORE) packet."""
        raise NotImplementedError

    def out_port_send(self, sim, sw: int, port: int, pkt: Packet) -> None:
        """Send out an explicit port — broadcast fan-out over recorded children."""
        raise NotImplementedError

    # --- static-tree support ------------------------------------------------
    def root_candidates(self) -> List[int]:
        """Global switch ids eligible as static-tree roots."""
        raise NotImplementedError

    def static_expected(self, parts: List[int], root: int) -> Dict[int, int]:
        """Per-switch child count the static tree rooted at ``root`` waits for."""
        raise NotImplementedError

    def static_send_up(self, sim, sw: int, root: int, pkt: Packet) -> None:
        """Forward a fully-aggregated partial one level toward ``root``."""
        raise NotImplementedError

    # --- fault-injection support -------------------------------------------
    def links_into(self, sw: int) -> List[Link]:
        """Every link whose far end is switch ``sw`` — what a switch-crash
        fault poisons so traffic stops being *offered* to a dead switch
        (packets already in flight still arrive and drop at the failed-switch
        check). Default: no structural knowledge, nothing to poison — crash
        faults on a plug-in fabric then only flush descriptors."""
        return []

    # --- accounting ---------------------------------------------------------
    def all_links(self) -> List[Link]:
        raise NotImplementedError

    def link_names(self) -> List[str]:
        """Human-readable names for ``all_links()``, index-aligned — the
        telemetry hotspot report renders these instead of bare indices.
        Fabrics override with structural names (``leaf3->spine7``); this
        fallback keeps plug-in topologies working unchanged."""
        return [f"link/{i}" for i in range(len(self.all_links()))]

    def utilizations(self, duration_ns: float) -> List[float]:
        if duration_ns <= 0:
            return [0.0 for _ in self.all_links()]
        denom = duration_ns * self.cfg.bytes_per_ns
        return [min(1.0, l.bytes_sent / denom) for l in self.all_links()]


TOPOLOGIES: Dict[str, Type[Topology]] = {}


def register_topology(name: str):
    """Class decorator: make a :class:`Topology` selectable via ``SimConfig``."""

    def deco(cls: Type[Topology]) -> Type[Topology]:
        cls.name = name
        TOPOLOGIES[name] = cls
        return cls

    return deco


def make_topology(cfg: SimConfig) -> Topology:
    try:
        cls = TOPOLOGIES[cfg.topology]
    except KeyError:
        raise ValueError(f"unknown topology {cfg.topology!r}; "
                         f"registered: {sorted(TOPOLOGIES)}") from None
    return cls(cfg)


def pick_min_backlog(links: List[Link], default: int, now: float,
                     policy: str, threshold_bytes: float,
                     remote: Optional[List[Link]] = None) -> int:
    """Generic congestion-aware up-port choice over a candidate link list.

    Mirrors the 2-level ``FatTree.pick_spine`` semantics: ``ecmp`` sticks to
    the hash default; ``adaptive`` keeps the default until its backlog crosses
    the threshold; otherwise (or ``per_packet``) take the least-backlogged
    candidate, ties broken toward the default for determinism. When ``remote``
    is given (one known downstream link per candidate), its backlog joins the
    metric — the CONGA-style path-congestion measure (§2.1).

    Hot path: the metric is computed inline (no per-call closure) and the
    arithmetic is kept bit-identical to ``Link.backlog_bytes`` — backlog is
    ``max(0, busy_until - now) * bytes_per_ns`` per leg, clamped *per link*
    before summing, so the golden replays cannot drift.
    """
    if policy == "ecmp":
        if links[default].busy_until < LINK_DOWN_HORIZON:
            return default
        # hashed member is dead: fall through to the backlog scan, which
        # sees the poisoned link as infinite backlog — the ECMP-group-member
        # removal real switches perform
    link = links[default]
    b = (link.busy_until - now) * link.bytes_per_ns
    best_b = b if b > 0.0 else 0.0
    if remote is not None:
        rl = remote[default]
        b = (rl.busy_until - now) * rl.bytes_per_ns
        if b > 0.0:
            best_b += b
    if policy == "adaptive" and best_b <= threshold_bytes:
        return default
    best = default
    if remote is None:
        for i, link in enumerate(links):
            b = (link.busy_until - now) * link.bytes_per_ns
            if b < 0.0:
                b = 0.0
            if b < best_b - 1e-9:
                best, best_b = i, b
    else:
        for i, link in enumerate(links):
            b = (link.busy_until - now) * link.bytes_per_ns
            if b < 0.0:
                b = 0.0
            rl = remote[i]
            rb = (rl.busy_until - now) * rl.bytes_per_ns
            if rb > 0.0:
                b += rb
            if b < best_b - 1e-9:
                best, best_b = i, b
    return best


@register_topology("three_tier")
class ThreeTierFatTree(Topology):
    """Three-tier folded Clos: hosts — leaves — pod aggregation — core.

    * ``cfg.num_pods`` pods, each with ``num_leaves / num_pods`` leaves and
      ``cfg.aggs_per_pod`` aggregation switches (full bipartite inside the
      pod); ``cfg.num_cores`` core switches, full bipartite to every
      aggregation switch.
    * Global switch ids: leaves ``[0, L)``, aggs ``[L, L+P*A)``, cores
      ``[L+P*A, L+P*A+C)``.
    * Port maps: leaf — ``[0, H)`` hosts then ``[H, H+A)`` pod aggs;
      agg — ``[0, leaves_per_pod)`` pod leaves then cores; core — one port
      per agg (``pod * A + agg_in_pod``).

    Cross-pod paths are 4 switch hops (leaf→agg→core→agg→leaf), so the
    congestion-aware policies make two up-port decisions per packet — this is
    the topology the LB sensitivity sweeps use. Oversubscription falls out of
    the counts (e.g. 8 leaves/pod vs 2 aggs/pod).
    """

    def __init__(self, cfg: SimConfig):
        cfg.validate()
        self.cfg = cfg
        self.L = cfg.num_leaves
        self.H = cfg.hosts_per_leaf
        self.P = cfg.num_pods
        if self.P <= 0 or self.L % self.P:
            raise ValueError("three_tier needs num_pods > 0 dividing num_leaves")
        self.A = cfg.aggs_per_pod
        self.C = cfg.num_cores
        if self.A <= 0 or self.C <= 0:
            raise ValueError("three_tier needs aggs_per_pod and num_cores > 0")
        self.leaves_per_pod = self.L // self.P
        self.num_hosts = cfg.num_hosts
        self.num_aggs = self.P * self.A
        self.num_switches = self.L + self.num_aggs + self.C
        bpn, lat, cap = cfg.bytes_per_ns, cfg.hop_latency_ns, cfg.buffer_bytes

        def mk() -> Link:
            return Link(bpn, lat, cap)

        self.host_up = [mk() for _ in range(self.num_hosts)]
        self.host_down = [mk() for _ in range(self.num_hosts)]
        # leaf <-> agg, within the pod: indexed [leaf][agg_in_pod]
        self.leaf_up = [[mk() for _ in range(self.A)] for _ in range(self.L)]
        self.leaf_down = [[mk() for _ in range(self.A)] for _ in range(self.L)]
        # agg <-> core, full bipartite: indexed [agg_global_local][core]
        self.agg_up = [[mk() for _ in range(self.C)]
                       for _ in range(self.num_aggs)]
        self.agg_down = [[mk() for _ in range(self.C)]
                         for _ in range(self.num_aggs)]
        self.flowlets: dict = {}
        # hot-path LB state, resolved once (ARCHITECTURE.md §Performance)
        self._lb = str(cfg.lb)
        self._noise_lb = str(cfg.noise_lb)
        self._thr = cfg.lb_threshold * cfg.buffer_bytes
        self._flowlet = cfg.flowlet_lb
        self._path_aware = cfg.path_aware_lb

    # ---- identity ----------------------------------------------------------
    @classmethod
    def config_num_switches(cls, cfg: SimConfig) -> int:
        return (cfg.num_leaves + cfg.num_pods * cfg.aggs_per_pod
                + cfg.num_cores)

    def leaf_of(self, host: int) -> int:
        return host // self.H

    def pod_of_leaf(self, leaf: int) -> int:
        return leaf // self.leaves_per_pod

    def is_leaf(self, sw: int) -> bool:
        return sw < self.L

    def is_agg(self, sw: int) -> bool:
        return self.L <= sw < self.L + self.num_aggs

    def agg_local(self, sw: int) -> int:
        return sw - self.L

    def core_local(self, sw: int) -> int:
        return sw - self.L - self.num_aggs

    def agg_gid(self, pod: int, a: int) -> int:
        return self.L + pod * self.A + a

    def core_gid(self, c: int) -> int:
        return self.L + self.num_aggs + c

    def is_up_port(self, sw: int, port: int) -> bool:
        if self.is_leaf(sw):
            return port >= self.H
        if self.is_agg(sw):
            return port >= self.leaves_per_pod
        return False

    # ---- low-level sends ---------------------------------------------------
    def send_from_host(self, sim, host: int, pkt: Packet) -> float:
        return self.tx_to_switch(sim, self.host_up[host], pkt,
                                 self.leaf_of(host), host % self.H)

    def _send_to_host(self, sim, host: int, pkt: Packet) -> None:
        self.tx_to_host(sim, self.host_down[host], pkt, host)

    def _send_leaf_to_agg(self, sim, leaf: int, a: int, pkt: Packet) -> None:
        pod = self.pod_of_leaf(leaf)
        self.tx_to_switch(sim, self.leaf_up[leaf][a], pkt,
                          self.agg_gid(pod, a), leaf % self.leaves_per_pod)

    def _send_agg_to_leaf(self, sim, agg_l: int, leaf: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.leaf_down[leaf][agg_l % self.A], pkt,
                          leaf, self.H + agg_l % self.A)

    def _send_agg_to_core(self, sim, agg_l: int, c: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.agg_up[agg_l][c], pkt, self.core_gid(c),
                          agg_l)

    def _send_core_to_agg(self, sim, c: int, agg_l: int, pkt: Packet) -> None:
        self.tx_to_switch(sim, self.agg_down[agg_l][c], pkt, self.L + agg_l,
                          self.leaves_per_pod + c)

    # ---- LB decisions ------------------------------------------------------
    def _policy_for(self, pkt: Packet) -> str:
        return self._noise_lb if pkt.kind == PacketKind.NOISE else self._lb

    def _pick(self, sim, sw: int, links: List[Link], default: int,
              pkt: Packet, remote: Optional[List[Link]] = None) -> int:
        """Choose an up-port index among ``links`` (flowlet-sticky for
        point-to-point traffic when ``cfg.flowlet_lb``). ``remote`` carries
        the known downstream leg per candidate for CONGA-style path metrics
        (only passed when ``cfg.path_aware_lb``)."""
        kind = pkt.kind
        policy = self._noise_lb if kind == PacketKind.NOISE else self._lb
        if self._flowlet and (kind == PacketKind.NOISE
                              or kind == PacketKind.RING):
            fkey = (sw,) + self.flowlet_key(pkt)
            cached = self.flowlets.get(fkey)
            if cached is not None:
                if links[cached].busy_until < LINK_DOWN_HORIZON:
                    return cached
                # cached member died mid-run: re-pick and re-pin
            choice = pick_min_backlog(links, default, sim.engine.now, policy,
                                      self._thr, remote)
            self.flowlets[fkey] = choice
            return choice
        return pick_min_backlog(links, default, sim.engine.now, policy,
                                self._thr, remote)

    # ---- routing -----------------------------------------------------------
    def forward_toward_host(self, sim, sw: int, pkt: Packet) -> None:
        # flow_hash is computed lazily per branch: final-hop delivery (the
        # most common case — every packet ends in one) never needs it
        dleaf = self.leaf_of(pkt.dest)
        if self.is_leaf(sw):
            if dleaf == sw:
                self._send_to_host(sim, pkt.dest, pkt)
                return
            fh = self.flow_hash(pkt)
            # path-aware metric: when the destination leaf is in this pod
            # the agg->dest-leaf down leg is known per candidate agg; for
            # cross-pod traffic the remaining legs depend on later hops
            remote = [self.leaf_down[dleaf][a] for a in range(self.A)] \
                if self._path_aware and \
                self.pod_of_leaf(dleaf) == self.pod_of_leaf(sw) else None
            a = self._pick(sim, sw, self.leaf_up[sw], fh % self.A, pkt,
                           remote)
            self._send_leaf_to_agg(sim, sw, a, pkt)
        elif self.is_agg(sw):
            agg_l = self.agg_local(sw)
            pod = agg_l // self.A
            if self.pod_of_leaf(dleaf) == pod:
                self._send_agg_to_leaf(sim, agg_l, dleaf, pkt)
            else:
                fh = self.flow_hash(pkt)
                # the down agg in the destination pod is a deterministic hash
                # choice (see the core branch below), so the core->agg down
                # leg per candidate core is known here: measure it (§2.1)
                dagg = self.pod_of_leaf(dleaf) * self.A + fh % self.A
                remote = [self.agg_down[dagg][c] for c in range(self.C)] \
                    if self._path_aware else None
                c = self._pick(sim, sw, self.agg_up[agg_l], fh % self.C, pkt,
                               remote)
                self._send_agg_to_core(sim, agg_l, c, pkt)
        else:
            c = self.core_local(sw)
            dpod = self.pod_of_leaf(dleaf)
            # deterministic hash choice of the destination pod's agg: same
            # block converges on one down-path, maximizing in-path aggregation
            a = self.flow_hash(pkt) % self.A
            if self.agg_down[dpod * self.A + a][c].busy_until \
                    >= LINK_DOWN_HORIZON:
                # hashed agg is dead/unreachable: deterministic walk to the
                # first live pod agg (same choice for every packet of the
                # block, so convergence on one down-path is preserved)
                for alt in range(self.A):
                    dl = self.agg_down[dpod * self.A + (a + alt) % self.A][c]
                    if dl.busy_until < LINK_DOWN_HORIZON:
                        a = (a + alt) % self.A
                        break
            self._send_core_to_agg(sim, c, dpod * self.A + a, pkt)

    def forward_toward_switch(self, sim, sw: int, pkt: Packet) -> None:
        target = pkt.dest_switch
        fh = hash(target)
        if self.is_leaf(sw):
            pod = self.pod_of_leaf(sw)
            if self.is_agg(target) and self.agg_local(target) // self.A == pod:
                self._send_leaf_to_agg(sim, sw, self.agg_local(target) % self.A,
                                       pkt)
            else:
                self._send_leaf_to_agg(sim, sw, fh % self.A, pkt)
        elif self.is_agg(sw):
            agg_l = self.agg_local(sw)
            pod = agg_l // self.A
            if self.is_leaf(target):
                if self.pod_of_leaf(target) == pod:
                    self._send_agg_to_leaf(sim, agg_l, target, pkt)
                else:
                    self._send_agg_to_core(sim, agg_l, fh % self.C, pkt)
            elif self.is_agg(target):
                if self.agg_local(target) // self.A == pod:
                    # sibling agg: bounce via the pod's first leaf
                    self._send_agg_to_leaf(sim, agg_l,
                                           pod * self.leaves_per_pod, pkt)
                else:
                    self._send_agg_to_core(sim, agg_l, fh % self.C, pkt)
            else:
                self._send_agg_to_core(sim, agg_l, self.core_local(target), pkt)
        else:
            c = self.core_local(sw)
            if self.is_agg(target):
                self._send_core_to_agg(sim, c, self.agg_local(target), pkt)
            else:
                dpod = self.pod_of_leaf(target) if self.is_leaf(target) else 0
                self._send_core_to_agg(sim, c, dpod * self.A + fh % self.A, pkt)

    def out_port_send(self, sim, sw: int, port: int, pkt: Packet) -> None:
        if self.is_leaf(sw):
            if port < self.H:
                self._send_to_host(sim, sw * self.H + port, pkt)
            else:
                self._send_leaf_to_agg(sim, sw, port - self.H, pkt)
        elif self.is_agg(sw):
            agg_l = self.agg_local(sw)
            pod = agg_l // self.A
            if port < self.leaves_per_pod:
                self._send_agg_to_leaf(sim, agg_l,
                                       pod * self.leaves_per_pod + port, pkt)
            else:
                self._send_agg_to_core(sim, agg_l, port - self.leaves_per_pod,
                                       pkt)
        else:
            self._send_core_to_agg(sim, self.core_local(sw), port, pkt)

    # ---- static-tree support ----------------------------------------------
    def root_candidates(self) -> List[int]:
        return [self.core_gid(c) for c in range(self.C)]

    def _designated_agg(self, root: int, pod: int) -> int:
        """The one agg a static tree uses in ``pod`` (deterministic per root,
        spread across roots so multi-tree runs use disjoint up-paths)."""
        return self.agg_gid(pod, (self.core_local(root) + pod) % self.A)

    def static_expected(self, parts: List[int], root: int) -> Dict[int, int]:
        plan: Dict[int, int] = {}
        pods = set()
        leaves_by_pod: Dict[int, set] = {}
        for h in parts:
            leaf = self.leaf_of(h)
            plan[leaf] = plan.get(leaf, 0) + 1
            pod = self.pod_of_leaf(leaf)
            pods.add(pod)
            leaves_by_pod.setdefault(pod, set()).add(leaf)
        for pod, leaves in leaves_by_pod.items():
            plan[self._designated_agg(root, pod)] = len(leaves)
        plan[root] = len(pods)
        return plan

    def static_send_up(self, sim, sw: int, root: int, pkt: Packet) -> None:
        if self.is_leaf(sw):
            agg = self._designated_agg(root, self.pod_of_leaf(sw))
            self._send_leaf_to_agg(sim, sw, self.agg_local(agg) % self.A, pkt)
        else:
            self._send_agg_to_core(sim, self.agg_local(sw),
                                   self.core_local(root), pkt)

    # ---- fault-injection support -------------------------------------------
    def links_into(self, sw: int) -> List[Link]:
        if self.is_leaf(sw):
            return ([self.host_up[h]
                     for h in range(sw * self.H, (sw + 1) * self.H)]
                    + [self.leaf_down[sw][a] for a in range(self.A)])
        if self.is_agg(sw):
            agg_l = self.agg_local(sw)
            pod, a = agg_l // self.A, agg_l % self.A
            first = pod * self.leaves_per_pod
            return ([self.leaf_up[leaf][a]
                     for leaf in range(first, first + self.leaves_per_pod)]
                    + [self.agg_down[agg_l][c] for c in range(self.C)])
        c = self.core_local(sw)
        return [self.agg_up[g][c] for g in range(self.num_aggs)]

    # ---- accounting --------------------------------------------------------
    def all_links(self) -> List[Link]:
        out: List[Link] = []
        out.extend(self.host_up)
        out.extend(self.host_down)
        for row in self.leaf_up:
            out.extend(row)
        for row in self.leaf_down:
            out.extend(row)
        for row in self.agg_up:
            out.extend(row)
        for row in self.agg_down:
            out.extend(row)
        return out

    def link_names(self) -> List[str]:
        out = [f"host{h}->leaf{self.leaf_of(h)}"
               for h in range(self.num_hosts)]
        out += [f"leaf{self.leaf_of(h)}->host{h}"
                for h in range(self.num_hosts)]
        for leaf in range(self.L):
            pod = self.pod_of_leaf(leaf)
            out += [f"leaf{leaf}->agg{pod * self.A + a}"
                    for a in range(self.A)]
        for leaf in range(self.L):
            pod = self.pod_of_leaf(leaf)
            out += [f"agg{pod * self.A + a}->leaf{leaf}"
                    for a in range(self.A)]
        for g in range(self.num_aggs):
            out += [f"agg{g}->core{c}" for c in range(self.C)]
        for g in range(self.num_aggs):
            out += [f"core{c}->agg{g}" for c in range(self.C)]
        return out
