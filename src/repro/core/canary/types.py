"""Core datatypes for the Canary packet-level discrete-event simulator.

This module mirrors the entities of the paper:

* ``Packet`` — the Canary packet format of §4.1 (destination/leader address,
  block ``id``, aggregation ``counter``, participating ``hosts`` count, the
  collision stamp fields ``switch_addr``/``port_stamp``, the ``bypass`` and
  ``multicast`` flags, and the payload ``value``).
* ``Descriptor`` — the per-block switch state of §3.1.1 (accumulator, children
  port set, timer, counter) stored in a static hash-indexed array (§3.2).
* ``SimConfig`` — the simulated world: the two-level fat tree of §5.2
  (32 leaf switches x 64 ports, 32 spines x 32 ports, 100 Gb/s links), packet
  framing from the Tofino prototype of §5.1, and the §5.2 congestion model.

Values carried by packets are Python integers so that every simulation is an
*exact* end-to-end correctness check of the allreduce (integer addition is
associative — any aggregation order must give the same total).
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple


class PacketKind(enum.IntEnum):
    """Kinds of packets flowing through the simulated network."""

    REDUCE = 0       # host/switch partial aggregate flowing toward the leader
    BCAST = 1        # fully-reduced data flowing down the recorded tree
    RESTORE = 2      # leader -> switch tree-restoration packet (§3.2.1)
    RETX_REQ = 3     # host -> leader retransmission request (§3.3)
    FAIL = 4         # leader -> host "reduce this block again" message (§3.3)
    UNICAST_DATA = 5 # leader -> host retransmitted reduced block (§3.3)
    NOISE = 6        # background congestion traffic (random uniform, §5.2)
    RING = 7         # host-based ring allreduce traffic (baseline, §5.2)
    # Transport-policy control traffic (repro.core.transport). Ids >= 8 fall
    # in the switch dataplane's contiguous pass-through range (kind >=
    # RETX_REQ): switches forward them toward ``dest`` untouched.
    CNP = 8          # DCQCN congestion-notification packet (receiver -> sender)
    ACK = 9          # go-back-N cumulative acknowledgement (receiver -> sender)


class _StrEnum(str, enum.Enum):
    """``enum.StrEnum`` backport: members *are* their string values and
    ``str()`` returns the bare value (``enum.StrEnum`` itself is 3.11+; the
    supported floor is Python 3.10)."""

    __str__ = str.__str__
    __format__ = str.__format__


class Algo(_StrEnum):
    """Allreduce algorithms implemented in the simulator (§5.2)."""

    CANARY = "canary"
    STATIC_TREE = "static_tree"   # N static trees (N=1 ~ SHARP/SwitchML/ATP, N>1 ~ PANAMA)
    RING = "ring"                 # bandwidth-optimal host-based ring


class LoadBalancing(_StrEnum):
    """Up-port selection policies at the leaf switches."""

    ECMP = "ecmp"            # hash-based, congestion-oblivious
    ADAPTIVE = "adaptive"    # paper §5.2: default port unless >50% full, then min-queue
    PER_PACKET = "per_packet"  # always pick the least-loaded up-port (DRILL-like)


_PKT_SEQ = 0


@dataclass(slots=True)
class Packet:
    """A Canary packet (§4.1). ``size_bytes`` includes header framing."""

    kind: PacketKind
    dest: int                 # destination host id (the leader for REDUCE)
    id: int                   # unique block id: (app << APP_SHIFT) | (block << GEN_BITS) | gen
    counter: int = 0          # number of already-reduced host contributions
    hosts: int = 0            # number of hosts participating in the reduction
    value: int = 0            # payload (exact integer aggregation check)
    bypass: bool = False      # set after a collision: switches must not process
    multicast: bool = False   # set on broadcast-phase packets
    switch_addr: int = -1     # collision stamp: switch address (§3.2.1)
    port_stamp: int = -1      # collision stamp: in-port at that switch (§3.2.1)
    restore_ports: Tuple[int, ...] = ()  # RESTORE: ports bitmap payload (§3.2.1)
    dest_switch: int = -1     # RESTORE: target switch address
    size_bytes: int = 0
    src: int = -1             # source host (for RETX_REQ / debugging)
    chunk: int = -1           # RING: chunk index
    step: int = -1            # RING: algorithm step
    # Provenance tag set by the trace recorder (repro.core.trace) when
    # SimConfig.trace is on: the TraceNode id whose aggregate this packet
    # carries. Observation-only — never read by the protocol layers.
    trace_node: int = -1
    # Transport-policy fields (repro.core.transport). Under the default
    # ``none`` policy both stay at their defaults for a packet's whole life.
    ecn: bool = False         # ECN congestion-experienced mark (dcqcn, RED)
    seq: int = -1             # go-back-N per-flow sequence number (gbn; ACK:
                              # the cumulative acknowledged sequence)


class PacketPool:
    """Free-list allocator for :class:`Packet` (ARCHITECTURE.md §Performance).

    The per-event hot path allocates one ``Packet`` per send; recycling them
    through an explicit free list cuts allocator/GC churn without touching
    simulation semantics. The invariants that keep reuse safe (pinned by the
    golden replays and ``tests/core/test_perf_contract.py``):

    * **Only linear packets are ever freed.** A packet is *linear* when
      exactly one reference exists at any time (REDUCE/NOISE/RING/RESTORE/
      FAIL/UNICAST_DATA/RETX_REQ). Multicast packets (``multicast=True`` —
      broadcast fan-outs schedule the *same object* on several links) must
      never be freed; every free site guards on ``pkt.multicast`` (or frees
      a kind that is never multicast, which also keeps the pooled
      ``multicast`` flag invariantly False).
    * **``free`` resets exactly the fields whose stale values could be
      *read through a guard* on the next life**: ``bypass`` (a stale True
      would route an aggregable REDUCE around every switch), ``switch_addr``
      / ``port_stamp`` (a stale stamp would fabricate §3.2.1 restorations at
      the leader) and ``trace_node`` (the recorder lazily trusts any id
      >= 0). Every other field is only ever read for packet kinds whose
      alloc sites assign it: ``alloc`` sites must set ``kind``, ``dest``,
      ``id``, ``size_bytes`` plus every field their kind's consumers read
      (REDUCE: counter/hosts/value [+src at host sends]; NOISE: src/chunk;
      RING: value/src/chunk/step). RESTORE's ``restore_ports``/
      ``dest_switch`` are exempt because RESTORE packets are always
      constructed fresh, never pool-allocated.
    """

    __slots__ = ("_free", "allocated", "reused", "freed", "max_free")

    def __init__(self, max_free: int = 8192) -> None:
        self._free: List["Packet"] = []
        self.allocated = 0   # fresh Packet constructions
        self.reused = 0      # allocs served from the free list
        self.freed = 0       # packets returned to the pool
        self.max_free = max_free

    def alloc(self) -> "Packet":
        free = self._free
        if free:
            self.reused += 1
            return free.pop()
        self.allocated += 1
        return Packet(kind=PacketKind.REDUCE, dest=-1, id=0)

    def free(self, pkt: "Packet") -> None:
        free = self._free
        if len(free) < self.max_free:
            # minimal reset — see the class docstring for the field audit.
            # ``ecn``/``seq`` join it: a stale ECN mark would fabricate CNPs
            # on the next life, a stale seq would make an unsequenced packet
            # look go-back-N-tracked (both read through ``is not default``
            # guards in repro.core.transport).
            pkt.bypass = False
            pkt.switch_addr = -1
            pkt.port_stamp = -1
            pkt.trace_node = -1
            pkt.ecn = False
            pkt.seq = -1
            self.freed += 1
            free.append(pkt)

    # NOTE: ``freed`` can exceed ``allocated + reused`` — packets born via
    # the plain ``Packet(...)`` constructor (control traffic: FAIL, RESTORE,
    # UNICAST_DATA, RETX_REQ) are recycled into the pool at end-of-life too.


# --- Block id packing -------------------------------------------------------
# id = (app << APP_SHIFT) | (block << GEN_BITS) | generation
# A retransmitted block gets a fresh generation so that it hashes to (likely)
# different descriptor slots and ECMP paths, exactly as §3.3 prescribes
# ("the hosts re-issue the reduction of that packet with a different id").
GEN_BITS = 6
APP_SHIFT = 40
BLOCK_MASK = (1 << (APP_SHIFT - GEN_BITS)) - 1


def make_id(app: int, block: int, generation: int = 0) -> int:
    return (app << APP_SHIFT) | (block << GEN_BITS) | generation


def id_app(pid: int) -> int:
    return pid >> APP_SHIFT


def id_block(pid: int) -> int:
    return (pid >> GEN_BITS) & BLOCK_MASK


def id_gen(pid: int) -> int:
    return pid & ((1 << GEN_BITS) - 1)


def block_key(pid: int) -> Tuple[int, int]:
    """(app, block) — generation-independent identity of a reduction block."""
    return (id_app(pid), id_block(pid))


@dataclass(slots=True)
class Descriptor:
    """Per-block soft state held by a switch (§3.1.1, §3.2).

    Allocated on the first REDUCE packet of a block, deallocated when the
    BCAST sweep passes through (or when garbage-collected after ``gc_ns`` of
    inactivity — stale generations abandoned by a retransmission would
    otherwise leak, a detail the paper leaves to the implementation).
    """

    id: int
    slot: int
    value: int = 0
    counter: int = 0
    hosts: int = 0
    children: Set[int] = field(default_factory=set)
    sent: bool = False            # timer fired (or early completion) — partial forwarded
    expected: int = -1            # STATIC_TREE mode: exact child count to wait for
    alloc_ns: float = 0.0
    last_ns: float = 0.0
    timer_seq: int = 0            # guards against stale timer events
    trace_node: int = -1          # trace recorder tag (see Packet.trace_node)


@dataclass
class SimConfig:
    """World configuration. Defaults reproduce the paper's §5.2 setup."""

    # -- topology --------------------------------------------------------------
    # Which registered Topology implementation to build (see topology.py):
    # "fat_tree" (the paper's 2-level leaf/spine) or "three_tier" (folded-Clos
    # leaf/agg/core). New topologies register via @register_topology.
    topology: str = "fat_tree"
    num_leaves: int = 32
    hosts_per_leaf: int = 32
    num_spines: int = 32              # fat_tree only
    # three_tier only: pods of (num_leaves/num_pods) leaves + aggs_per_pod
    # aggregation switches, num_cores core switches (full bipartite agg<->core)
    num_pods: int = 0
    aggs_per_pod: int = 0
    num_cores: int = 0

    # -- links ---------------------------------------------------------------
    link_gbps: float = 100.0          # hosts and switches: 100 Gb/s NICs/ports
    hop_latency_ns: float = 300.0     # per-hop delay (§3.2.2 cites ~300 ns)
    buffer_bytes: int = 131072        # per output port; 50% threshold for adaptive LB

    # -- packet framing (§5.1: Tofino prototype calibration) ------------------
    payload_bytes: int = 1024         # 256 x 4 B elements (large-sim setting, §5.1)
    header_bytes: int = 57            # 19 B Canary + 14 B Ethernet + 24 B framing

    # -- Canary data plane -----------------------------------------------------
    timeout_ns: float = 1000.0        # descriptor aggregation window (§3.1.1)
    table_size: int = 32768           # descriptor array entries (§5.1: 32K on Tofino)
    partition_table: bool = False     # statically partition table across apps (§3.2.1)
    gc_ns: float = 5e6                # descriptor idle GC (see Descriptor docstring)

    # -- load balancing --------------------------------------------------------
    lb: LoadBalancing = LoadBalancing.ADAPTIVE
    # Background (non-allreduce) traffic policy. The paper's premise (§2.1) is
    # that production traffic load-balanced with ECMP "often experiences
    # congestion, even in the presence of alternative non-congested paths";
    # the congestion-aware substrate is what *Canary* packets ride on. We keep
    # both knobs so the sensitivity is measurable (EXPERIMENTS.md §Sim).
    noise_lb: LoadBalancing = LoadBalancing.ECMP
    lb_threshold: float = 0.5         # occupancy fraction that triggers adaptation
    # CONGA-style path-level congestion metric (up + remote down-link backlog)
    # vs. purely local up-port queues. Canary is "orthogonal to the load
    # balancing algorithm" (§3); CONGA [37] is the paper's canonical example
    # and measures path congestion, so this defaults to True for allreduce
    # traffic. Sensitivity measured in EXPERIMENTS.md §Sim.
    path_aware_lb: bool = True
    # Flowlet switching [37]: point-to-point flows (congestion traffic, ring
    # chunks, unicast control) pick an up-port once per flowlet/message and
    # stick to it; re-decision happens on a new flowlet. Canary's aggregated
    # partials are one packet per (switch, block), i.e. inherently per-packet.
    flowlet_lb: bool = True

    # -- reliability (§3.3) ----------------------------------------------------
    drop_prob: float = 0.0            # iid per-link packet drop probability
    retx_timeout_ns: float = 2.0e5    # ~2 RTT at simulated scale
    max_generations: int = 8          # then fall back to host-based (bypass) reduce
    switch_fail_ns: Optional[float] = None  # time at which `failed_switch` dies
    failed_switch: Optional[int] = None     # global switch index

    # -- host behaviour ---------------------------------------------------------
    noise_prob: float = 0.0           # §5.2.5: P(delay a send by noise_delay_ns)
    noise_delay_ns: float = 1000.0
    noise_msg_bytes: int = 65536      # congestion flows: message size between re-picks
    leader_aggregate_ns: float = 1000.0  # host-side per-block leader processing (§3.2.2 "r")

    # -- transport policy (repro.core.transport) -------------------------------
    # Registry key: "none" (default; bit-identical to the pre-transport
    # engine), "gbn" (go-back-N recovery: per-flow sequence numbers +
    # cumulative ACKs) or "dcqcn" (ECN/RED marking, CNP notification, DCQCN
    # rate control, PFC pause). Knobs are FLAT fields (not a nested
    # dataclass) so sweep work items survive the dataclasses.asdict ->
    # SimConfig(**cfg) round trip.
    transport: str = "none"
    # ECN / RED marking at egress queues (dcqcn): mark probability ramps from
    # 0 at ecn_kmin_bytes of backlog to ecn_pmax at ecn_kmax_bytes, then 1.
    ecn_kmin_bytes: int = 16384
    ecn_kmax_bytes: int = 65536
    ecn_pmax: float = 0.2
    cnp_interval_ns: float = 5.0e4    # min gap between CNPs per (receiver, sender)
    # DCQCN sender state machine (rate decrease on CNP; timer-driven fast
    # recovery then additive increase).
    dcqcn_g: float = 1.0 / 16.0
    dcqcn_rai_gbps: float = 5.0       # additive-increase step
    dcqcn_timer_ns: float = 3.0e5     # rate-increase timer period
    dcqcn_min_rate_gbps: float = 1.0
    dcqcn_f: int = 5                  # fast-recovery stages before additive increase
    # PFC priority pause (dcqcn): pause the culprit sender when an egress
    # queue crosses pfc_pause_bytes; resume when it drains to pfc_resume_bytes.
    pfc_pause_bytes: int = 98304      # Xoff (75% of the default 128 KiB buffer)
    pfc_resume_bytes: int = 32768     # Xon
    # go-back-N (gbn): sender window in packets (point-to-point flows) /
    # blocks (aggregated flows), retransmission timeout, cumulative-ACK cadence.
    gbn_window: int = 32
    gbn_timeout_ns: float = 2.0e5
    gbn_ack_every: int = 1

    # -- experiment ------------------------------------------------------------
    seed: int = 0
    max_events: int = 200_000_000     # safety valve
    # Opt-in aggregation-provenance recording (repro.core.trace): the run
    # gains a ``Simulator.trace`` TraceRecorder that reconstructs the dynamic
    # tree every block actually rode. Recording is observation-only — it
    # touches no RNG draw, schedules no event and mutates no protocol state,
    # so traced runs reproduce untraced ``SimResult``s bit-for-bit.
    trace: bool = False
    # Opt-in telemetry (repro.core.telemetry): a metrics registry, periodic
    # time-series probes sampled on a sim-time cadence, block/descriptor
    # lifecycle spans, and Perfetto / CSV exporters. Observation-only like
    # ``trace``: off means ``Simulator.telemetry is None`` and every hook
    # site reduces to one identity check; on leaves the golden event stream
    # bit-identical (probe ticks dispatch outside the ``events`` count, and
    # no hook touches ``sim.rng`` or protocol state). Knobs are FLAT fields
    # so sweep work items survive the asdict -> SimConfig(**cfg) round trip.
    telemetry: bool = False
    telemetry_probe_ns: float = 10_000.0  # probe cadence in sim time
    telemetry_probes: bool = True         # periodic time-series sampling
    telemetry_spans: bool = True          # lifecycle spans + instant events
    telemetry_max_spans: int = 200_000    # span cap (overflow is counted,
    telemetry_max_samples: int = 200_000  # per-series sample cap  never silent)
    # Per-*packet* instants (stragglers, collisions) get their own, much
    # smaller cap: a congested cell emits tens of thousands, which are
    # worthless individually in a trace view (the exact totals live in
    # ``SimResult``) but dominate the telemetry-on overhead if all retained.
    telemetry_max_pkt_instants: int = 2_000
    # Opt-in fault injection (repro.core.faults): a list of FLAT, JSON-able
    # spec dicts (so sweep work items survive the asdict -> SimConfig(**cfg)
    # round trip), each naming a registered fault kind plus its parameters,
    # e.g. ``{"kind": "switch_crash", "target": 5, "at_ns": 2000.0,
    # "heal_ns": 50000.0}``. Empty means ``Simulator.faults is None`` and
    # every hook site reduces to one identity check — fault-free runs
    # (including every golden) stay bit-identical. Kinds: "switch_crash",
    # "link_down", "link_degrade", "link_flap", "host_slow".
    faults: List[dict] = field(default_factory=list)

    # Derived ------------------------------------------------------------------
    @property
    def num_hosts(self) -> int:
        return self.num_leaves * self.hosts_per_leaf

    @property
    def num_switches(self) -> int:
        """Total switch count of the selected topology (delegates to the
        registered Topology class, so plug-in fabrics report correctly)."""
        from .topology import TOPOLOGIES  # function-level: avoid import cycle
        cls = TOPOLOGIES.get(self.topology)
        if cls is not None:
            return cls.config_num_switches(self)
        if self.topology == "fat_tree":
            # registry not populated yet (bare `types` import): the 2-level
            # formula is correct for the default fabric only
            return self.num_leaves + self.num_spines
        raise ValueError(f"unknown topology {self.topology!r}; import the "
                         "module that registers it before reading num_switches")

    @property
    def bytes_per_ns(self) -> float:
        return self.link_gbps / 8.0  # Gb/s -> B/ns

    @property
    def mtu_bytes(self) -> int:
        return self.payload_bytes + self.header_bytes

    def validate(self) -> None:
        if self.topology == "fat_tree" and self.num_spines > self.hosts_per_leaf:
            # the paper's fat tree is full-bisection: 32 up + 32 down ports/leaf
            raise ValueError("leaf switches need hosts_per_leaf >= num_spines uplinks "
                             "only in oversubscribed setups; got more spines than uplinks")
        if self.payload_bytes <= 0 or self.timeout_ns <= 0:
            raise ValueError("payload_bytes and timeout_ns must be positive")


def paper_config(**overrides) -> "SimConfig":
    """The paper's §5.2 network: 1024 hosts, 32 leaves x 64 ports, 32 spines."""
    base = dict(num_leaves=32, hosts_per_leaf=32, num_spines=32,
                link_gbps=100.0, payload_bytes=1024, table_size=32768)
    base.update(overrides)
    return SimConfig(**base)


def three_tier_config(num_pods: int = 4, leaves_per_pod: int = 2,
                      hosts_per_leaf: int = 4, aggs_per_pod: int = 2,
                      num_cores: int = 4, **overrides) -> "SimConfig":
    """A 3-tier folded-Clos network (leaf/agg/core). Defaults give 32 hosts
    with 2:1 leaf->agg oversubscription; cross-pod paths are 4 switch hops,
    exercising the LB policies twice per packet."""
    base = dict(topology="three_tier",
                num_leaves=num_pods * leaves_per_pod,
                hosts_per_leaf=hosts_per_leaf, num_pods=num_pods,
                aggs_per_pod=aggs_per_pod, num_cores=num_cores,
                table_size=max(4096, num_pods * leaves_per_pod
                               * hosts_per_leaf * 64))
    base.update(overrides)
    return SimConfig(**base)


def scaled_config(scale: int = 8, **overrides) -> "SimConfig":
    """A proportionally scaled-down full-bisection fat tree (scale^2 hosts)
    that keeps the paper's 50%-background-load geometry but runs in seconds
    on CPU. Used by tests and the default benchmark profile."""
    base = dict(num_leaves=scale, hosts_per_leaf=scale, num_spines=scale,
                link_gbps=100.0, payload_bytes=1024,
                table_size=max(4096, scale * scale * 64))
    base.update(overrides)
    return SimConfig(**base)


# Paper-scale parameterizations (1024-4096 hosts). These are the grids the
# flow-level backend (repro.core.flow) exists for: a packet-level cell at
# these sizes costs minutes-to-hours of event dispatch, a flow-level cell is
# one row of a batched XLA call. ``benchmarks/sweep.py --topology <name>``
# accepts any key. Fat trees stay full-bisection (num_spines == up-ports per
# leaf); the folded-Clos entries keep the bench profile's 2:1 leaf->agg
# oversubscription so congestion actually binds.
PAPER_SCALES: Dict[str, Callable[..., "SimConfig"]] = {
    "fat_tree_1024": lambda **o: paper_config(**o),
    "fat_tree_2048": lambda **o: paper_config(
        num_leaves=64, hosts_per_leaf=32, num_spines=32,
        table_size=65536, **o),
    "fat_tree_4096": lambda **o: paper_config(
        num_leaves=64, hosts_per_leaf=64, num_spines=64,
        table_size=131072, **o),
    "three_tier_1024": lambda **o: three_tier_config(
        num_pods=8, leaves_per_pod=4, hosts_per_leaf=32,
        aggs_per_pod=16, num_cores=16, **o),
    "three_tier_2048": lambda **o: three_tier_config(
        num_pods=8, leaves_per_pod=8, hosts_per_leaf=32,
        aggs_per_pod=16, num_cores=32, **o),
    "three_tier_4096": lambda **o: three_tier_config(
        num_pods=16, leaves_per_pod=8, hosts_per_leaf=32,
        aggs_per_pod=16, num_cores=32, **o),
}


def paper_scale_config(name: str, **overrides) -> "SimConfig":
    """Build one of the named 1024-4096-host parameterizations."""
    try:
        factory = PAPER_SCALES[name]
    except KeyError:
        raise KeyError(f"unknown paper-scale topology {name!r} "
                       f"(have: {', '.join(sorted(PAPER_SCALES))})") from None
    return factory(**overrides)


@dataclass
class AllreduceJob:
    """One application's collective over ``participants``.

    ``collective`` (paper §6, "Support for other collectives"):

    * ``allreduce`` — reduce + broadcast (the default).
    * ``reduce``    — the destination (``root``) acts as the leader for every
                      block and the broadcast phase is skipped.
    * ``broadcast`` — the source (``root``) acts as the leader and the
                      aggregation is skipped: receivers send empty *join*
                      packets toward the source (recording the dynamic tree)
                      and the source's data rides the broadcast phase down.
    * ``barrier``   — a 0-byte allreduce (header-only packets).

    ``arrival_ns`` makes the submit time a first-class engine event
    (``EV_JOB_ARRIVE``): the job's protocol state is set up — and its hosts
    start sending — only when the event fires, so fleets of tenants can
    submit jobs open-loop over the lifetime of one run. ``tenant`` groups
    apps under one owner for switch-memory quota accounting
    (``repro.core.fleet``); it defaults to the app id.
    """

    app: int
    participants: List[int]
    data_bytes: int
    collective: str = "allreduce"
    root: Optional[int] = None     # reduce destination / broadcast source
    arrival_ns: float = 0.0        # submit time (0 = present at t=0, as before)
    tenant: int = -1               # owning tenant (< 0: the app is its own tenant)

    def num_blocks(self, payload_bytes: int) -> int:
        if self.collective == "barrier":
            return 1
        return max(1, -(-self.data_bytes // payload_bytes))


@dataclass(frozen=True)
class TenantSpec:
    """A fleet tenant: identity plus its share of the descriptor table.

    ``weight`` drives the weighted quota policies (``repro.core.fleet.quota``):
    a tenant's slot region is ``table_size * weight / sum(weights)``, so a
    priority tenant can claim more of the table (§3.2.2 — descriptor memory
    is the scarce resource bounding concurrent in-network tenants).
    """

    tenant: int
    weight: float = 1.0
    name: str = ""


@dataclass
class SimResult:
    """Outputs of one simulation run."""

    duration_ns: float
    start_ns: float
    # per-app goodput: data_bytes * 8 / duration of that app's allreduce
    goodput_gbps: Dict[int, float]
    correct: bool
    # diagnostics
    link_utilization: List[float]          # one sample per directed link
    avg_utilization: float
    stragglers: int
    collisions: int
    restorations: int
    retransmissions: int
    fallbacks: int
    max_descriptors_per_switch: int
    max_descriptor_bytes: int
    events: int
    dropped_packets: int
    completed_blocks: int
    # -- per-job lifecycle (fleet subsystem) ---------------------------------
    # Additive diagnostics: the golden-replay contract pins only the fields
    # above (tests/core/golden_cases.py GOLDEN_FIELDS).
    job_submit_ns: Dict[int, float] = field(default_factory=dict)
    job_start_ns: Dict[int, float] = field(default_factory=dict)   # admitted/degraded (not deferred)
    job_finish_ns: Dict[int, float] = field(default_factory=dict)
    job_admitted: Dict[int, bool] = field(default_factory=dict)    # False: host-based fallback
    app_fallback_blocks: Dict[int, int] = field(default_factory=dict)
    tenant_of: Dict[int, int] = field(default_factory=dict)
    # -- transport telemetry (repro.core.transport) ---------------------------
    # Additive diagnostics like the fleet fields above. ``drop_causes`` splits
    # the single ``dropped_packets`` total by cause ("wire": iid link loss,
    # "switch_fail": arrivals at a failed switch, "gbn_ooo": go-back-N
    # out-of-order endpoint discards — not part of dropped_packets, which
    # counts in-network losses only). ``transport_stats`` carries the active
    # policy's counters (ecn_marks, cnps, rate_cuts, pfc_pauses,
    # pfc_pause_ns, gbn_retx, gbn_acks, gbn_ooo). ``host_rate_gbps`` is the
    # final DCQCN sending rate of every throttled sender.
    transport: str = "none"
    drop_causes: Dict[str, int] = field(default_factory=dict)
    transport_stats: Dict[str, float] = field(default_factory=dict)
    host_rate_gbps: Dict[int, float] = field(default_factory=dict)
    # -- telemetry (repro.core.telemetry) -------------------------------------
    # Flat numeric digest of the run's Telemetry hub (probe/span/sample
    # counts, backlog and occupancy high-waters, flush split). Deliberately a
    # plain dict of floats — the live hub (with full series and spans) stays
    # on ``Simulator.telemetry``; embedding it here would break the
    # ``dataclasses.asdict`` round trip sweep work items rely on. Empty when
    # telemetry is off.
    telemetry_summary: Dict[str, float] = field(default_factory=dict)
    # -- fault injection (repro.core.faults) ----------------------------------
    # Additive survivability diagnostics, empty when no fault schedule ran.
    # ``fault_events`` logs every injected fault/heal as a flat dict
    # (kind, target, t_ns, phase). ``fault_exposure_ns`` measures, per app,
    # how much of its [start, finish] window overlapped an active fault;
    # ``fault_recovery_ns`` is the tail the app needed after the last
    # overlapping heal (0 when it finished before the heal, or was never
    # exposed). ``survived`` records whether each app completed at all.
    fault_events: List[dict] = field(default_factory=list)
    fault_exposure_ns: Dict[int, float] = field(default_factory=dict)
    fault_recovery_ns: Dict[int, float] = field(default_factory=dict)
    survived: Dict[int, bool] = field(default_factory=dict)

    def jct_ns(self, app: int) -> float:
        """Job completion time: finish minus submit (includes deferral wait)."""
        return self.job_finish_ns[app] - self.job_submit_ns[app]

    def summary(self) -> str:
        gp = ", ".join(f"app{a}={g:.1f}Gbps" for a, g in sorted(self.goodput_gbps.items()))
        # an app with no finish time (deferred, still running, or a budget
        # abort) renders as "done=-", never "done=nan us"
        done = {a: (f"{t/1e3:.1f}us" if t is not None else "-")
                for a in sorted(self.goodput_gbps)
                for t in (self.job_finish_ns.get(a),)}
        apps = " ".join(
            f"app{a}[done={done[a]} fb={self.app_fallback_blocks.get(a, 0)}]"
            for a in sorted(self.goodput_gbps))
        # render EVERY cause present (insertion order), so policy-specific
        # causes like gbn_ooo_discard — and any future ones — never vanish
        dc = self.drop_causes or {"wire": 0, "switch_fail": 0}
        drops = "drops[" + ",".join(f"{k}={v}" for k, v in dc.items()) + "]"
        tseg = ""
        if self.transport != "none":
            ts = self.transport_stats
            tseg = (f" tp={self.transport}"
                    f"[ecn={int(ts.get('ecn_marks', 0))}"
                    f" cnp={int(ts.get('cnps', 0))}"
                    f" pfc={int(ts.get('pfc_pauses', 0))}"
                    f" gbn_retx={int(ts.get('gbn_retx', 0))}"
                    f" ooo={int(ts.get('gbn_ooo', 0))}]")
            if self.host_rate_gbps:
                # senders DCQCN still held below line rate at end of run
                tseg += (f" throttled[{len(self.host_rate_gbps)}hosts"
                         f" min={min(self.host_rate_gbps.values()):.1f}Gbps]")
        return (f"t={self.duration_ns/1e3:.1f}us {gp} correct={self.correct} "
                f"stragglers={self.stragglers} collisions={self.collisions} "
                f"retx={self.retransmissions} maxdesc={self.max_descriptors_per_switch} "
                f"{drops}{tseg} {apps}")
