"""Switch dataplane layer: per-switch soft state + aggregation strategies.

Two pieces live here (see ``ARCHITECTURE.md``):

* :class:`SwitchLayer` — the algorithm-independent dataplane every switch
  runs: failure state, descriptor tables, arrival dispatch (pass-through
  kinds, RESTORE routing, timer guards), and the tree-restoration fan-out.
* The **algorithm-strategy registry**: :class:`AggregationStrategy`
  subclasses implement how REDUCE/BCAST packets are processed in-network and
  how hosts generate their sends. ``CANARY`` and ``STATIC_TREE`` live here;
  host-based algorithms (``RING``, in ``hostproto.py``) register in the same
  registry and simply leave the switch hooks at their pass-through defaults.

Registering a new collective::

    @register_algorithm(Algo.MY_ALGO)
    class MyStrategy(AggregationStrategy):
        ...

No engine, topology or facade changes are needed — the facade looks the
algorithm up by ``Algo`` value at construction time.

Hot-path notes (ARCHITECTURE.md §Performance): ``SwitchLayer.finalize``
pre-resolves the strategy's dataplane hooks and the topology's forwarding
methods into instance attributes once per run, arrival dispatch branches on
the raw packet-kind int, and descriptor timers use *lazy cancellation* — a
``live_timers`` registry maps an armed timer's sequence number to its
descriptor; firing early or deallocating unregisters the timer, and the
stale ``EV_TIMER`` heap entry is skipped with a single failed dict lookup
when it pops (it still counts as a dispatched event, preserving the golden
``events`` counts). Strategies recycle consumed REDUCE packets through
``sim.pool`` — a packet merged into a descriptor is at end-of-life; anything
forwarded on (stragglers, collisions, bypass) stays live.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Type

from .engine import EV_RETX, EV_TIMER
from .types import (APP_SHIFT, Algo, BLOCK_MASK, Descriptor, GEN_BITS, Packet,
                    PacketKind, id_app)

# kinds the switch dataplane never inspects — pure forwarding
_PASSTHROUGH = (PacketKind.NOISE, PacketKind.RING, PacketKind.RETX_REQ,
                PacketKind.FAIL, PacketKind.UNICAST_DATA)
_K_REDUCE = int(PacketKind.REDUCE)
_K_BCAST = int(PacketKind.BCAST)
_K_RESTORE = int(PacketKind.RESTORE)
_K_RETX_REQ = int(PacketKind.RETX_REQ)  # first of the passthrough id range


class SwitchLayer:
    """Algorithm-independent per-switch state + arrival dispatch."""

    def __init__(self, sim, num_switches: int):
        self.sim = sim
        self.tables: List[Dict[int, Descriptor]] = [dict() for _ in
                                                    range(num_switches)]
        self.slots: List[Dict[int, int]] = [dict() for _ in range(num_switches)]
        self.failed = [False] * num_switches
        self.desc_high = [0] * num_switches
        self.timer_seq = 0
        # lazy timer cancellation: timer_seq -> armed Descriptor. Entries are
        # removed when the descriptor fires (early or by timeout) or is
        # deallocated; a stale EV_TIMER pop then misses here and is dropped.
        self.live_timers: Dict[int, Descriptor] = {}
        # pre-resolved in finalize() once the strategy exists
        self._on_reduce = None
        self._on_bcast = None
        self._fwd_host = None
        self._fwd_switch = None
        self._pool_free = None
        self._telemetry = None

    def finalize(self) -> None:
        """Pre-resolve per-run hot-path callables (strategy hooks + topology
        forwarding). Called by the facade after every layer is built."""
        sim = self.sim
        self._on_reduce = sim.strategy.on_switch_reduce
        self._on_bcast = sim.strategy.on_switch_bcast
        self._fwd_host = sim.net.forward_toward_host
        self._fwd_switch = sim.net.forward_toward_switch
        self._pool_free = sim.pool.free
        self._telemetry = sim.telemetry

    # ------------------------------------------------------------- dispatch
    def arrive(self, sw: int, in_port: int, pkt: Packet) -> None:
        sim = self.sim
        if self.failed[sw]:
            sim.dropped += 1
            sim.dropped_failed += 1
            tel = self._telemetry
            if tel is not None:
                tel.on_drop("switch_fail", sw)
            if not pkt.multicast:
                self._pool_free(pkt)
            return
        kind = pkt.kind
        if kind >= _K_RETX_REQ:
            # _PASSTHROUGH kinds (RETX_REQ..ACK, a contiguous id range:
            # one compare for the most common arrivals): pure forwarding —
            # transport control packets (CNP/ACK) ride this branch too
            self._fwd_host(sim, sw, pkt)
        elif kind == _K_REDUCE:
            self._on_reduce(sw, in_port, pkt)
        elif kind == _K_BCAST:
            self._on_bcast(sw, pkt)
        else:  # RESTORE
            if pkt.dest_switch == sw:
                self.restore_at(sw, pkt)
                self._pool_free(pkt)
            else:
                self._fwd_switch(sim, sw, pkt)

    def on_timer(self, sw: int, timer_seq: int, pid: int) -> None:
        # lazy cancellation: a cancelled/fired timer is a single missed
        # dict lookup here (the heap entry was left in place)
        desc = self.live_timers.pop(timer_seq, None)
        if desc is not None and not self.failed[sw]:
            self.sim.strategy.on_descriptor_timeout(sw, desc)

    def fail_switch(self, sw: int) -> None:
        self.failed[sw] = True

    def crash_switch(self, sw: int) -> None:
        """Mid-run crash (repro.core.faults): mark failed AND flush the
        dataplane — descriptor table, slot map and armed timers all vanish
        with the switch's SRAM. Partials the descriptors were accumulating
        are state, not packets in flight, so nothing is charged to the drop
        counters here; the *protocol* recovers the data (timeout at the
        parent or whole-block retransmission). ``fail_switch`` above is the
        legacy pre-scheduled form and keeps its flush-free semantics — the
        ``canary_switch_failure`` golden pins it."""
        self.failed[sw] = True
        table = self.tables[sw]
        if table:
            for desc in table.values():
                if desc.timer_seq:
                    self.live_timers.pop(desc.timer_seq, None)
            table.clear()
        self.slots[sw].clear()

    def heal_switch(self, sw: int) -> None:
        """Recovery: the switch rejoins with empty tables (crash flushed
        them) and starts admitting descriptors again."""
        self.failed[sw] = False

    # ------------------------------------------------------------- helpers
    # (descriptor high-water tracking is inlined at the two allocation sites
    # in the strategies: ``if len(table) > desc_high[sw]: ...``)
    def dealloc(self, sw: int, desc: Descriptor) -> None:
        self.tables[sw].pop(desc.id, None)
        slots = self.slots[sw]
        if slots.get(desc.slot) == desc.id:
            del slots[desc.slot]
        if desc.timer_seq:
            self.live_timers.pop(desc.timer_seq, None)

    def restore_at(self, sw: int, pkt: Packet) -> None:
        """Tree restoration (§3.2.1): forward data out the stamped ports."""
        sim = self.sim
        bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pkt.id, value=pkt.value,
                    multicast=True, size_bytes=sim.cfg.mtu_bytes)
        if sim.trace is not None:
            sim.trace.on_bcast_fanout(sw, bc, pkt.restore_ports)
        for port in pkt.restore_ports:
            sim.net.out_port_send(sim, sw, port, bc)


# --------------------------------------------------------------------------
# Algorithm-strategy registry
# --------------------------------------------------------------------------
# Keyed by *string* value (Algo is a str-enum, so built-ins use their enum
# value) — new collectives register under any fresh key without having to
# extend the Algo enum first.
ALGORITHMS: Dict[str, Type["AggregationStrategy"]] = {}


def register_algorithm(algo):
    """Class decorator: bind a strategy to an :class:`Algo` value or any
    string key a new collective wants to go by."""

    def deco(cls: Type["AggregationStrategy"]) -> Type["AggregationStrategy"]:
        cls.algo = algo
        ALGORITHMS[str(algo)] = cls
        return cls

    return deco


def make_strategy(algo, sim) -> "AggregationStrategy":
    try:
        cls = ALGORITHMS[str(algo)]
    except KeyError:
        raise ValueError(f"no strategy registered for algorithm {algo!r}; "
                         f"registered: {sorted(ALGORITHMS)}") from None
    return cls(sim)


class AggregationStrategy:
    """How one collective algorithm uses the fabric.

    The defaults implement a *host-based* algorithm riding a cursor-less
    send queue: switches forward everything, hosts drive the protocol via
    :meth:`on_host_packet`. In-network algorithms override the switch hooks.
    """

    algo: Algo
    leader_skips_self = False  # CANARY: the leader keeps its contribution local
    uses_retx_timers = False   # CANARY: host-side loss detection (§3.3)
    # True when the strategy allocates per-switch descriptors — the resource
    # the fleet admission controller budgets (§3.2.2). Host-based strategies
    # (RING) keep the default and are always admitted without a quota.
    uses_switch_memory = False
    # True when generation-bumped FAIL resends must bypass in-network
    # aggregation (plan-driven strategies: a static plan has no notion of a
    # partial cohort, so a resent generation routed through it deadlocks on
    # the leader's never-resent leaf contribution). Read by
    # HostProtocol.host_handle_fail when a transport policy owns block retx.
    fail_resend_bypass = False
    # telemetry site state, installed by Telemetry.start() and retracted by
    # the hub when a site goes cold (all blocks opened / instant log full):
    # _tel_open is the hub's block_open dict (first-send detection),
    # _tel_pkt is the hub's raw per-packet instant log while instants are
    # wanted (the site appends and retracts itself at _tel_pkt_cap).
    # Pre-binding the state into ONE attribute keeps the hot sites at a
    # single load + identity check (see ARCHITECTURE.md §Telemetry).
    _tel_open = None
    _tel_pkt = None
    _tel_pkt_cap = 0

    def __init__(self, sim):
        self.sim = sim
        # per-run hot-path bindings (every layer the hooks touch exists
        # before strategies are constructed)
        cfg = sim.cfg
        self._engine = sim.engine
        self._push = sim.engine.push
        self._push_timer = sim.engine.push_timer
        self._fwd_host = sim.net.forward_toward_host
        self._pool = sim.pool
        self._trace = sim.trace
        self._transport = sim.transport
        # inlined descriptor telemetry site state (None/0 when telemetry is
        # off), installed by Telemetry.finalize() — the hub is constructed
        # after the layers (heap-locality, see Simulator). _tel_sw_hi is the
        # hub's exact per-switch occupancy high-water list, _tel_desc_log
        # its raw flush log ((sw, desc, reason, nchildren, t) records up to
        # _tel_desc_cap entries, then slim (reason, duration) pairs for the
        # window histogram only), and
        # _tel_desc_n counts allocs. Inlining keeps the per-descriptor
        # sites at a few attribute loads instead of a bound-method call;
        # the hub decodes the log once, lazily, after the run.
        self._telemetry = None
        self._tel_sw_hi = None
        self._tel_desc_log = None
        self._tel_desc_cap = 0
        self._tel_desc_n = 0
        self._mtu = cfg.mtu_bytes
        self._retx_timeout = cfg.retx_timeout_ns
        # per-app send constants, built lazily on first pump (after
        # activation, so the admission degrade decision is already made):
        # (B, parts, p, fixed_leader, nhosts, size, degraded, plain, abase)
        self._send_cache: Dict[int, tuple] = {}

    # ---- job setup ---------------------------------------------------------
    def setup_job(self, app: int, job, parts: List[int]) -> None:
        """Default: every participant streams its blocks via a lazy cursor.

        Pumps are scheduled at ``sim.now`` — 0.0 for construction-time jobs,
        the arrival/admission time for open-loop (fleet) jobs.
        """
        sim = self.sim
        hp = sim.hostproto
        for h in parts:
            hp.hosts[h].send_cursor.append([app, 0])
            hp.schedule_pump(h, sim.now)

    # ---- host send generation ---------------------------------------------
    def _send_consts(self, app: int) -> tuple:
        """Per-app constants for the cursor walk. Safe to cache: the
        participant list, leader map, wire size and the admission degrade
        decision are all fixed before ``setup_job`` schedules the first
        pump (a retx *fallback* is per-block state, not per-app)."""
        sim = self.sim
        parts = sim.leaders[app]
        consts = (sim.blocks[app], parts, len(parts),
                  sim._leader_fixed.get(app), sim.nparts[app],
                  sim.pkt_bytes[app], app in sim.bypass_apps,
                  app not in sim._barrier_apps
                  and app not in sim._contrib_root,
                  7919 * app)
        self._send_cache[app] = consts
        return consts

    def invalidate_send_cache(self, app: int) -> None:
        """Drop the cached per-app send constants. The fault-escalation path
        (repro.core.faults) flips ``app`` into ``sim.bypass_apps`` mid-run —
        the one post-setup event that changes the cached ``degraded`` flag."""
        self._send_cache.pop(app, None)

    def next_host_packet(self, host: int) -> Optional[Packet]:
        """Produce this host's next allreduce send (monolith cursor walk)."""
        sim = self.sim
        hs = sim.hostproto.hosts[host]
        cache = self._send_cache
        for cur in hs.send_cursor:
            app, nxt = cur
            consts = cache.get(app)
            if consts is None:
                consts = self._send_consts(app)
            B, parts, p, fixed, nhosts, size, degraded, plain, abase = consts
            # admission-degraded apps ride the §3.3 host-based path whatever
            # the strategy: bypass packets straight to the leader, which
            # keeps its own contribution local and unicasts the result
            if self.leader_skips_self or degraded:
                if fixed is None:
                    while nxt < B and parts[nxt % p] == host:
                        nxt += 1  # leader keeps its contribution local (§3.1.4)
                elif fixed == host:
                    nxt = B
            if nxt < B:
                cur[1] = nxt + 1
                pkt = self._pool.alloc()
                pkt.kind = PacketKind.REDUCE
                pkt.dest = parts[nxt % p] if fixed is None else fixed
                pkt.id = (app << APP_SHIFT) | (nxt << GEN_BITS)
                pkt.counter = 1
                pkt.hosts = nhosts
                # inline contribution() for plain allreduce/reduce apps
                pkt.value = (host + 1) * 1000003 + 31 * nxt + abase if plain \
                    else sim.contribution_of(app, nxt, host)
                pkt.bypass = degraded
                pkt.size_bytes = size
                pkt.src = host
                if self._trace is not None:
                    self._trace.on_host_send(host, pkt)
                # telemetry hot-site inlining: only a block's FIRST send is
                # interesting — _tel_open IS the hub's block_open dict while
                # unopened blocks remain (the hub retracts it after the last
                # one), so repeats are rejected without paying a call
                bo = self._tel_open
                if bo is not None and (pkt.id >> GEN_BITS) not in bo:
                    self._telemetry.on_host_send(host, pkt)
                tp = self._transport
                if tp is not None and tp.owns_block_retx:
                    # go-back-N block flows supersede the whole-block timer
                    tp.on_block_sent(host, app, nxt)
                elif self.uses_retx_timers or degraded:
                    # loss detection is part of the Canary protocol (§3.3);
                    # static-tree systems restart from scratch instead.
                    self._push_timer(self._engine.now + self._retx_timeout,
                                     EV_RETX, host, 0, (app, nxt, 0))
                return pkt
            cur[1] = nxt
        return None

    # ---- switch dataplane hooks --------------------------------------------
    def on_switch_reduce(self, sw: int, in_port: int, pkt: Packet) -> None:
        self._fwd_host(self.sim, sw, pkt)

    def on_switch_bcast(self, sw: int, pkt: Packet) -> None:
        self._fwd_host(self.sim, sw, pkt)

    def on_descriptor_timeout(self, sw: int, desc: Descriptor) -> None:
        pass

    # ---- host arrival hook --------------------------------------------------
    def on_host_packet(self, host: int, pkt: Packet) -> bool:
        """Return True when the strategy consumed the packet. A consumed
        linear (non-multicast) packet is recycled by the caller — do not
        retain references to it past this call."""
        return False


@register_algorithm(Algo.CANARY)
class CanaryStrategy(AggregationStrategy):
    """Dynamic trees: timeout aggregation, collisions + restoration (§3)."""

    leader_skips_self = True
    uses_retx_timers = True
    uses_switch_memory = True

    def __init__(self, sim):
        super().__init__(sim)
        cfg = sim.cfg
        sl = sim.switch
        self._switch = sl
        self._tables = sl.tables
        self._slots = sl.slots
        self._desc_high = sl.desc_high
        self._live = sl.live_timers
        self._timeout = cfg.timeout_ns
        self._gc_ns = cfg.gc_ns
        self._table_size = cfg.table_size
        self._partition = cfg.partition_table and len(sim.jobs) > 1

    # ---- descriptor slot hashing -------------------------------------------
    @staticmethod
    def _hash64(pid: int) -> int:
        # Fibonacci hashing; use the HIGH bits — block ids have zero low bits
        # (generation field), and power-of-two tables would otherwise see only
        # a tiny fraction of their slots.
        return ((pid * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> 24

    def slot_of(self, pid: int) -> int:
        sim = self.sim
        region = sim.slot_regions.get(id_app(pid))
        if region is not None:
            # enforced tenant quota (fleet admission, §3.2.2): this app's
            # descriptors can only ever occupy its tenant's slot region, so
            # a tenant's per-switch footprint is hard-bounded by its quota —
            # overflow within the region collides and bypasses (§3.2.1)
            # instead of stealing another tenant's slots.
            offset, size = region
            return offset + self._hash64(pid) % size
        if self._partition:
            apps = len(sim.jobs)
            region_sz = max(1, self._table_size // apps)
            return (id_app(pid) % apps) * region_sz \
                + self._hash64(pid) % region_sz
        return self._hash64(pid) % self._table_size

    # ---- dataplane ----------------------------------------------------------
    def on_switch_reduce(self, sw: int, in_port: int, pkt: Packet) -> None:
        sim = self.sim
        if pkt.bypass:
            self._fwd_host(sim, sw, pkt)
            return
        pid = pkt.id
        table = self._tables[sw]
        desc = table.get(pid)
        now = self._engine.now
        trace = self._trace
        if desc is not None:
            desc.children.add(in_port)
            desc.last_ns = now
            if desc.sent:
                # straggler (§3.1.1): forward immediately, keep child recorded
                sim.stragglers += 1
                if trace is not None:
                    trace.on_straggler(sw, in_port, pkt)
                ins = self._tel_pkt  # raw instant log while it has room
                if ins is not None:
                    # inlined pkt-instant site: raw packed id, decoded at
                    # consolidation; the site retracts itself when full
                    ins.append(("straggler", sw, pkt.id, now))
                    if len(ins) >= self._tel_pkt_cap:
                        self._tel_pkt = None
                        self._telemetry.want_pkt_instants = False
                self._fwd_host(sim, sw, pkt)
            else:
                desc.value += pkt.value
                desc.counter += pkt.counter
                if trace is not None:
                    trace.on_switch_merge(sw, desc, in_port, pkt)
                if desc.counter >= desc.hosts - 1:
                    self._fire_descriptor(sw, desc)  # all data received (§3.1.4)
                self._pool.free(pkt)  # merged: packet consumed
            return
        if not sim.slot_regions and not self._partition:
            slot = (((pid * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)
                    >> 24) % self._table_size
        else:
            slot = self.slot_of(pid)
        slots = self._slots[sw]
        occupant = slots.get(slot)
        if occupant is not None:
            odesc = table.get(occupant)
            if odesc is None:
                slots.pop(slot, None)
                occupant = None
            elif now - odesc.last_ns > self._gc_ns:
                # stale soft state (abandoned generation): garbage collect
                self._switch.dealloc(sw, odesc)
                occupant = None
        if occupant is not None:
            # collision (§3.2.1): stamp and bypass straight to the leader
            sim.collisions += 1
            if trace is not None:
                trace.on_collision(sw, in_port, pkt)
            ins = self._tel_pkt  # raw instant log while it has room
            if ins is not None:
                # inlined pkt-instant site (see the straggler site above)
                ins.append(("collision", sw, pkt.id, now))
                if len(ins) >= self._tel_pkt_cap:
                    self._tel_pkt = None
                    self._telemetry.want_pkt_instants = False
            pkt.switch_addr = sw
            pkt.port_stamp = in_port
            pkt.bypass = True
            self._fwd_host(sim, sw, pkt)
            return
        desc = Descriptor(id=pid, slot=slot, value=pkt.value,
                          counter=pkt.counter, hosts=pkt.hosts,
                          children={in_port}, alloc_ns=now,
                          last_ns=now)
        table[pid] = desc
        slots[slot] = pid
        dh = self._desc_high
        n = len(table)
        if n > dh[sw]:
            dh[sw] = n
        if trace is not None:
            trace.on_desc_alloc(sw, desc, in_port, pkt)
        hi = self._tel_sw_hi
        if hi is not None:
            # inlined on_desc_alloc: occupancy only rises at an alloc, so
            # the event-driven high-water stays exact at any probe cadence
            self._tel_desc_n += 1
            if n > hi[sw]:
                hi[sw] = n
        if desc.counter >= desc.hosts - 1:
            self._fire_descriptor(sw, desc)
            self._pool.free(pkt)
            return
        sl = self._switch
        sl.timer_seq = tseq = sl.timer_seq + 1
        desc.timer_seq = tseq
        self._live[tseq] = desc
        self._push_timer(now + self._timeout, EV_TIMER, sw, tseq, pid)
        self._pool.free(pkt)

    def _fire_descriptor(self, sw: int, desc: Descriptor,
                         reason: str = "complete") -> None:
        """Timeout (or early completion): forward the partial aggregate (§3.1.1)."""
        sim = self.sim
        desc.sent = True
        if desc.timer_seq:
            # early completion: lazily cancel the armed timer (the heap
            # entry stays; its pop misses live_timers and is dropped)
            self._live.pop(desc.timer_seq, None)
        did = desc.id
        leader = sim.leader_of(did >> APP_SHIFT, (did >> GEN_BITS) & BLOCK_MASK)
        out = self._pool.alloc()
        out.kind = PacketKind.REDUCE
        out.dest = leader
        out.id = did
        out.counter = desc.counter
        out.hosts = desc.hosts
        out.value = desc.value
        out.size_bytes = self._mtu
        # switch-originated aggregate: no single culprit sender (a stale
        # pooled src would misdirect transport CNPs/PFC pauses)
        out.src = -1
        if self._trace is not None:
            self._trace.on_desc_flush(sw, desc, out, reason)
        dlog = self._tel_desc_log
        if dlog is not None:
            # inlined on_desc_flush: raw-log the aggregation window. The
            # descriptor itself is retained (descriptors are not pooled, so
            # nothing aliases it later) and the hub reads id/counter/
            # alloc_ns off it lazily after the run — only the child count
            # must be captured here, because stragglers keep mutating the
            # children set after the flush. Past the span cap only
            # (reason, duration) survives, so retention stays bounded.
            t = self._engine.now
            if len(dlog) < self._tel_desc_cap:
                dlog.append((sw, desc, reason, len(desc.children), t))
            else:
                dlog.append((reason, t - desc.alloc_ns))
        self._fwd_host(sim, sw, out)

    def on_descriptor_timeout(self, sw: int, desc: Descriptor) -> None:
        self._fire_descriptor(sw, desc, reason="timeout")

    def on_switch_bcast(self, sw: int, pkt: Packet) -> None:
        sim = self.sim
        desc = self._tables[sw].get(pkt.id)
        if desc is None:
            # collision happened here during reduce: drop; the leader's
            # restoration packet re-attaches this subtree (§3.2.1)
            return
        if self._trace is not None:
            self._trace.on_bcast_fanout(sw, pkt, desc.children)
        out_port_send = sim.net.out_port_send
        for port in desc.children:
            out_port_send(sim, sw, port, pkt)
        self._switch.dealloc(sw, desc)


@register_algorithm(Algo.STATIC_TREE)
class StaticTreeStrategy(AggregationStrategy):
    """N statically-configured reduction trees (N=1 ~ SHARP/SwitchML/ATP;
    N=4 ~ PANAMA). Roots are drawn from the topology's root candidates; the
    per-switch expected-children plan comes from
    :meth:`~.topology.Topology.static_expected`, so the same strategy runs on
    any registered topology."""

    uses_switch_memory = True
    fail_resend_bypass = True

    def __init__(self, sim):
        super().__init__(sim)
        self._tables = sim.switch.tables
        self._desc_high = sim.switch.desc_high
        self.roots: Dict[int, List[int]] = {}          # app -> tree roots
        self.plans: Dict[tuple, Dict[int, int]] = {}   # (app, root) -> plan

    def setup_job(self, app: int, job, parts: List[int]) -> None:
        sim = self.sim
        cands = sim.net.root_candidates()
        roots = [cands[sim.rng.randrange(len(cands))]
                 for _ in range(sim.n_trees)]
        self.roots[app] = roots
        for root in roots:
            if (app, root) not in self.plans:
                self.plans[(app, root)] = sim.net.static_expected(parts, root)
        super().setup_job(app, job, parts)

    def root_of(self, app: int, block: int) -> int:
        roots = self.roots[app]
        return roots[block % len(roots)]

    def on_switch_reduce(self, sw: int, in_port: int, pkt: Packet) -> None:
        sim = self.sim
        if pkt.bypass:
            # admission-degraded app (host-based fallback): never part of the
            # static plan — forward straight toward the leader host
            self._fwd_host(sim, sw, pkt)
            return
        pid = pkt.id
        app = pid >> APP_SHIFT
        roots = self.roots[app]
        root = roots[((pid >> GEN_BITS) & BLOCK_MASK) % len(roots)]
        table = self._tables[sw]
        desc = table.get(pid)
        now = self._engine.now
        if desc is None:
            expected = self.plans[(app, root)][sw]
            desc = Descriptor(id=pid, slot=-1, hosts=pkt.hosts,
                              expected=expected, alloc_ns=now,
                              last_ns=now)
            table[pid] = desc
            dh = self._desc_high
            n = len(table)
            if n > dh[sw]:
                dh[sw] = n
            hi = self._tel_sw_hi
            if hi is not None:  # inlined on_desc_alloc (see CanaryStrategy)
                self._tel_desc_n += 1
                if n > hi[sw]:
                    hi[sw] = n
        desc.children.add(in_port)
        desc.value += pkt.value
        desc.counter += pkt.counter
        desc.last_ns = now
        trace = self._trace
        if trace is not None:
            trace.on_switch_merge(sw, desc, in_port, pkt)
        if len(desc.children) < desc.expected:
            self._pool.free(pkt)
            return
        if sw != root:
            out = self._pool.alloc()
            out.kind = PacketKind.REDUCE
            out.dest = -1
            out.id = pid
            out.counter = desc.counter
            out.hosts = pkt.hosts
            out.value = desc.value
            out.size_bytes = self._mtu
            out.src = -1  # switch-originated aggregate (see CanaryStrategy)
            if trace is not None:
                trace.on_desc_flush(sw, desc, out, "complete")
            dlog = self._tel_desc_log
            if dlog is not None:  # inlined on_desc_flush (see CanaryStrategy)
                if len(dlog) < self._tel_desc_cap:
                    dlog.append((sw, desc, "complete",
                                 len(desc.children), now))
                else:
                    dlog.append(("complete", now - desc.alloc_ns))
            sim.net.static_send_up(sim, sw, root, out)
            desc.sent = True
        else:
            bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pid,
                        value=desc.value, multicast=True,
                        size_bytes=self._mtu)
            if trace is not None:
                trace.on_static_root_done(sw, desc)
                trace.on_bcast_fanout(sw, bc, desc.children)
            out_port_send = sim.net.out_port_send
            for port in desc.children:
                out_port_send(sim, sw, port, bc)
            table.pop(pid, None)
            dlog = self._tel_desc_log
            if dlog is not None:  # inlined on_desc_flush (see CanaryStrategy)
                if len(dlog) < self._tel_desc_cap:
                    dlog.append((sw, desc, "complete",
                                 len(desc.children), now))
                else:
                    dlog.append(("complete", now - desc.alloc_ns))
        self._pool.free(pkt)

    def on_switch_bcast(self, sw: int, pkt: Packet) -> None:
        sim = self.sim
        table = self._tables[sw]
        desc = table.get(pkt.id)
        if desc is None:
            return
        net = sim.net
        if self._trace is not None:
            self._trace.on_bcast_fanout(
                sw, pkt,
                [p for p in desc.children if not net.is_up_port(sw, p)])
        out_port_send = net.out_port_send
        is_up_port = net.is_up_port
        for port in desc.children:
            if is_up_port(sw, port):
                continue  # never broadcast back up the tree
            out_port_send(sim, sw, port, pkt)
        table.pop(pkt.id, None)
