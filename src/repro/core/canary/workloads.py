"""Workload layer: background congestion traffic and sender-side noise.

The paper's evaluation (§5.2) surrounds the allreduce with two disturbance
models, both of which live here rather than in the host protocol:

* **Random-uniform congestion** — every non-participant "noise host" streams
  ``noise_msg_bytes``-sized messages to uniformly re-drawn noise-host peers.
  The background jobs and the allreduce are distinct applications: noise
  flows target noise hosts, sharing the fabric (leaf/spine links) with the
  allreduce but not the participants' NICs.
* **Sender OS noise (§5.2.5)** — with probability ``noise_prob`` a host's
  next send is delayed by ``noise_delay_ns``, emulating jittery sender
  stacks.

Both consume the simulator's single RNG stream, so runs stay reproducible.

Hot-path note (ARCHITECTURE.md §Performance): noise generation is *batched*
per message — the one RNG peer draw still happens exactly when the first
packet of a message is pumped (so the RNG stream and event order are
bit-identical to per-packet generation), but all of the message's packets are
materialized into a per-host buffer in one pass and handed out by ``pop`` on
subsequent pumps. The buffer is consulted at the same priority point as
before (after protocol sends, never ahead of them).
"""
from __future__ import annotations

from typing import List, Optional

from .types import Packet, PacketKind


class CongestionWorkload:
    """Background-traffic generation + sender-noise decisions."""

    def __init__(self, sim, noise_hosts: Optional[List[int]]):
        self.sim = sim
        self.noise_hosts = list(noise_hosts or [])
        self._noise_set = set(self.noise_hosts)
        cfg = sim.cfg
        self._noise_prob = cfg.noise_prob
        self._noise_delay = cfg.noise_delay_ns
        self._msg_bytes = cfg.noise_msg_bytes
        self._payload = cfg.payload_bytes
        self._header = cfg.header_bytes
        self._rng = sim.rng

    def start(self) -> None:
        """Kick every noise host's pump at t=0 (after job setup)."""
        for h in self.noise_hosts:
            self.sim.hostproto.schedule_pump(h, 0.0)

    def next_noise_packet(self, host: int, hs) -> Optional[Packet]:
        """The next background-traffic packet for ``host`` (None when the
        host is not a noise host). ``hs`` is the host's ``_HostState``; its
        ``noise_buf`` holds the rest of the current message, pre-built."""
        buf = hs.noise_buf
        if buf:
            return buf.pop()
        if host not in self._noise_set:
            return None
        hosts = self.noise_hosts
        n = len(hosts)
        if n < 2:
            return None  # a lone noise host has no peer to stream to
        # random-uniform pattern *among the congestion hosts* (§5.2) — the
        # draw happens at the first packet of each message, exactly as in
        # per-packet generation
        rng = self._rng
        peer = hosts[rng.randrange(n)]
        while peer == host:
            peer = hosts[rng.randrange(n)]
        hs.noise_peer = peer
        hs.noise_msg_idx = idx = hs.noise_msg_idx + 1
        # batch-build the whole message, last packet first so buf.pop()
        # yields packets in transmission order
        payload = self._payload
        header = self._header
        remaining = self._msg_bytes
        alloc = self.sim.pool.alloc
        if remaining <= 0:
            # degenerate config: header-only packet per pump, like the old
            # per-packet generator (peer redrawn every call)
            pkt = alloc()
            pkt.kind = PacketKind.NOISE
            pkt.dest = peer
            pkt.id = 0
            pkt.size_bytes = header
            pkt.src = host
            pkt.chunk = idx
            hs.noise_remaining = 0
            return pkt
        first: Optional[Packet] = None
        while remaining > 0:
            take = payload if remaining >= payload else remaining
            remaining -= take
            pkt = alloc()
            pkt.kind = PacketKind.NOISE
            pkt.dest = peer
            pkt.id = 0
            pkt.size_bytes = take + header
            pkt.src = host
            pkt.chunk = idx
            if first is None:
                first = pkt
            else:
                buf.append(pkt)
        buf.reverse()
        hs.noise_remaining = 0
        return first

    def sender_delay_ns(self, host: int) -> Optional[float]:
        """§5.2.5 sender-side OS noise: delay the pending send or not."""
        if self._noise_prob > 0.0 and self._rng.random() < self._noise_prob:
            return self._noise_delay
        return None
