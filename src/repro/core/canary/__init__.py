"""Faithful packet-level reproduction of Canary (§3-§5 of the paper).

Layered architecture (see ``ARCHITECTURE.md``): ``engine`` (event loop) /
``topology`` + ``network`` (fabrics) / ``switch`` (dataplane + algorithm
registry) / ``hostproto`` (host protocol) / ``workloads`` (disturbance
models), behind the :class:`Simulator` facade.
"""
from .algorithms import ExperimentResult, compare_algorithms, run_allreduce
from .backends import (BACKENDS, Backend, PacketBackend, get_backend,
                       register_backend, run_cells)
from .engine import EventLoop
from .hostproto import HostProtocol, RingStrategy
from .memory_model import OccupancyModel, model_for, paper_example
from .network import FatTree
from .simulator import Simulator, contribution
from .switch import (ALGORITHMS, AggregationStrategy, CanaryStrategy,
                     StaticTreeStrategy, SwitchLayer, make_strategy,
                     register_algorithm)
from .topology import (TOPOLOGIES, Link, ThreeTierFatTree, Topology,
                       make_topology, register_topology)
from .types import (PAPER_SCALES, Algo, AllreduceJob, Descriptor,
                    LoadBalancing, Packet, PacketKind, SimConfig, SimResult,
                    TenantSpec, block_key, id_app, id_block, id_gen, make_id,
                    paper_config, paper_scale_config, scaled_config,
                    three_tier_config)
from .workloads import CongestionWorkload

__all__ = [
    "ALGORITHMS", "Algo", "AllreduceJob", "AggregationStrategy",
    "BACKENDS", "Backend", "CanaryStrategy", "CongestionWorkload",
    "Descriptor", "EventLoop", "ExperimentResult", "FatTree", "HostProtocol",
    "Link", "LoadBalancing", "OccupancyModel", "PAPER_SCALES", "Packet",
    "PacketBackend", "PacketKind", "RingStrategy", "SimConfig", "SimResult",
    "Simulator", "StaticTreeStrategy", "SwitchLayer", "TOPOLOGIES",
    "TenantSpec", "ThreeTierFatTree", "Topology", "block_key",
    "compare_algorithms", "contribution", "get_backend", "id_app",
    "id_block", "id_gen", "make_id", "make_strategy", "make_topology",
    "model_for", "paper_example", "paper_config", "paper_scale_config",
    "register_algorithm", "register_backend", "register_topology",
    "run_allreduce", "run_cells", "scaled_config", "three_tier_config",
]
