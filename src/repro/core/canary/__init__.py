"""Faithful packet-level reproduction of Canary (§3-§5 of the paper)."""
from .algorithms import ExperimentResult, compare_algorithms, run_allreduce
from .memory_model import OccupancyModel, model_for, paper_example
from .simulator import Simulator, contribution
from .types import (Algo, AllreduceJob, Descriptor, LoadBalancing, Packet,
                    PacketKind, SimConfig, SimResult, block_key, id_app,
                    id_block, id_gen, make_id, paper_config, scaled_config)

__all__ = [
    "Algo", "AllreduceJob", "Descriptor", "ExperimentResult", "LoadBalancing",
    "OccupancyModel", "Packet", "PacketKind", "SimConfig", "SimResult",
    "Simulator", "block_key", "compare_algorithms", "contribution", "id_app",
    "id_block", "id_gen", "make_id", "model_for", "paper_example",
    "paper_config", "run_allreduce", "scaled_config",
]
