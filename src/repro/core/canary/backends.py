"""Simulation-backend registry: one experiment grid, pluggable executors.

A *backend* answers the question "what does this allreduce experiment cell
measure?" — the packet engine answers it by dispatching every packet as a
discrete event (exact, the reference), a flow-level model answers it by
solving a bandwidth-sharing problem over the same topology (approximate,
orders of magnitude faster at paper scale). Both consume the same
*work-item* dicts that ``benchmarks/sweep.py`` expands a suite into::

    {label, algo, n_trees, congestion, num_hosts, data_bytes, rep,
     topology, cfg: dataclasses.asdict(SimConfig), [lb]}

and both produce the same cell dicts (``label``/``rep``/``goodput_gbps``/
``runtime_us``/``correct``/``wall_s`` plus backend-specific diagnostics),
so sweeps, figures and the validation harness can swap executors with a
string.

The registry follows the ``ALGORITHMS`` / ``TOPOLOGIES`` pattern: a
string-keyed dict of *factories*. Factories (not instances) so that the
flow backend can defer its jax import until the first time someone actually
selects ``backend="flow"`` — ``import repro.core.canary`` stays jax-free
(the contract pinned by ``tests/flow/test_flow_backend.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Protocol


class Backend(Protocol):
    """What a simulation backend must provide."""

    name: str

    def run_cells(self, items: List[dict]) -> List[dict]:
        """Execute a list of sweep work items, one result dict per item
        (same order). Implementations may batch across items."""
        ...


BACKENDS: Dict[str, Callable[[], "Backend"]] = {}


def register_backend(name: str):
    """Class/factory decorator: ``@register_backend("mine")`` over a zero-arg
    callable returning a :class:`Backend`."""

    def deco(factory: Callable[[], "Backend"]):
        BACKENDS[name] = factory
        return factory

    return deco


def get_backend(name: str) -> "Backend":
    try:
        factory = BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r} "
                       f"(have: {', '.join(sorted(BACKENDS))})") from None
    return factory()


def item_config(item: dict):
    """Reconstruct the :class:`SimConfig` a work item describes (shared by
    every backend so they simulate the *same* world)."""
    from .types import SimConfig
    cfg = SimConfig(**item["cfg"])
    if "lb" in item:
        cfg = dataclasses.replace(cfg, lb=item["lb"])
    return cfg


@register_backend("packet")
class PacketBackend:
    """The discrete-event reference: exact packet-level execution."""

    name = "packet"

    def run_cell(self, item: dict) -> dict:
        from .algorithms import run_allreduce
        from .types import Algo
        cfg = item_config(item)
        t0 = time.perf_counter()
        # rep0 makes sweep cell r identical to rep r of a serial
        # run_allreduce(reps=R) call — one rep per work item, so a pool
        # load-balances cells, not whole experiments
        res = run_allreduce(cfg, Algo(item["algo"]), item["num_hosts"],
                            item["data_bytes"], n_trees=item["n_trees"],
                            congestion=item["congestion"], reps=1,
                            rep0=item["rep"])
        wall = time.perf_counter() - t0
        cell = dict(label=item["label"], rep=item["rep"],
                    goodput_gbps=res.goodput_gbps_mean,
                    runtime_us=res.runtime_us_mean,
                    avg_utilization=res.avg_utilization,
                    correct=res.correct,
                    events=res.reps[0].events,
                    wall_s=wall)
        if cfg.telemetry:
            cell["telemetry"] = res.reps[0].telemetry_summary
        return cell

    def run_cells(self, items: List[dict]) -> List[dict]:
        return [self.run_cell(it) for it in items]


@register_backend("flow")
def _flow_backend():
    # lazy: pulling the flow package is what (eventually) pulls jax
    from repro.core.flow import FlowBackend
    return FlowBackend()


def run_cells(items: List[dict], backend: str = "packet") -> List[dict]:
    """Convenience one-shot: ``get_backend(backend).run_cells(items)``."""
    return get_backend(backend).run_cells(items)
