"""Host protocol layer: send pump, leader recovery, retransmission.

Everything a *host* NIC/CPU does lives here (see ``ARCHITECTURE.md``):

* :class:`HostProtocol` — per-host send queues and the pump (one in-flight
  packet per NIC, rescheduled at line rate), block-completion accounting, and
  the Canary leader role: final aggregation (§3.1.4), broadcast +
  tree-restoration kickoff (§3.2.1), loss recovery and generation management
  (§3.3).
* :class:`RingStrategy` — the host-based ring allreduce baseline. It is an
  :class:`~.switch.AggregationStrategy` like CANARY/STATIC_TREE, registered
  in the same registry; switches simply forward its packets (the base-class
  default), which is precisely what makes it "host-based".

Hot-path notes (ARCHITECTURE.md §Performance): ``handle_pump`` is the
``EV_PUMP`` handler itself (no facade trampoline) and draws from pre-resolved
bindings set up in :meth:`HostProtocol.finalize`; ``arrive`` recycles every
*linear* (non-multicast) packet through ``sim.pool`` once it has been fully
processed — a packet delivered to a host is at end-of-life unless it is a
multicast broadcast fan-out, whose object is shared across links.
"""
from __future__ import annotations

from collections import deque
from heapq import heappush as _heappush
from typing import Deque, Dict, List, Optional, Set, Tuple

from .engine import EV_LEADER_DONE, EV_PUMP, EV_RETX
from .switch import AggregationStrategy, register_algorithm
from .types import (APP_SHIFT, Algo, BLOCK_MASK, GEN_BITS, Packet, PacketKind,
                    id_app, id_block, id_gen, make_id)

_MAX_GEN = (1 << GEN_BITS) - 1
_K_REDUCE = int(PacketKind.REDUCE)
_K_BCAST = int(PacketKind.BCAST)
_K_RETX_REQ = int(PacketKind.RETX_REQ)
_K_FAIL = int(PacketKind.FAIL)
_K_UNICAST = int(PacketKind.UNICAST_DATA)
_K_NOISE = int(PacketKind.NOISE)

# Transport-policy verdicts for the pump gate (repro.core.transport.base):
# ``before_send`` returns one of these sentinels (identity-compared), a float
# release time, or None to let the packet go. TX_PAUSED parks the packet in
# ``pending`` with NO pump event scheduled — the policy's resume event must
# call ``schedule_pump``. TX_ABSORBED means the policy took ownership of the
# packet (window stall, stale clone); the pump re-fires immediately for the
# host's next packet.
TX_PAUSED = object()
TX_ABSORBED = object()


class _HostState:
    __slots__ = ("queue", "pending", "pump_scheduled", "noise_peer",
                 "noise_remaining", "noise_msg_idx", "noise_buf",
                 "send_cursor")

    def __init__(self) -> None:
        self.queue: Deque[Packet] = deque()
        self.pending: Optional[Packet] = None
        self.pump_scheduled = False
        self.noise_peer = -1
        self.noise_remaining = 0
        self.noise_msg_idx = 0
        # rest of the current background-noise message, pre-built (the
        # workload layer batches generation per message; see workloads.py)
        self.noise_buf: List[Packet] = []
        # lazy cursor over this host's allreduce contributions: [app, next_block]
        self.send_cursor: List[List[int]] = []


class _LeaderState:
    __slots__ = ("value", "counter", "gen", "restorations", "done",
                 "last_fail_ns", "pending_done", "contributed")

    def __init__(self) -> None:
        self.value = 0
        self.counter = 0
        self.gen = 0
        self.restorations: List[Tuple[int, int]] = []
        self.done = False
        self.pending_done = False
        self.last_fail_ns = -1e18
        # go-back-N only: src hosts already merged into the current partial.
        # Lets saturated-generation resends accumulate without double counts
        # (under "none" the set stays empty — generation discipline dedups).
        self.contributed: Set[int] = set()


class HostProtocol:
    """Per-host send machinery + the leader/reliability protocol."""

    def __init__(self, sim, num_hosts: int):
        self.sim = sim
        self.hosts = [_HostState() for _ in range(num_hosts)]
        self.host_gen: Dict[Tuple[int, int, int], int] = {}  # (host, app, block)
        self.leader_state: Dict[Tuple[int, int], _LeaderState] = {}
        self.completed_total: Dict[Tuple[int, int], int] = {}
        self.fallback_blocks: Set[Tuple[int, int]] = set()
        # per-run hot-path bindings, filled by finalize()
        self._engine = sim.engine
        self._push = sim.engine.push
        self._push_timer = sim.engine.push_timer
        self._send_from_host = sim.net.send_from_host
        self._pool_free = sim.pool.free
        self._next_strategy_pkt = None
        self._on_host_packet = None
        self._next_noise_pkt = None
        self._sender_delay = None
        self._noise_prob = sim.cfg.noise_prob
        # transport policy (None under the default "none" — every hook below
        # is guarded by one identity check, the trace-recorder pattern)
        self._transport = None
        self._telemetry = None
        # telemetry site state, installed by Telemetry.start(): the hub's
        # block_left countdown dict while spans are on, else None — one load
        # + identity check gates the whole completion hook
        self._tel_left = None
        self._fail_resend_bypass = False
        self._gbn = False  # transport owns block retx (go-back-N recovery)
        # fault-injection state (repro.core.faults): the schedule object
        # (None without one — every hook is one identity check) and the
        # live paused-host set a host_slow fault installs
        self._faults = None
        self._fault_paused = None

    def finalize(self) -> None:
        """Pre-resolve the strategy/workload callables (both layers are
        constructed after this one). Called by the facade once per run."""
        sim = self.sim
        self._next_strategy_pkt = sim.strategy.next_host_packet
        self._on_host_packet = sim.strategy.on_host_packet
        self._next_noise_pkt = sim.workload.next_noise_packet
        self._sender_delay = sim.workload.sender_delay_ns
        self._transport = sim.transport
        self._telemetry = sim.telemetry
        self._fail_resend_bypass = sim.strategy.fail_resend_bypass
        self._gbn = self._transport is not None \
            and self._transport.owns_block_retx
        self._faults = getattr(sim, "faults", None)

    # ------------------------------------------------------------ send pump
    def schedule_pump(self, host: int, t: float) -> None:
        hs = self.hosts[host]
        if not hs.pump_scheduled:
            hs.pump_scheduled = True
            self._push(t, EV_PUMP, host, 0, None)

    def handle_pump(self, host: int, _b: int, _c: object) -> None:
        """The ``EV_PUMP`` handler: send this host's next packet, if any."""
        hs = self.hosts[host]
        hs.pump_scheduled = False
        sim = self.sim
        if self._engine.stop:  # == sim.all_done(): set in job_finished
            return
        fp = self._fault_paused
        if fp is not None and host in fp:
            # host_slow fault (repro.core.faults): the straggler's pump is
            # parked; the heal re-pumps every paused host
            return
        pkt = hs.pending
        if pkt is None:
            queue = hs.queue
            if queue:
                pkt = queue.popleft()
            else:
                # the strategy walk reads only send_cursor (contract shared
                # by every strategy: queue-driven ones enqueue into hs.queue)
                pkt = self._next_strategy_pkt(host) if hs.send_cursor else None
                if pkt is None:
                    buf = hs.noise_buf
                    pkt = buf.pop() if buf \
                        else self._next_noise_pkt(host, hs)
                    if pkt is None:
                        return
            # §5.2.5 sender-side OS noise: delay this send with probability p.
            if self._noise_prob:
                delay = self._sender_delay(host)
                if delay is not None:
                    hs.pending = pkt
                    hs.pump_scheduled = True
                    self._push(self._engine.now + delay, EV_PUMP, host, 0,
                               None)
                    return
        else:
            hs.pending = None
        tp = self._transport
        if tp is not None:
            verdict = tp.before_send(host, pkt)
            if verdict is not None:
                if verdict is TX_PAUSED:
                    # parked until the policy's resume event re-pumps; no
                    # event outstanding, so pump_scheduled must stay False
                    hs.pending = pkt
                    return
                if verdict is TX_ABSORBED:
                    # policy took the packet (window stall / stale clone);
                    # immediately try the host's next packet
                    hs.pump_scheduled = True
                    self._push(self._engine.now, EV_PUMP, host, 0, None)
                    return
                # float: rate-paced — hold the packet until the release time
                hs.pending = pkt
                hs.pump_scheduled = True
                self._push(verdict, EV_PUMP, host, 0, None)
                return
        nic_free = self._send_from_host(sim, host, pkt)
        if tp is not None:
            nic_free = tp.after_send(host, pkt, nic_free)
        hs.pump_scheduled = True
        eng = self._engine
        eng._seq = seq = eng._seq + 1
        _heappush(eng.heap, (nic_free, seq, EV_PUMP, host, 0, None))

    # ----------------------------------------------------------- completion
    def complete_at_host(self, host: int, app: int, block: int,
                         value: int) -> None:
        sim = self.sim
        flags = sim.have.get((app, host))
        if flags is None or flags[block]:
            return
        flags[block] = 1
        if sim.trace is not None:
            sim.trace.on_host_complete(host, app, block)
        # telemetry hot-site inlining: _tel_left IS the hub's per-block
        # countdown dict (spans on) — decrement in place and only pay a call
        # for the LAST completion of a block, which closes its lifecycle span
        tl = self._tel_left
        if tl is not None:
            arr = tl[app]
            n = arr[block] - 1
            arr[block] = n
            if n <= 0:
                self._telemetry.on_block_complete(host, app, block)
        tp = self._transport
        if tp is not None and tp.owns_block_retx:
            tp.on_block_complete(host, app, block)
            # memo the reduced value at the leader so later RETX_REQs can be
            # served even when the completion path bypassed leader_block_done
            if host == sim.leader_of(app, block):
                key = (app, block)
                if key not in self.completed_total:
                    self.completed_total[key] = value
        if value != sim.expected_total(app, block):
            sim.mismatches += 1
        remaining = sim.app_remaining[app] - 1
        sim.app_remaining[app] = remaining
        sim.completed_blocks += 1
        if remaining == 0:
            sim.job_finished(app)

    # ---------------------------------------------------------- leader role
    def leader_block_done(self, host: int, app: int, block: int,
                          total: int) -> None:
        sim = self.sim
        key = (app, block)
        st = self.leader_state.get(key)
        if st is None or st.done:
            return
        st.done = True
        self.completed_total[key] = total
        if self._telemetry is not None:
            # before complete_at_host: the broadcast sub-span opens at the
            # leader-done instant, ahead of any participant completion
            self._telemetry.on_leader_done(host, app, block)
        self.complete_at_host(host, app, block, total)
        if sim.jobs[app].collective == "reduce":
            return  # §6: a reduce skips the broadcast phase entirely
        pid = make_id(app, block, st.gen)
        cfg = sim.cfg
        if key in self.fallback_blocks or app in sim.bypass_apps:
            # host-based fallback (§3.3): no descriptors exist — unicast result
            for h in sim.leaders[app]:
                if h == host:
                    continue
                up = Packet(kind=PacketKind.UNICAST_DATA, dest=h, id=pid,
                            value=total, size_bytes=cfg.mtu_bytes, src=host)
                self.hosts[host].queue.append(up)
        else:
            # broadcast down the recorded tree (§3.1.2)
            bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pid, value=total,
                        multicast=True, size_bytes=cfg.mtu_bytes)
            self.hosts[host].queue.append(bc)
            # tree restoration for collided subtrees (§3.2.1)
            by_switch: Dict[int, List[int]] = {}
            for sw_addr, port in st.restorations:
                by_switch.setdefault(sw_addr, []).append(port)
            for sw_addr, ports in by_switch.items():
                sim.restorations += 1
                rp = Packet(kind=PacketKind.RESTORE, dest=-1, id=pid,
                            value=total, dest_switch=sw_addr,
                            restore_ports=tuple(set(ports)),
                            size_bytes=cfg.mtu_bytes)
                if sim.trace is not None:
                    sim.trace.on_restore(pid, sw_addr, rp.restore_ports)
                self.hosts[host].queue.append(rp)
        self.schedule_pump(host, sim.now)

    # --------------------------------------------------------- host arrival
    def handle_arrive(self, host: int, _b: int, pkt: Packet) -> None:
        """The ``EV_ARRIVE_HOST`` handler. Processes the packet, then
        recycles it unless it is a shared multicast object."""
        sim = self.sim
        tp = self._transport
        if tp is not None:
            # CNP/ACK consumption, ECN-echo, go-back-N sequencing. A None
            # return means the policy consumed (and recycled) the packet.
            pkt = tp.on_receive(host, pkt)
            if pkt is None:
                return
        kind = pkt.kind
        if kind == _K_NOISE:
            self._pool_free(pkt)
            return
        if self._on_host_packet(host, pkt):
            if not pkt.multicast:
                self._pool_free(pkt)
            return
        pid = pkt.id
        app = pid >> APP_SHIFT
        block = (pid >> GEN_BITS) & BLOCK_MASK
        if kind == _K_REDUCE:
            if sim.leader_of(app, block) == host:
                key = (app, block)
                st = self.leader_state.get(key)
                if st is None:
                    st = self.leader_state[key] = _LeaderState()
                gen = pid & _MAX_GEN
                if not (st.done or st.pending_done or gen != st.gen) \
                        and not (self._gbn and pkt.src >= 0
                                 and pkt.src in st.contributed):
                    if self._gbn and pkt.src >= 0:
                        st.contributed.add(pkt.src)
                    st.value += pkt.value
                    st.counter += pkt.counter
                    if sim.trace is not None:
                        sim.trace.on_leader_merge(host, pkt)
                    if pkt.switch_addr >= 0:
                        st.restorations.append((pkt.switch_addr,
                                                pkt.port_stamp))
                    if st.counter >= sim.nparts[app] - 1:
                        total = st.value + sim.contribution_of(app, block,
                                                               host)
                        st.pending_done = True
                        if sim.trace is not None:
                            sim.trace.on_leader_complete(host, app, block,
                                                         gen)
                        # leader-side aggregation cost r (§3.2.2)
                        self._push(self._engine.now
                                   + sim.cfg.leader_aggregate_ns,
                                   EV_LEADER_DONE, host, 0,
                                   (app, block, total))
            self._pool_free(pkt)
            return
        if kind == _K_BCAST or kind == _K_UNICAST:
            self.complete_at_host(host, app, block, pkt.value)
            if not pkt.multicast:
                self._pool_free(pkt)
            return
        if kind == _K_RETX_REQ:
            self.leader_handle_retx(host, app, block, pkt.src)
            self._pool_free(pkt)
            return
        if kind == _K_FAIL:
            self.host_handle_fail(host, pkt)
            self._pool_free(pkt)
            return

    def arrive(self, host: int, pkt: Packet) -> None:
        """Compat entry point (the engine dispatches ``handle_arrive``)."""
        self.handle_arrive(host, 0, pkt)

    # ----------------------------------------------------------- reliability
    def leader_handle_retx(self, leader: int, app: int, block: int,
                           requester: int) -> None:
        sim = self.sim
        cfg = sim.cfg
        key = (app, block)
        total = self.completed_total.get(key)
        if total is not None:
            # loss was in the broadcast phase: retransmit reduced data (§3.3)
            up = Packet(kind=PacketKind.UNICAST_DATA, dest=requester,
                        id=make_id(app, block, 0), value=total,
                        size_bytes=cfg.mtu_bytes, src=leader)
            self.hosts[leader].queue.append(up)
            self.schedule_pump(leader, sim.now)
            return
        st = self.leader_state.get(key)
        if st is None:
            st = self.leader_state[key] = _LeaderState()
        if st.pending_done:
            return  # completion already in flight
        if sim.now - st.last_fail_ns < cfg.retx_timeout_ns / 2:
            return  # debounce: a failure round is already in flight
        st.last_fail_ns = sim.now
        newgen = min(st.gen + 1, _MAX_GEN)
        fallback = newgen >= cfg.max_generations
        if fallback and key not in self.fallback_blocks:
            sim.fallbacks += 1
            self.fallback_blocks.add(key)
            if app not in sim.bypass_apps:
                # admission-degraded apps were counted whole at activation
                sim.app_fallback_blocks[app] = \
                    sim.app_fallback_blocks.get(app, 0) + 1
                fa = self._faults
                if fa is not None and fa.any_active():
                    # generation cap hit while a fault is live: the fabric
                    # path is (probably) the casualty — escalate the whole
                    # app to the §3.3 host-based fallback rather than let
                    # later blocks spin through the cap too (the documented
                    # agg-switch livelock)
                    fa.escalate_app(app)
        # Generation ids saturate at _MAX_GEN. Under go-back-N the saturated
        # rounds keep ONE accumulating partial (src-deduped above) instead of
        # restarting — each host's resend then only has to get through once
        # ever, so recovery converges at any loss rate. Pre-saturation (and
        # always under "none") a new generation starts from scratch.
        if not (self._gbn and newgen == st.gen
                and (fallback or self._fail_resend_bypass)):
            st.value = 0
            st.counter = 0
            st.restorations = []
            st.contributed.clear()
        st.gen = newgen
        # "the leader broadcasts a failure message" (§3.3) — delivered unicast
        for h in sim.leaders[app]:
            if h == leader:
                continue
            fl = Packet(kind=PacketKind.FAIL, dest=h,
                        id=make_id(app, block, newgen),
                        counter=1 if fallback else 0,
                        size_bytes=cfg.header_bytes + 16, src=leader)
            self.hosts[leader].queue.append(fl)
        self.schedule_pump(leader, sim.now)

    def host_handle_fail(self, host: int, pkt: Packet) -> None:
        sim = self.sim
        cfg = sim.cfg
        app, block, gen = id_app(pkt.id), id_block(pkt.id), id_gen(pkt.id)
        hkey = (host, app, block)
        tp = self._transport
        gbn = self._gbn
        prev = self.host_gen.get(hkey, 0)
        if prev > gen or (prev == gen and not gbn):
            # under go-back-N a same-generation FAIL re-triggers the resend
            # (the earlier copy may have been lost; the leader's src dedup
            # absorbs duplicates) — saturated generations depend on this
            return
        flags = sim.have.get((app, host))
        if flags is not None and flags[block] and not gbn:
            # under go-back-N a completed host still re-contributes: the new
            # generation's cohort needs every contribution to converge
            return
        self.host_gen[hkey] = gen
        sim.retransmissions += 1
        if self._telemetry is not None:
            self._telemetry.on_retx("fail", host, app, block)
        fallback = pkt.counter == 1 or app in sim.bypass_apps
        # Plan-driven strategies (static tree) have no per-generation switch
        # state: a resent cohort routed through the plan waits forever for
        # the leader's (never resent) leaf contribution. Under a transport
        # that owns block recovery, resends bypass the fabric aggregation
        # and sum at the leader host instead.
        # fail_resend_bypass generalizes to mid-run deaths: with a fault
        # schedule present, a resent cohort routed through the plan could be
        # waiting on a switch whose descriptors a crash just flushed
        bypass = fallback or (self._fail_resend_bypass
                              and (gbn or self._faults is not None))
        rp = Packet(kind=PacketKind.REDUCE, dest=sim.leader_of(app, block),
                    id=make_id(app, block, gen), counter=1,
                    hosts=len(sim.leaders[app]),
                    value=sim.contribution_of(app, block, host),
                    bypass=bypass, size_bytes=cfg.mtu_bytes, src=host)
        if sim.trace is not None:
            sim.trace.on_host_send(host, rp)
        self.hosts[host].queue.append(rp)
        if gbn:
            if flags is not None and not flags[block]:
                tp.on_block_sent(host, app, block)
        else:
            self._push_timer(sim.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                             (app, block, gen))
        self.schedule_pump(host, sim.now)

    def handle_retx(self, host: int, _b: int, c: object) -> None:
        """The ``EV_RETX`` handler."""
        app, block, gen = c
        self.host_retx_check(host, app, block, gen)

    def handle_leader_done(self, host: int, _b: int, c: object) -> None:
        """The ``EV_LEADER_DONE`` handler."""
        app, block, total = c
        self.leader_block_done(host, app, block, total)

    def host_retx_check(self, host: int, app: int, block: int,
                        gen: int) -> None:
        sim = self.sim
        cfg = sim.cfg
        if sim.apps_active == 0:
            return
        flags = sim.have.get((app, host))
        if flags is None or flags[block]:
            return
        if self.host_gen.get((host, app, block), 0) > gen:
            return  # a newer generation is already in flight
        sim.retransmissions += 1
        if self._telemetry is not None:
            self._telemetry.on_retx("request", host, app, block)
        req = Packet(kind=PacketKind.RETX_REQ, dest=sim.leader_of(app, block),
                     id=make_id(app, block, gen),
                     size_bytes=cfg.header_bytes + 16, src=host)
        self.hosts[host].queue.append(req)
        self._push_timer(sim.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                         (app, block, gen))
        self.schedule_pump(host, sim.now)

    def gbn_request_block(self, host: int, app: int, block: int) -> None:
        """Re-request a block result from its leader on behalf of the
        go-back-N block flow. Unlike :meth:`host_retx_check` this never arms
        an ``EV_RETX`` timer — the transport's per-flow timer owns the retry
        cadence and calls back here each round."""
        sim = self.sim
        if sim.apps_active == 0:
            return
        flags = sim.have.get((app, host))
        if flags is None or flags[block]:
            return
        gen = self.host_gen.get((host, app, block), 0)
        sim.retransmissions += 1
        if self._telemetry is not None:
            self._telemetry.on_retx("request", host, app, block)
        req = Packet(kind=PacketKind.RETX_REQ, dest=sim.leader_of(app, block),
                     id=make_id(app, block, gen),
                     size_bytes=sim.cfg.header_bytes + 16, src=host)
        self.hosts[host].queue.append(req)
        self.schedule_pump(host, sim.now)


# --------------------------------------------------------------------------
# Host-based ring allreduce — same registry as the in-network strategies
# --------------------------------------------------------------------------
class _RingState:
    """Per-app ring-allreduce bookkeeping."""

    __slots__ = ("order", "rank", "p", "chunk_vals", "recv_count", "steps",
                 "pkts_per_chunk", "chunk_bytes", "done_steps")

    def __init__(self, order: List[int], data_bytes: int, payload: int) -> None:
        self.order = order
        self.rank = {h: r for r, h in enumerate(order)}
        self.p = len(order)
        self.chunk_bytes = max(1, -(-data_bytes // self.p))
        self.pkts_per_chunk = max(1, -(-self.chunk_bytes // payload))
        self.steps = 2 * self.p - 2
        self.chunk_vals: List[List[int]] = []
        self.recv_count: List[Dict[int, int]] = []
        self.done_steps: List[int] = []


@register_algorithm(Algo.RING)
class RingStrategy(AggregationStrategy):
    """Bandwidth-optimal host-based ring: reduce-scatter + all-gather.

    Switches only forward (base-class defaults); the whole protocol runs in
    :meth:`on_host_packet` + the per-step send enqueues."""

    def __init__(self, sim):
        super().__init__(sim)
        self.ring: Dict[int, _RingState] = {}

    def setup_job(self, app: int, job, parts: List[int]) -> None:
        sim = self.sim
        from .simulator import contribution
        rs = _RingState(parts, job.data_bytes, sim.cfg.payload_bytes)
        rs.chunk_vals = [
            [contribution(app, c, parts[r]) for c in range(rs.p)]
            for r in range(rs.p)
        ]
        rs.recv_count = [dict() for _ in range(rs.p)]
        rs.done_steps = [0] * rs.p
        self.ring[app] = rs
        for h in parts:
            self._enqueue_send(app, h, step=0)

    def next_host_packet(self, host: int) -> Optional[Packet]:
        return None  # ring sends are queue-driven, not cursor-driven

    def on_host_packet(self, host: int, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.RING:
            return False
        self._receive(host, pkt)
        return True

    # ---- protocol ----------------------------------------------------------
    def _enqueue_send(self, app: int, host: int, step: int) -> None:
        sim = self.sim
        rs = self.ring[app]
        r = rs.rank[host]
        if step > rs.steps - 1:
            return
        c = (r - step) % rs.p
        dest = rs.order[(r + 1) % rs.p]
        val = rs.chunk_vals[r][c]
        payload = sim.cfg.payload_bytes
        header = sim.cfg.header_bytes
        alloc = self._pool.alloc
        remaining = rs.chunk_bytes
        last = rs.pkts_per_chunk - 1
        queue = sim.hostproto.hosts[host].queue
        for i in range(rs.pkts_per_chunk):
            take = payload if remaining >= payload else remaining
            remaining -= take
            pkt = alloc()
            pkt.kind = PacketKind.RING
            pkt.dest = dest
            pkt.id = app
            pkt.value = val if i == last else 0
            pkt.size_bytes = take + header
            pkt.src = host
            pkt.chunk = c
            pkt.step = step
            queue.append(pkt)
        sim.hostproto.schedule_pump(host, sim.now)

    def _receive(self, host: int, pkt: Packet) -> None:
        app = pkt.id
        rs = self.ring[app]
        r = rs.rank[host]
        counts = rs.recv_count[r]
        step = pkt.step
        got = counts.get(step, 0) + 1
        counts[step] = got
        if pkt.value:
            if step < rs.p - 1:
                rs.chunk_vals[r][pkt.chunk] += pkt.value  # reduce-scatter phase
            else:
                rs.chunk_vals[r][pkt.chunk] = pkt.value   # all-gather phase
        if got < rs.pkts_per_chunk:
            return
        counts.pop(step, None)
        rs.done_steps[r] += 1
        if step + 1 <= rs.steps - 1:
            self._enqueue_send(app, host, step + 1)
        # steps can *complete* out of order when paths differ; the host is
        # finished only once every step's chunk has fully arrived.
        if rs.done_steps[r] == rs.steps:
            self._finish_host(app, host)

    def _finish_host(self, app: int, host: int) -> None:
        sim = self.sim
        rs = self.ring[app]
        r = rs.rank[host]
        ok = all(rs.chunk_vals[r][c] == sim.expected_total(app, c)
                 for c in range(rs.p))
        if not ok:
            sim.mismatches += 1
        flags = sim.have[(app, host)]
        newly = 0
        for b in range(sim.blocks[app]):
            if not flags[b]:
                flags[b] = 1
                newly += 1
        remaining = sim.app_remaining[app] - newly
        sim.app_remaining[app] = remaining
        sim.completed_blocks += newly
        if remaining == 0:
            sim.job_finished(app)
