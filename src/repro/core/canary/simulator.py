"""Packet-level discrete-event simulator facade for in-network allreduce (§5.2).

The simulator is layered (see ``ARCHITECTURE.md``); this module only wires
the layers together and exposes the stable public API:

* :mod:`~.engine`    — event heap, clock, dispatch.
* :mod:`~.topology`  — link fabric + routing (``fat_tree``/``three_tier``/...).
* :mod:`~.switch`    — switch dataplane + the algorithm-strategy registry
                       (``CANARY``, ``STATIC_TREE``; ``RING`` registers from
                       :mod:`~.hostproto`).
* :mod:`~.hostproto` — host send pump, leader role, loss recovery.
* :mod:`~.workloads` — background congestion + sender-noise models.

Every packet carries an exact integer payload; at the end of a run the
simulator asserts that every participant received the true sum for every
block, under any combination of congestion, stragglers, collisions, drops and
switch failures. A run is therefore both a performance measurement and an
end-to-end correctness proof of the protocol implementation.

Hot-path wiring (ARCHITECTURE.md §Performance): construction ends with a
*finalize* pass — every layer pre-resolves the callables it dispatches to per
packet, the topology binds the engine's ``push`` directly, and ``run`` hands
the engine a pre-resolved handler table indexed by event kind. ``all_done``
is O(1) via the ``apps_active`` counter, and per-app constants (leader maps,
expected totals, packet sizes) are precomputed at job setup so no hot path
re-derives them per packet.
"""
from __future__ import annotations

import gc
import random
from typing import Dict, List, Optional, Set, Tuple

from . import network as _network  # noqa: F401  (registers "fat_tree")
from .engine import (EV_ARRIVE_HOST, EV_ARRIVE_SWITCH, EV_FAIL_SWITCH,
                     EV_FAULT, EV_GBN_TIMER, EV_HEAL, EV_JOB_ARRIVE,
                     EV_LEADER_DONE, EV_LINK_ARRIVE_HOST,
                     EV_LINK_ARRIVE_SWITCH, EV_PFC_PAUSE, EV_PFC_RESUME,
                     EV_PUMP, EV_RATE_TIMER, EV_RETX, EV_TELEMETRY_PROBE,
                     EV_TIMER, EventLoop, N_EVENT_KINDS)
from .hostproto import HostProtocol
from .switch import SwitchLayer, make_strategy
from .topology import make_topology
from .types import (Algo, AllreduceJob, Packet, PacketPool, SimConfig,
                    SimResult)
from .workloads import CongestionWorkload

_CONTRIB_MULT = 1000003


def contribution(app: int, block: int, host: int) -> int:
    """Deterministic integer contribution of ``host`` to ``(app, block)``."""
    return (host + 1) * _CONTRIB_MULT + 31 * block + 7919 * app


class Simulator:
    """One simulation run. Construct, then call :meth:`run` once."""

    def __init__(self, cfg: SimConfig, jobs: List[AllreduceJob],
                 algo: Algo = Algo.CANARY, n_trees: int = 1,
                 noise_hosts: Optional[List[int]] = None,
                 admission=None):
        cfg.validate()
        self.cfg = cfg
        self.jobs = {j.app: j for j in jobs}
        try:
            self.algo = Algo(algo)
        except ValueError:
            self.algo = str(algo)  # strategy registered under a custom key
        self.n_trees = n_trees
        self.net = make_topology(cfg)
        self.rng = random.Random(cfg.seed)
        self.engine = EventLoop()
        self.pool = PacketPool()
        # hot-path drop state (tx_to_* in topology.py): the RNG is drawn
        # only when drop_prob > 0, exactly like maybe_drop()
        self._drop_prob = cfg.drop_prob
        self._rng_random = self.rng.random

        # opt-in aggregation-provenance recording (repro.core.trace). The
        # recorder is observation-only: every layer guards its hook calls
        # with ``sim.trace is not None`` and the hooks touch no protocol
        # state, so traced runs replay the goldens bit-for-bit.
        self.trace = None
        if cfg.trace:
            from ..trace.recorder import TraceRecorder  # deferred: optional
            self.trace = TraceRecorder(self)

        # layers (construction order matters: strategies touch hostproto)
        self.switch = SwitchLayer(self, self.net.num_switches)
        self.hostproto = HostProtocol(self, cfg.num_hosts)
        self.workload = CongestionWorkload(self, noise_hosts)
        # transport policy (repro.core.transport): None under the default
        # "none", so every hook site reduces to one identity check. Deferred
        # import — the transport package imports canary modules, never the
        # other way around, and the core import graph stays jax-free.
        self.transport = None
        if cfg.transport and cfg.transport != "none":
            from ..transport import make_transport
            self.transport = make_transport(cfg.transport, self)
        self.strategy = make_strategy(self.algo, self)
        # opt-in telemetry (repro.core.telemetry): the same observation-only
        # deal as the trace recorder — ``None`` when off, so every layer
        # hook site is one guarded identity check, and on-runs replay the
        # goldens bit-for-bit (probe ticks are outside the events count).
        # Built AFTER the layers on purpose: the hub's own object graph
        # (registry, per-link series, span state) must not interleave with
        # the hot layer structures on the heap — layers resolve it in their
        # finalize step, never at construction.
        self.telemetry = None
        if cfg.telemetry:
            from ..telemetry.hub import Telemetry  # deferred: optional
            self.telemetry = Telemetry(self)
        # opt-in fault injection (repro.core.faults): same deal — ``None``
        # without a schedule, so the hot-layer hooks stay one identity check
        # (or one float compare against the link poison horizon) and
        # fault-free runs replay the goldens bit-for-bit. Built before the
        # finalize pass so hostproto can bind it.
        self.faults = None
        if cfg.faults:
            from ..faults import FaultSchedule  # deferred: optional
            self.faults = FaultSchedule(self)
        # finalize: every layer pre-resolves its per-packet callables now
        # that the full layer graph exists (ARCHITECTURE.md §Performance)
        self.switch.finalize()
        self.hostproto.finalize()
        self.net.bind(self)
        if self.transport is not None:
            self.transport.finalize()
        if self.telemetry is not None:
            self.telemetry.finalize()

        # multi-tenant fleet state (repro.core.fleet). With no admission
        # controller everything below stays empty and the dataplane behaves
        # exactly as before — the fleet layer is pay-for-what-you-use.
        self.admission = admission
        self.tenant_of: Dict[int, int] = {}            # app -> tenant
        self.slot_regions: Dict[int, Tuple[int, int]] = {}  # app -> (offset, size)
        self.bypass_apps: Set[int] = set()             # degraded: host-based §3.3 path
        self.job_submit_ns: Dict[int, float] = {}
        self.job_start_ns: Dict[int, float] = {}
        self.app_fallback_blocks: Dict[int, int] = {}
        if admission is not None:
            admission.attach(self)

        # completion tracking. ``apps_active`` counts apps with unfinished
        # blocks so ``all_done`` is O(1) — it is decremented exactly once
        # per app (in job_finished, or at activation for degenerate
        # single-participant jobs).
        self.have: Dict[Tuple[int, int], bytearray] = {}
        self.app_remaining: Dict[int, int] = {}
        self.app_done_ns: Dict[int, float] = {}
        self.apps_active = 0
        self.mismatches = 0

        # counters (mutated by the layers)
        self.stragglers = 0
        self.collisions = 0
        self.restorations = 0
        self.retransmissions = 0
        self.fallbacks = 0
        self.dropped = 0
        self.dropped_failed = 0  # subset of ``dropped``: failed-switch sink
        self.completed_blocks = 0

        # per-job precomputation (hot-path constants; see _setup_jobs)
        self.blocks: Dict[int, int] = {}
        self.leaders: Dict[int, List[int]] = {}
        self.partset: Dict[int, Set[int]] = {}
        self.contrib_sum_base: Dict[int, Tuple[int, int]] = {}
        self.nparts: Dict[int, int] = {}               # len(participants)
        self.pkt_bytes: Dict[int, int] = {}            # REDUCE wire size
        self._leader_fixed: Dict[int, int] = {}        # reduce/broadcast root
        self._contrib_root: Dict[int, int] = {}        # broadcast source
        self._barrier_apps: Set[int] = set()
        self._et_base: Dict[int, int] = {}             # expected_total =
        self._et_slope: Dict[int, int] = {}            #   base + slope * block
        self._setup_jobs()
        if self.faults is not None:
            self.faults.start()

    # ------------------------------------------------------------------ setup
    def _setup_jobs(self) -> None:
        cfg = self.cfg
        for app, job in self.jobs.items():
            parts = sorted(job.participants)
            if len(set(parts)) != len(parts):
                raise ValueError(f"duplicate participants in app {app}")
            B = job.num_blocks(cfg.payload_bytes)
            self.blocks[app] = B
            self.partset[app] = set(parts)
            self.leaders[app] = parts
            self.nparts[app] = len(parts)
            self.tenant_of[app] = job.tenant if job.tenant >= 0 else app
            s1 = sum(h + 1 for h in parts)
            self.contrib_sum_base[app] = (s1, len(parts))
            self.job_submit_ns[app] = max(0.0, job.arrival_ns)
            # hot-path constants: leader map, wire size, expected totals
            coll = job.collective
            if coll in ("reduce", "broadcast"):
                root = job.root if job.root is not None else parts[0]
                self._leader_fixed[app] = root
            self.pkt_bytes[app] = cfg.header_bytes + 8 \
                if coll == "barrier" else cfg.mtu_bytes
            if coll == "barrier":
                self._barrier_apps.add(app)
                self._et_base[app] = 0
                self._et_slope[app] = 0
            elif coll == "broadcast":
                root = self._leader_fixed[app]
                self._contrib_root[app] = root
                self._et_base[app] = (root + 1) * _CONTRIB_MULT + 7919 * app
                self._et_slope[app] = 31
            else:
                p = len(parts)
                self._et_base[app] = _CONTRIB_MULT * s1 + p * 7919 * app
                self._et_slope[app] = 31 * p
            # completion tracking is registered up front for every job —
            # including ones that arrive later — so ``all_done`` keeps the
            # engine running until open-loop arrivals have completed too.
            if coll == "reduce":
                root = self._leader_fixed[app]
                self.have[(app, root)] = bytearray(B)
                self.app_remaining[app] = B
            else:
                for h in parts:
                    self.have[(app, h)] = bytearray(B)
                self.app_remaining[app] = len(parts) * B
            self.apps_active += 1
            if job.arrival_ns > 0.0:
                self.engine.push(job.arrival_ns, EV_JOB_ARRIVE, app, 0, None)
            else:
                self._activate_job(app)
        self.workload.start()
        if cfg.switch_fail_ns is not None and cfg.failed_switch is not None:
            self.engine.push(cfg.switch_fail_ns, EV_FAIL_SWITCH,
                             cfg.failed_switch, 0, None)

    def _activate_job(self, app: int) -> None:
        """Start ``app``'s protocol: at construction (t=0 jobs), when its
        ``EV_JOB_ARRIVE`` fires, or when the admission controller retries a
        deferred job after capacity frees up."""
        job = self.jobs[app]
        parts = self.leaders[app]
        B = self.blocks[app]
        if len(parts) == 1:
            # degenerate single-participant collective: already reduced
            h = parts[0]
            flags = self.have[(app, h)]
            for b in range(B):
                flags[b] = 1
            self.app_remaining[app] = 0
            self.apps_active -= 1
            if self.apps_active == 0:
                self.engine.stop = True
            self.completed_blocks += B
            self.job_start_ns[app] = self.now
            self.app_done_ns[app] = self.now
            return
        if self.admission is not None:
            decision = self.admission.on_job_arrival(self, app, job)
            if decision == "defer":
                return  # retried via on_job_done when a slot frees up
            if decision == "degrade":
                # quota exhausted: the whole job rides the §3.3 host-based
                # path (bypass packets, leader unicasts the result)
                self.bypass_apps.add(app)
                self.app_fallback_blocks[app] = B
        self.job_start_ns[app] = self.now
        self.strategy.setup_job(app, job, parts)

    def job_finished(self, app: int) -> None:
        """All of ``app``'s blocks completed: stamp the finish time and give
        the admission controller its quota slots back."""
        self.apps_active -= 1
        if self.apps_active == 0:
            self.engine.stop = True  # loop breaks before the next dispatch
        self.app_done_ns[app] = self.now
        if self.admission is not None:
            self.admission.on_job_done(self, app)

    # ------------------------------------------------------------- protocol
    def expected_total(self, app: int, block: int) -> int:
        # precomputed affine form of the original per-call derivation; see
        # _setup_jobs (barrier: 0; broadcast: the root's contribution;
        # allreduce/reduce: MULT*s1 + p*(31*block + 7919*app))
        return self._et_base[app] + self._et_slope[app] * block

    def leader_of(self, app: int, block: int) -> int:
        root = self._leader_fixed.get(app)
        if root is not None:
            return root
        parts = self.leaders[app]
        return parts[block % len(parts)]

    def contribution_of(self, app: int, block: int, host: int) -> int:
        if app in self._barrier_apps:
            return 0
        root = self._contrib_root.get(app)
        if root is not None:  # broadcast: only the source contributes
            return contribution(app, block, root) if host == root else 0
        return contribution(app, block, host)

    # ----------------------------------------------- hooks used by the layers
    @property
    def now(self) -> float:
        return self.engine.now

    @property
    def events(self) -> int:
        return self.engine.events

    @property
    def tables(self):
        """Per-switch descriptor tables (compat accessor; state lives in the
        switch layer)."""
        return self.switch.tables

    def maybe_drop(self) -> bool:
        return self._drop_prob > 0.0 and self._rng_random() < self._drop_prob

    def arrive_switch(self, t: float, sw: int, port: int, pkt: Packet) -> None:
        self.engine.push(t, EV_ARRIVE_SWITCH, sw, port, pkt)

    def arrive_host(self, t: float, host: int, pkt: Packet) -> None:
        self.engine.push(t, EV_ARRIVE_HOST, host, 0, pkt)

    def all_done(self) -> bool:
        return self.apps_active == 0

    # -------------------------------------------------------------------- run
    def _handle_fail_switch(self, a: int, b: int, c: object) -> None:
        self.switch.fail_switch(a)

    def _handle_job_arrive(self, a: int, b: int, c: object) -> None:
        self._activate_job(a)

    def run(self) -> SimResult:
        cfg = self.cfg
        # pre-resolved handler table, indexed by event kind (engine.run
        # dispatches via one list index + call per event)
        handlers = [None] * N_EVENT_KINDS
        handlers[EV_ARRIVE_SWITCH] = self.switch.arrive
        handlers[EV_ARRIVE_HOST] = self.hostproto.handle_arrive
        # staged link arrivals dispatch to the same layer entry points (the
        # engine unwraps the Link's FIFO head into the packet argument)
        handlers[EV_LINK_ARRIVE_SWITCH] = self.switch.arrive
        handlers[EV_LINK_ARRIVE_HOST] = self.hostproto.handle_arrive
        handlers[EV_TIMER] = self.switch.on_timer
        handlers[EV_PUMP] = self.hostproto.handle_pump
        handlers[EV_RETX] = self.hostproto.handle_retx
        handlers[EV_FAIL_SWITCH] = self._handle_fail_switch
        handlers[EV_LEADER_DONE] = self.hostproto.handle_leader_done
        handlers[EV_JOB_ARRIVE] = self._handle_job_arrive
        tp = self.transport
        if tp is not None:
            handlers[EV_PFC_PAUSE] = tp.handle_pfc_pause
            handlers[EV_PFC_RESUME] = tp.handle_pfc_resume
            handlers[EV_RATE_TIMER] = tp.handle_rate_timer
            handlers[EV_GBN_TIMER] = tp.handle_gbn_timer
        tel = self.telemetry
        if tel is not None:
            handlers[EV_TELEMETRY_PROBE] = tel.handle_probe
            tel.start()  # arm the self-re-arming probe chain
        fa = self.faults
        if fa is not None:
            handlers[EV_FAULT] = fa.handle_fault
            handlers[EV_HEAL] = fa.handle_heal
        # the event loop allocates millions of short-lived tuples/packets and
        # creates no reference cycles; pausing the cyclic GC for the drain is
        # worth ~10-15% wall time (state restored on every exit path)
        gc_was_enabled = gc.isenabled()
        if gc_was_enabled:
            gc.disable()
        self.engine.stop = self.all_done()
        try:
            self.engine.run(handlers, cfg.max_events)
            if tel is not None:
                # freezes exact summary totals. Cheap by design
                # (O(counters + one pass over the flush log)); the closing
                # probe sample and the heavy span/instant decode defer to
                # the first reader of tel.spans/instants/registry (see
                # hub docstring)
                tel.finish()
        finally:
            if gc_was_enabled:
                gc.enable()
        end = max(self.app_done_ns.values()) if self.app_done_ns else self.now
        utils = self.net.utilizations(end if end > 0 else 1.0)
        goodput = {}
        for app, job in self.jobs.items():
            # JCT, not absolute finish: identical for t=0 jobs, and the only
            # meaningful denominator for open-loop (late-arriving) jobs
            dur = self.app_done_ns.get(app, self.now) - self.job_submit_ns[app]
            goodput[app] = (job.data_bytes * 8.0) / dur if dur > 0 else 0.0
        maxdesc = max(self.switch.desc_high) if self.switch.desc_high else 0
        # per-cause drop split + transport telemetry (additive SimResult
        # fields: the golden contract pins only the pre-existing ones)
        tele = self.transport.telemetry() if self.transport is not None else {}
        host_rates = tele.pop("host_rate_gbps", {})
        fault_dropped = sum(fa.drop_counts.values()) if fa is not None else 0
        drop_causes = {
            "wire": self.dropped - self.dropped_failed - fault_dropped,
            "switch_fail": self.dropped_failed}
        if fa is not None:
            # fault drops merge by cause ("switch_fail" folds into the
            # failed-switch sink; "link_down" is its own bucket)
            for cause, n in fa.drop_counts.items():
                drop_causes[cause] = drop_causes.get(cause, 0) + n
            fault_exposure, fault_recovery, survived = fa.finish()
        else:
            fault_exposure = fault_recovery = survived = {}
        if "gbn_ooo" in tele:
            drop_causes["gbn_ooo_discard"] = tele["gbn_ooo"]
        return SimResult(
            duration_ns=end,
            start_ns=0.0,
            goodput_gbps=goodput,
            correct=(self.mismatches == 0 and self.all_done()),
            link_utilization=utils,
            avg_utilization=sum(utils) / len(utils) if utils else 0.0,
            stragglers=self.stragglers,
            collisions=self.collisions,
            restorations=self.restorations,
            retransmissions=self.retransmissions,
            fallbacks=self.fallbacks,
            max_descriptors_per_switch=maxdesc,
            max_descriptor_bytes=maxdesc * cfg.mtu_bytes,
            events=self.events,
            dropped_packets=self.dropped,
            completed_blocks=self.completed_blocks,
            job_submit_ns=dict(self.job_submit_ns),
            job_start_ns=dict(self.job_start_ns),
            job_finish_ns=dict(self.app_done_ns),
            job_admitted={a: a not in self.bypass_apps for a in self.jobs},
            app_fallback_blocks=dict(self.app_fallback_blocks),
            tenant_of=dict(self.tenant_of),
            transport=str(cfg.transport),
            drop_causes=drop_causes,
            transport_stats=tele,
            host_rate_gbps=host_rates,
            telemetry_summary=(tel.summary_dict() if tel is not None else {}),
            fault_events=(list(fa.events) if fa is not None else []),
            fault_exposure_ns=fault_exposure,
            fault_recovery_ns=fault_recovery,
            survived=survived,
        )
