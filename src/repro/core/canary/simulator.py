"""Packet-level discrete-event simulator for in-network allreduce (§5.2).

Implements the three algorithm families the paper evaluates:

* ``Algo.CANARY``       — dynamic trees, timeout aggregation, collisions +
                          tree restoration, leader host, loss recovery (§3).
* ``Algo.STATIC_TREE``  — N statically-configured reduction trees
                          (N=1 ~ SHARP/SwitchML/ATP; N=4 ~ PANAMA).
* ``Algo.RING``         — bandwidth-optimal host-based ring allreduce.

plus a background random-uniform congestion workload (§5.2) and the §5.2.5
sender-noise model.

Every packet carries an exact integer payload; at the end of a run the
simulator asserts that every participant received the true sum for every
block, under any combination of congestion, stragglers, collisions, drops and
switch failures. A run is therefore both a performance measurement and an
end-to-end correctness proof of the protocol implementation.
"""
from __future__ import annotations

import heapq
import random
from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

from .network import FatTree
from .types import (Algo, AllreduceJob, Descriptor, Packet, PacketKind,
                    SimConfig, SimResult, GEN_BITS, id_app, id_block, id_gen,
                    make_id)

# Event kinds (heap entries are (time, seq, kind, a, b, c) tuples).
EV_ARRIVE_SWITCH = 0  # a=global switch idx, b=in port, c=packet
EV_ARRIVE_HOST = 1    # a=host, c=packet
EV_TIMER = 2          # a=switch, b=timer_seq, c=packet id
EV_PUMP = 3           # a=host
EV_RETX = 4           # a=host, c=(app, block, gen)
EV_FAIL_SWITCH = 5    # a=switch
EV_LEADER_DONE = 6    # a=leader host, c=(app, block, total)

_CONTRIB_MULT = 1000003
_MAX_GEN = (1 << GEN_BITS) - 1


def contribution(app: int, block: int, host: int) -> int:
    """Deterministic integer contribution of ``host`` to ``(app, block)``."""
    return (host + 1) * _CONTRIB_MULT + 31 * block + 7919 * app


class _HostState:
    __slots__ = ("queue", "pending", "pump_scheduled", "noise_peer",
                 "noise_remaining", "noise_msg_idx", "send_cursor")

    def __init__(self) -> None:
        self.queue: Deque[Packet] = deque()
        self.pending: Optional[Packet] = None
        self.pump_scheduled = False
        self.noise_peer = -1
        self.noise_remaining = 0
        self.noise_msg_idx = 0
        # lazy cursor over this host's allreduce contributions: [app, next_block]
        self.send_cursor: List[List[int]] = []


class _LeaderState:
    __slots__ = ("value", "counter", "gen", "restorations", "done",
                 "last_fail_ns", "pending_done")

    def __init__(self) -> None:
        self.value = 0
        self.counter = 0
        self.gen = 0
        self.restorations: List[Tuple[int, int]] = []
        self.done = False
        self.pending_done = False
        self.last_fail_ns = -1e18


class _RingState:
    """Per-app ring-allreduce bookkeeping."""

    __slots__ = ("order", "rank", "p", "chunk_vals", "recv_count", "steps",
                 "pkts_per_chunk", "chunk_bytes", "done_steps")

    def __init__(self, order: List[int], data_bytes: int, payload: int) -> None:
        self.order = order
        self.rank = {h: r for r, h in enumerate(order)}
        self.p = len(order)
        self.chunk_bytes = max(1, -(-data_bytes // self.p))
        self.pkts_per_chunk = max(1, -(-self.chunk_bytes // payload))
        self.steps = 2 * self.p - 2
        self.chunk_vals: List[List[int]] = []
        self.recv_count: List[Dict[int, int]] = []
        self.done_steps: List[int] = []


class Simulator:
    """One simulation run. Construct, then call :meth:`run` once."""

    def __init__(self, cfg: SimConfig, jobs: List[AllreduceJob],
                 algo: Algo = Algo.CANARY, n_trees: int = 1,
                 noise_hosts: Optional[List[int]] = None):
        cfg.validate()
        self.cfg = cfg
        self.jobs = {j.app: j for j in jobs}
        self.algo = Algo(algo)
        self.n_trees = n_trees
        self.net = FatTree(cfg)
        self.rng = random.Random(cfg.seed)
        self.noise_hosts = list(noise_hosts or [])
        self._noise_set = set(self.noise_hosts)

        self.heap: List[Tuple[float, int, int, int, int, object]] = []
        self._seq = 0
        self.now = 0.0
        self.events = 0

        # hosts
        self.hosts = [_HostState() for _ in range(cfg.num_hosts)]
        self.host_gen: Dict[Tuple[int, int, int], int] = {}  # (host, app, block)

        # switches
        S = cfg.num_switches
        self.tables: List[Dict[int, Descriptor]] = [dict() for _ in range(S)]
        self.slots: List[Dict[int, int]] = [dict() for _ in range(S)]
        self.failed = [False] * S
        self.desc_high = [0] * S
        self._timer_seq = 0

        # leaders
        self.leader_state: Dict[Tuple[int, int], _LeaderState] = {}
        self.completed_total: Dict[Tuple[int, int], int] = {}
        self.fallback_blocks: Set[Tuple[int, int]] = set()

        # completion tracking
        self.have: Dict[Tuple[int, int], bytearray] = {}
        self.app_remaining: Dict[int, int] = {}
        self.app_done_ns: Dict[int, float] = {}
        self.mismatches = 0

        # counters
        self.stragglers = 0
        self.collisions = 0
        self.restorations = 0
        self.retransmissions = 0
        self.fallbacks = 0
        self.dropped = 0
        self.completed_blocks = 0

        # per-job precomputation
        self.blocks: Dict[int, int] = {}
        self.leaders: Dict[int, List[int]] = {}
        self.partset: Dict[int, Set[int]] = {}
        self.static_roots: Dict[int, List[int]] = {}
        self.leaf_expected: Dict[Tuple[int, int], int] = {}
        self.root_expected: Dict[int, int] = {}
        self.contrib_sum_base: Dict[int, Tuple[int, int]] = {}
        self.ring: Dict[int, _RingState] = {}
        self._setup_jobs()

    # ------------------------------------------------------------------ setup
    def _setup_jobs(self) -> None:
        cfg = self.cfg
        for app, job in self.jobs.items():
            parts = sorted(job.participants)
            if len(set(parts)) != len(parts):
                raise ValueError(f"duplicate participants in app {app}")
            B = job.num_blocks(cfg.payload_bytes)
            self.blocks[app] = B
            self.partset[app] = set(parts)
            self.leaders[app] = parts
            s1 = sum(h + 1 for h in parts)
            self.contrib_sum_base[app] = (s1, len(parts))
            if job.collective == "reduce":
                root = job.root if job.root is not None else parts[0]
                self.have[(app, root)] = bytearray(B)
                self.app_remaining[app] = B
            else:
                for h in parts:
                    self.have[(app, h)] = bytearray(B)
                self.app_remaining[app] = len(parts) * B
            if len(parts) == 1:
                # degenerate single-participant allreduce: already reduced
                h = parts[0]
                flags = self.have[(app, h)]
                for b in range(B):
                    flags[b] = 1
                self.app_remaining[app] = 0
                self.app_done_ns[app] = 0.0
                self.completed_blocks += B
                continue
            if self.algo == Algo.STATIC_TREE:
                roots = [self.rng.randrange(self.net.S) for _ in range(self.n_trees)]
                self.static_roots[app] = roots
                active_leaves = {self.net.leaf_of(h) for h in parts}
                self.root_expected[app] = len(active_leaves)
                for leaf in active_leaves:
                    cnt = sum(1 for h in parts if self.net.leaf_of(h) == leaf)
                    self.leaf_expected[(app, leaf)] = cnt
            if self.algo == Algo.RING:
                rs = _RingState(parts, job.data_bytes, cfg.payload_bytes)
                rs.chunk_vals = [
                    [contribution(app, c, parts[r]) for c in range(rs.p)]
                    for r in range(rs.p)
                ]
                rs.recv_count = [dict() for _ in range(rs.p)]
                rs.done_steps = [0] * rs.p
                self.ring[app] = rs
                for h in parts:
                    self._ring_enqueue_send(app, h, step=0)
            else:
                for h in parts:
                    self.hosts[h].send_cursor.append([app, 0])
                    self._schedule_pump(h, 0.0)
        for h in self.noise_hosts:
            self._schedule_pump(h, 0.0)
        if cfg.switch_fail_ns is not None and cfg.failed_switch is not None:
            self._push(cfg.switch_fail_ns, EV_FAIL_SWITCH, cfg.failed_switch, 0, None)

    # ------------------------------------------------------------------ utils
    def _push(self, t: float, kind: int, a: int, b: int, c: object) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (t, self._seq, kind, a, b, c))

    def _schedule_pump(self, host: int, t: float) -> None:
        hs = self.hosts[host]
        if not hs.pump_scheduled:
            hs.pump_scheduled = True
            self._push(t, EV_PUMP, host, 0, None)

    def expected_total(self, app: int, block: int) -> int:
        c = self.jobs[app].collective
        if c == "barrier":
            return 0
        if c == "broadcast":
            return contribution(app, block, self.leader_of(app, block))
        s1, p = self.contrib_sum_base[app]
        return _CONTRIB_MULT * s1 + p * (31 * block + 7919 * app)

    def leader_of(self, app: int, block: int) -> int:
        job = self.jobs[app]
        if job.collective in ("reduce", "broadcast"):
            return job.root if job.root is not None else self.leaders[app][0]
        parts = self.leaders[app]
        return parts[block % len(parts)]

    def contribution_of(self, app: int, block: int, host: int) -> int:
        c = self.jobs[app].collective
        if c == "barrier":
            return 0
        if c == "broadcast":
            root = self.leader_of(app, block)
            return contribution(app, block, root) if host == root else 0
        return contribution(app, block, host)

    @staticmethod
    def _hash64(pid: int) -> int:
        # Fibonacci hashing; use the HIGH bits — block ids have zero low bits
        # (generation field), and power-of-two tables would otherwise see only
        # a tiny fraction of their slots.
        return ((pid * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> 24

    def _slot_of(self, pid: int) -> int:
        cfg = self.cfg
        if cfg.partition_table and len(self.jobs) > 1:
            apps = len(self.jobs)
            region = max(1, cfg.table_size // apps)
            return (id_app(pid) % apps) * region + self._hash64(pid) % region
        return self._hash64(pid) % cfg.table_size

    # --------------------------------------------------------------- transmit
    def _maybe_drop(self) -> bool:
        return self.cfg.drop_prob > 0.0 and self.rng.random() < self.cfg.drop_prob

    def _send_from_host(self, host: int, pkt: Packet) -> float:
        link = self.net.host_up[host]
        arrival = link.transmit(self.now, pkt.size_bytes)
        if self._maybe_drop():
            self.dropped += 1
        else:
            leaf = self.net.leaf_of(host)
            self._push(arrival, EV_ARRIVE_SWITCH, leaf,
                       self.net.leaf_port_of_host(host), pkt)
        return link.busy_until

    def _send_leaf_up(self, leaf: int, spine: int, pkt: Packet) -> None:
        link = self.net.leaf_up[leaf][spine]
        arrival = link.transmit(self.now, pkt.size_bytes)
        if self._maybe_drop():
            self.dropped += 1
            return
        self._push(arrival, EV_ARRIVE_SWITCH, self.net.L + spine,
                   self.net.spine_port_of_leaf(leaf), pkt)

    def _send_spine_down(self, spine: int, leaf: int, pkt: Packet) -> None:
        link = self.net.leaf_down[leaf][spine]
        arrival = link.transmit(self.now, pkt.size_bytes)
        if self._maybe_drop():
            self.dropped += 1
            return
        self._push(arrival, EV_ARRIVE_SWITCH, leaf,
                   self.net.leaf_port_of_spine(spine), pkt)

    def _send_leaf_to_host(self, host: int, pkt: Packet) -> None:
        link = self.net.host_down[host]
        arrival = link.transmit(self.now, pkt.size_bytes)
        if self._maybe_drop():
            self.dropped += 1
            return
        self._push(arrival, EV_ARRIVE_HOST, host, 0, pkt)

    def _forward_toward_host(self, sw: int, pkt: Packet) -> None:
        net = self.net
        if net.is_leaf(sw):
            if net.leaf_of(pkt.dest) == sw:
                self._send_leaf_to_host(pkt.dest, pkt)
            else:
                # Default up-port: hash of (destination, block id). Same-block
                # partials share the hash and so converge on one spine
                # (maximizing aggregation); different blocks spread across
                # spines ("each block in a different root", §3.1.3); and a
                # retransmitted generation gets a *different* id and hence a
                # different default path, which is how §3.3 routes around a
                # failed switch. Background noise hashes on destination only.
                kind = pkt.kind
                dleaf = net.leaf_of(pkt.dest)
                if kind == PacketKind.NOISE:
                    fh = hash(pkt.dest)
                elif kind == PacketKind.RING:
                    fh = hash((pkt.dest, pkt.step))
                else:
                    fh = hash((pkt.dest, pkt.id))
                # background congestion traffic rides its own policy (§2.1)
                policy = str(self.cfg.noise_lb) if kind == PacketKind.NOISE \
                    else None
                if self.cfg.flowlet_lb and kind in (PacketKind.NOISE,
                                                    PacketKind.RING):
                    # point-to-point traffic moves at flowlet granularity [37]
                    fkey = (int(kind), pkt.src, pkt.dest,
                            pkt.chunk if kind == PacketKind.NOISE else pkt.step)
                    spine = net.pick_spine_flowlet(sw, self.now, fh, fkey,
                                                   self.rng, dest_leaf=dleaf,
                                                   policy=policy)
                else:
                    spine = net.pick_spine(sw, self.now, fh, self.rng,
                                           dest_leaf=dleaf)
                self._send_leaf_up(sw, spine, pkt)
        else:
            self._send_spine_down(net.spine_index(sw), net.leaf_of(pkt.dest), pkt)

    def _forward_toward_switch(self, sw: int, pkt: Packet) -> None:
        net = self.net
        target = pkt.dest_switch
        if net.is_leaf(sw):
            if net.is_leaf(target):
                fh = hash(target)
                spine = net.pick_spine(sw, self.now, fh, self.rng,
                                       dest_leaf=target)
                self._send_leaf_up(sw, spine, pkt)
            else:
                self._send_leaf_up(sw, net.spine_index(target), pkt)
        else:
            if net.is_leaf(target):
                self._send_spine_down(net.spine_index(sw), target, pkt)
            else:
                # spine -> spine requires bouncing off any leaf; route via leaf 0
                self._send_spine_down(net.spine_index(sw), 0, pkt)

    def _out_port_send(self, sw: int, port: int, pkt: Packet) -> None:
        net = self.net
        if net.is_leaf(sw):
            if port < net.H:
                self._send_leaf_to_host(sw * net.H + port, pkt)
            else:
                self._send_leaf_up(sw, port - net.H, pkt)
        else:
            self._send_spine_down(net.spine_index(sw), port, pkt)

    # ------------------------------------------------------------ host pump
    def _next_host_packet(self, host: int) -> Optional[Packet]:
        hs = self.hosts[host]
        if hs.queue:
            return hs.queue.popleft()
        cfg = self.cfg
        canary = self.algo == Algo.CANARY
        for cur in hs.send_cursor:
            app, nxt = cur
            B = self.blocks[app]
            if canary:
                while nxt < B and self.leader_of(app, nxt) == host:
                    nxt += 1  # the leader keeps its contribution local (§3.1.4)
            if nxt < B:
                cur[1] = nxt + 1
                pid = make_id(app, nxt, 0)
                size = cfg.header_bytes + 8 \
                    if self.jobs[app].collective == "barrier" else cfg.mtu_bytes
                pkt = Packet(kind=PacketKind.REDUCE, dest=self.leader_of(app, nxt),
                             id=pid, counter=1, hosts=len(self.leaders[app]),
                             value=self.contribution_of(app, nxt, host),
                             size_bytes=size, src=host)
                if canary:
                    # loss detection is part of the Canary protocol (§3.3);
                    # static-tree systems restart from scratch instead.
                    self._push(self.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                               (app, nxt, 0))
                return pkt
            cur[1] = nxt
        if host in self._noise_set:
            if hs.noise_remaining <= 0:
                # random-uniform pattern *among the congestion hosts* (§5.2):
                # the background jobs and the allreduce job are distinct
                # applications, so noise flows target noise hosts; they share
                # the fabric (leaf/spine links) with the allreduce, not the
                # participants' NICs.
                peer = self.noise_hosts[self.rng.randrange(len(self.noise_hosts))]
                while peer == host:
                    peer = self.noise_hosts[self.rng.randrange(len(self.noise_hosts))]
                hs.noise_peer = peer
                hs.noise_remaining = cfg.noise_msg_bytes
                hs.noise_msg_idx += 1
            take = min(cfg.payload_bytes, hs.noise_remaining)
            hs.noise_remaining -= take
            return Packet(kind=PacketKind.NOISE, dest=hs.noise_peer, id=0,
                          size_bytes=take + cfg.header_bytes, src=host,
                          chunk=hs.noise_msg_idx)
        return None

    def _pump(self, host: int) -> None:
        hs = self.hosts[host]
        if self._all_done():
            return
        cfg = self.cfg
        pkt = hs.pending
        hs.pending = None
        if pkt is None:
            pkt = self._next_host_packet(host)
            if pkt is None:
                return
            # §5.2.5 sender-side OS noise: delay this send with probability p.
            if cfg.noise_prob > 0.0 and self.rng.random() < cfg.noise_prob:
                hs.pending = pkt
                hs.pump_scheduled = True
                self._push(self.now + cfg.noise_delay_ns, EV_PUMP, host, 0, None)
                return
        nic_free = self._send_from_host(host, pkt)
        hs.pump_scheduled = True
        self._push(nic_free, EV_PUMP, host, 0, None)

    # ------------------------------------------------------ canary data plane
    def _canary_reduce_at_switch(self, sw: int, in_port: int, pkt: Packet) -> None:
        cfg = self.cfg
        pid = pkt.id
        table = self.tables[sw]
        desc = table.get(pid)
        if desc is not None:
            desc.children.add(in_port)
            desc.last_ns = self.now
            if desc.sent:
                # straggler (§3.1.1): forward immediately, keep child recorded
                self.stragglers += 1
                self._forward_toward_host(sw, pkt)
            else:
                desc.value += pkt.value
                desc.counter += pkt.counter
                if desc.counter >= desc.hosts - 1:
                    self._fire_descriptor(sw, desc)  # all data received (§3.1.4)
            return
        slot = self._slot_of(pid)
        occupant = self.slots[sw].get(slot)
        if occupant is not None:
            odesc = table.get(occupant)
            if odesc is None:
                self.slots[sw].pop(slot, None)
                occupant = None
            elif self.now - odesc.last_ns > cfg.gc_ns:
                # stale soft state (abandoned generation): garbage collect
                self._dealloc(sw, odesc)
                occupant = None
        if occupant is not None:
            # collision (§3.2.1): stamp and bypass straight to the leader
            self.collisions += 1
            pkt.switch_addr = sw
            pkt.port_stamp = in_port
            pkt.bypass = True
            self._forward_toward_host(sw, pkt)
            return
        desc = Descriptor(id=pid, slot=slot, value=pkt.value, counter=pkt.counter,
                          hosts=pkt.hosts, children={in_port}, alloc_ns=self.now,
                          last_ns=self.now)
        table[pid] = desc
        self.slots[sw][slot] = pid
        if len(table) > self.desc_high[sw]:
            self.desc_high[sw] = len(table)
        if desc.counter >= desc.hosts - 1:
            self._fire_descriptor(sw, desc)
            return
        self._timer_seq += 1
        desc.timer_seq = self._timer_seq
        self._push(self.now + cfg.timeout_ns, EV_TIMER, sw, self._timer_seq, pid)

    def _fire_descriptor(self, sw: int, desc: Descriptor) -> None:
        """Timeout (or early completion): forward the partial aggregate (§3.1.1)."""
        desc.sent = True
        leader = self.leader_of(id_app(desc.id), id_block(desc.id))
        out = Packet(kind=PacketKind.REDUCE, dest=leader, id=desc.id,
                     counter=desc.counter, hosts=desc.hosts, value=desc.value,
                     size_bytes=self.cfg.mtu_bytes)
        self._forward_toward_host(sw, out)

    def _dealloc(self, sw: int, desc: Descriptor) -> None:
        self.tables[sw].pop(desc.id, None)
        if self.slots[sw].get(desc.slot) == desc.id:
            self.slots[sw].pop(desc.slot, None)

    def _canary_bcast_at_switch(self, sw: int, pkt: Packet) -> None:
        desc = self.tables[sw].get(pkt.id)
        if desc is None:
            # collision happened here during reduce: drop; the leader's
            # restoration packet re-attaches this subtree (§3.2.1)
            return
        for port in desc.children:
            self._out_port_send(sw, port, pkt)
        self._dealloc(sw, desc)

    def _restore_at(self, sw: int, pkt: Packet) -> None:
        """Tree restoration (§3.2.1): forward data out the stamped ports."""
        bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pkt.id, value=pkt.value,
                    multicast=True, size_bytes=self.cfg.mtu_bytes)
        for port in pkt.restore_ports:
            self._out_port_send(sw, port, bc)

    # ------------------------------------------------------ static-tree plane
    def _static_reduce_at_switch(self, sw: int, in_port: int, pkt: Packet) -> None:
        app = id_app(pkt.id)
        block = id_block(pkt.id)
        root = self.static_roots[app][block % self.n_trees]
        table = self.tables[sw]
        desc = table.get(pkt.id)
        if desc is None:
            if self.net.is_leaf(sw):
                expected = self.leaf_expected[(app, sw)]
            else:
                expected = self.root_expected[app]
            desc = Descriptor(id=pkt.id, slot=-1, hosts=pkt.hosts,
                              expected=expected, alloc_ns=self.now,
                              last_ns=self.now)
            table[pkt.id] = desc
            if len(table) > self.desc_high[sw]:
                self.desc_high[sw] = len(table)
        desc.children.add(in_port)
        desc.value += pkt.value
        desc.counter += pkt.counter
        desc.last_ns = self.now
        if len(desc.children) < desc.expected:
            return
        if self.net.is_leaf(sw):
            out = Packet(kind=PacketKind.REDUCE, dest=-1, id=pkt.id,
                         counter=desc.counter, hosts=pkt.hosts, value=desc.value,
                         size_bytes=self.cfg.mtu_bytes)
            self._send_leaf_up(sw, root, out)
            desc.sent = True
        else:
            bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pkt.id,
                        value=desc.value, multicast=True,
                        size_bytes=self.cfg.mtu_bytes)
            for port in desc.children:
                self._out_port_send(sw, port, bc)
            table.pop(pkt.id, None)

    def _static_bcast_at_switch(self, sw: int, pkt: Packet) -> None:
        desc = self.tables[sw].get(pkt.id)
        if desc is None:
            return
        for port in desc.children:
            if self.net.is_leaf(sw) and port >= self.net.H:
                continue  # never broadcast back up the tree
            self._out_port_send(sw, port, pkt)
        self.tables[sw].pop(pkt.id, None)

    # ---------------------------------------------------------- switch arrival
    def _arrive_switch(self, sw: int, in_port: int, pkt: Packet) -> None:
        if self.failed[sw]:
            self.dropped += 1
            return
        kind = pkt.kind
        if kind in (PacketKind.NOISE, PacketKind.RING, PacketKind.RETX_REQ,
                    PacketKind.FAIL, PacketKind.UNICAST_DATA):
            self._forward_toward_host(sw, pkt)
            return
        if kind == PacketKind.RESTORE:
            if pkt.dest_switch == sw:
                self._restore_at(sw, pkt)
            else:
                self._forward_toward_switch(sw, pkt)
            return
        if self.algo == Algo.CANARY:
            if kind == PacketKind.REDUCE:
                if pkt.bypass:
                    self._forward_toward_host(sw, pkt)
                else:
                    self._canary_reduce_at_switch(sw, in_port, pkt)
            elif kind == PacketKind.BCAST:
                self._canary_bcast_at_switch(sw, pkt)
        else:  # STATIC_TREE
            if kind == PacketKind.REDUCE:
                self._static_reduce_at_switch(sw, in_port, pkt)
            elif kind == PacketKind.BCAST:
                self._static_bcast_at_switch(sw, pkt)

    # ------------------------------------------------------------ host arrival
    def _complete_at_host(self, host: int, app: int, block: int, value: int) -> None:
        flags = self.have.get((app, host))
        if flags is None or flags[block]:
            return
        flags[block] = 1
        if value != self.expected_total(app, block):
            self.mismatches += 1
        self.app_remaining[app] -= 1
        self.completed_blocks += 1
        if self.app_remaining[app] == 0:
            self.app_done_ns[app] = self.now

    def _leader_block_done(self, host: int, app: int, block: int, total: int) -> None:
        key = (app, block)
        st = self.leader_state.get(key)
        if st is None or st.done:
            return
        st.done = True
        self.completed_total[key] = total
        self._complete_at_host(host, app, block, total)
        if self.jobs[app].collective == "reduce":
            return  # §6: a reduce skips the broadcast phase entirely
        pid = make_id(app, block, st.gen)
        cfg = self.cfg
        if key in self.fallback_blocks:
            # host-based fallback (§3.3): no descriptors exist — unicast result
            for h in self.leaders[app]:
                if h == host:
                    continue
                up = Packet(kind=PacketKind.UNICAST_DATA, dest=h, id=pid,
                            value=total, size_bytes=cfg.mtu_bytes, src=host)
                self.hosts[host].queue.append(up)
        else:
            # broadcast down the recorded tree (§3.1.2)
            bc = Packet(kind=PacketKind.BCAST, dest=-1, id=pid, value=total,
                        multicast=True, size_bytes=cfg.mtu_bytes)
            self.hosts[host].queue.append(bc)
            # tree restoration for collided subtrees (§3.2.1)
            by_switch: Dict[int, List[int]] = {}
            for sw_addr, port in st.restorations:
                by_switch.setdefault(sw_addr, []).append(port)
            for sw_addr, ports in by_switch.items():
                self.restorations += 1
                rp = Packet(kind=PacketKind.RESTORE, dest=-1, id=pid, value=total,
                            dest_switch=sw_addr, restore_ports=tuple(set(ports)),
                            size_bytes=cfg.mtu_bytes)
                self.hosts[host].queue.append(rp)
        self._schedule_pump(host, self.now)

    def _arrive_host(self, host: int, pkt: Packet) -> None:
        kind = pkt.kind
        cfg = self.cfg
        if kind == PacketKind.NOISE:
            return
        if kind == PacketKind.RING:
            self._ring_receive(host, pkt)
            return
        app, block, gen = id_app(pkt.id), id_block(pkt.id), id_gen(pkt.id)
        if kind == PacketKind.REDUCE:
            if self.leader_of(app, block) != host:
                return
            key = (app, block)
            st = self.leader_state.setdefault(key, _LeaderState())
            if st.done or st.pending_done or gen != st.gen:
                return  # stale generation or already reduced
            st.value += pkt.value
            st.counter += pkt.counter
            if pkt.switch_addr >= 0:
                st.restorations.append((pkt.switch_addr, pkt.port_stamp))
            if st.counter >= len(self.leaders[app]) - 1:
                total = st.value + self.contribution_of(app, block, host)
                st.pending_done = True
                # leader-side aggregation cost r (§3.2.2)
                self._push(self.now + cfg.leader_aggregate_ns, EV_LEADER_DONE,
                           host, 0, (app, block, total))
            return
        if kind in (PacketKind.BCAST, PacketKind.UNICAST_DATA):
            self._complete_at_host(host, app, block, pkt.value)
            return
        if kind == PacketKind.RETX_REQ:
            self._leader_handle_retx(host, app, block, pkt.src)
            return
        if kind == PacketKind.FAIL:
            self._host_handle_fail(host, pkt)
            return

    # ----------------------------------------------------------- reliability
    def _leader_handle_retx(self, leader: int, app: int, block: int,
                            requester: int) -> None:
        cfg = self.cfg
        key = (app, block)
        total = self.completed_total.get(key)
        if total is not None:
            # loss was in the broadcast phase: retransmit reduced data (§3.3)
            up = Packet(kind=PacketKind.UNICAST_DATA, dest=requester,
                        id=make_id(app, block, 0), value=total,
                        size_bytes=cfg.mtu_bytes, src=leader)
            self.hosts[leader].queue.append(up)
            self._schedule_pump(leader, self.now)
            return
        st = self.leader_state.setdefault(key, _LeaderState())
        if st.pending_done:
            return  # completion already in flight
        if self.now - st.last_fail_ns < cfg.retx_timeout_ns / 2:
            return  # debounce: a failure round is already in flight
        st.last_fail_ns = self.now
        newgen = min(st.gen + 1, _MAX_GEN)
        fallback = newgen >= cfg.max_generations
        if fallback and key not in self.fallback_blocks:
            self.fallbacks += 1
            self.fallback_blocks.add(key)
        st.gen = newgen
        st.value = 0
        st.counter = 0
        st.restorations = []
        # "the leader broadcasts a failure message" (§3.3) — delivered unicast
        for h in self.leaders[app]:
            if h == leader:
                continue
            fl = Packet(kind=PacketKind.FAIL, dest=h,
                        id=make_id(app, block, newgen),
                        counter=1 if fallback else 0,
                        size_bytes=cfg.header_bytes + 16, src=leader)
            self.hosts[leader].queue.append(fl)
        self._schedule_pump(leader, self.now)

    def _host_handle_fail(self, host: int, pkt: Packet) -> None:
        cfg = self.cfg
        app, block, gen = id_app(pkt.id), id_block(pkt.id), id_gen(pkt.id)
        hkey = (host, app, block)
        if self.host_gen.get(hkey, 0) >= gen:
            return
        flags = self.have.get((app, host))
        if flags is not None and flags[block]:
            return
        self.host_gen[hkey] = gen
        self.retransmissions += 1
        fallback = pkt.counter == 1
        rp = Packet(kind=PacketKind.REDUCE, dest=self.leader_of(app, block),
                    id=make_id(app, block, gen), counter=1,
                    hosts=len(self.leaders[app]),
                    value=self.contribution_of(app, block, host),
                    bypass=fallback, size_bytes=cfg.mtu_bytes, src=host)
        self.hosts[host].queue.append(rp)
        self._push(self.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                   (app, block, gen))
        self._schedule_pump(host, self.now)

    def _host_retx_check(self, host: int, app: int, block: int, gen: int) -> None:
        cfg = self.cfg
        if self._all_done():
            return
        flags = self.have.get((app, host))
        if flags is None or flags[block]:
            return
        if self.host_gen.get((host, app, block), 0) > gen:
            return  # a newer generation is already in flight
        self.retransmissions += 1
        req = Packet(kind=PacketKind.RETX_REQ, dest=self.leader_of(app, block),
                     id=make_id(app, block, gen),
                     size_bytes=cfg.header_bytes + 16, src=host)
        self.hosts[host].queue.append(req)
        self._push(self.now + cfg.retx_timeout_ns, EV_RETX, host, 0,
                   (app, block, gen))
        self._schedule_pump(host, self.now)

    # ------------------------------------------------------------------- ring
    def _ring_enqueue_send(self, app: int, host: int, step: int) -> None:
        rs = self.ring[app]
        r = rs.rank[host]
        if step > rs.steps - 1:
            return
        c = (r - step) % rs.p
        dest = rs.order[(r + 1) % rs.p]
        val = rs.chunk_vals[r][c]
        cfg = self.cfg
        remaining = rs.chunk_bytes
        for i in range(rs.pkts_per_chunk):
            take = min(cfg.payload_bytes, remaining)
            remaining -= take
            pkt = Packet(kind=PacketKind.RING, dest=dest, id=app,
                         value=val if i == rs.pkts_per_chunk - 1 else 0,
                         size_bytes=take + cfg.header_bytes, src=host,
                         chunk=c, step=step)
            self.hosts[host].queue.append(pkt)
        self._schedule_pump(host, self.now)

    def _ring_receive(self, host: int, pkt: Packet) -> None:
        app = pkt.id
        rs = self.ring[app]
        r = rs.rank[host]
        counts = rs.recv_count[r]
        got = counts.get(pkt.step, 0) + 1
        counts[pkt.step] = got
        if pkt.value:
            if pkt.step < rs.p - 1:
                rs.chunk_vals[r][pkt.chunk] += pkt.value  # reduce-scatter phase
            else:
                rs.chunk_vals[r][pkt.chunk] = pkt.value   # all-gather phase
        if got < rs.pkts_per_chunk:
            return
        counts.pop(pkt.step, None)
        rs.done_steps[r] += 1
        if pkt.step + 1 <= rs.steps - 1:
            self._ring_enqueue_send(app, host, pkt.step + 1)
        # steps can *complete* out of order when paths differ; the host is
        # finished only once every step's chunk has fully arrived.
        if rs.done_steps[r] == rs.steps:
            self._ring_finish_host(app, host)

    def _ring_finish_host(self, app: int, host: int) -> None:
        rs = self.ring[app]
        r = rs.rank[host]
        ok = all(rs.chunk_vals[r][c] == self.expected_total(app, c)
                 for c in range(rs.p))
        if not ok:
            self.mismatches += 1
        flags = self.have[(app, host)]
        newly = 0
        for b in range(self.blocks[app]):
            if not flags[b]:
                flags[b] = 1
                newly += 1
        self.app_remaining[app] -= newly
        self.completed_blocks += newly
        if self.app_remaining[app] == 0:
            self.app_done_ns[app] = self.now

    # -------------------------------------------------------------------- run
    def _all_done(self) -> bool:
        return all(v == 0 for v in self.app_remaining.values())

    def run(self) -> SimResult:
        cfg = self.cfg
        heap = self.heap
        while heap:
            if self._all_done():
                break
            t, _, kind, a, b, c = heapq.heappop(heap)
            self.now = t
            self.events += 1
            if self.events > cfg.max_events:
                raise RuntimeError("event budget exceeded — livelock?")
            if kind == EV_ARRIVE_SWITCH:
                self._arrive_switch(a, b, c)           # type: ignore[arg-type]
            elif kind == EV_ARRIVE_HOST:
                self._arrive_host(a, c)                # type: ignore[arg-type]
            elif kind == EV_PUMP:
                self.hosts[a].pump_scheduled = False
                self._pump(a)
            elif kind == EV_TIMER:
                desc = self.tables[a].get(c)           # type: ignore[arg-type]
                if desc is not None and desc.timer_seq == b and \
                        not desc.sent and not self.failed[a]:
                    self._fire_descriptor(a, desc)
            elif kind == EV_RETX:
                app, block, gen = c                    # type: ignore[misc]
                self._host_retx_check(a, app, block, gen)
            elif kind == EV_FAIL_SWITCH:
                self.failed[a] = True
            elif kind == EV_LEADER_DONE:
                app, block, total = c                  # type: ignore[misc]
                self._leader_block_done(a, app, block, total)
        end = max(self.app_done_ns.values()) if self.app_done_ns else self.now
        utils = self.net.utilizations(end if end > 0 else 1.0)
        goodput = {}
        for app, job in self.jobs.items():
            dur = self.app_done_ns.get(app, self.now)
            goodput[app] = (job.data_bytes * 8.0) / dur if dur > 0 else 0.0
        maxdesc = max(self.desc_high) if self.desc_high else 0
        return SimResult(
            duration_ns=end,
            start_ns=0.0,
            goodput_gbps=goodput,
            correct=(self.mismatches == 0 and self._all_done()),
            link_utilization=utils,
            avg_utilization=sum(utils) / len(utils) if utils else 0.0,
            stragglers=self.stragglers,
            collisions=self.collisions,
            restorations=self.restorations,
            retransmissions=self.retransmissions,
            fallbacks=self.fallbacks,
            max_descriptors_per_switch=maxdesc,
            max_descriptor_bytes=maxdesc * cfg.mtu_bytes,
            events=self.events,
            dropped_packets=self.dropped,
            completed_blocks=self.completed_blocks,
        )
