"""High-level experiment drivers around the simulator.

These functions set up the host partitions the paper uses (§5.2): a fraction
of hosts runs the allreduce(s), the rest generate random-uniform congestion
traffic, with randomized placement across repetitions.
"""
from __future__ import annotations

import dataclasses
import random
import statistics
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .simulator import Simulator
from .types import Algo, AllreduceJob, SimConfig, SimResult


@dataclass
class ExperimentResult:
    """Aggregated over repetitions."""

    algo: str
    n_trees: int
    goodput_gbps_mean: float
    goodput_gbps_min: float
    goodput_gbps_max: float
    runtime_us_mean: float
    avg_utilization: float
    link_utilization: List[float]
    correct: bool
    reps: List[SimResult]

    def row(self) -> str:
        return (f"{self.algo}(t={self.n_trees}) goodput={self.goodput_gbps_mean:.1f}Gbps "
                f"runtime={self.runtime_us_mean:.1f}us util={self.avg_utilization:.3f} "
                f"correct={self.correct}")


def pick_hosts(cfg: SimConfig, n: int, rng: random.Random) -> List[int]:
    return rng.sample(range(cfg.num_hosts), n)


def build_cell_simulator(cfg: SimConfig, algo: Algo,
                         num_allreduce_hosts: int, data_bytes: int, *,
                         n_trees: int = 1, congestion: bool = False,
                         num_apps: int = 1, rep: int = 0) -> Simulator:
    """Construct rep ``rep`` of one experiment cell — the exact Simulator
    :func:`run_allreduce` would run, handed back unstarted so callers can
    keep the live object (the telemetry exporters need the hub after
    ``run()``, which ``ExperimentResult`` does not carry)."""
    rng = random.Random(cfg.seed * 1000003 + rep)
    chosen = pick_hosts(cfg, num_allreduce_hosts, rng)
    per_app = max(2, num_allreduce_hosts // num_apps)
    jobs = []
    for a in range(num_apps):
        parts = chosen[a * per_app:(a + 1) * per_app]
        if len(parts) < 2:
            break
        jobs.append(AllreduceJob(app=a, participants=parts,
                                 data_bytes=data_bytes))
    noise = [h for h in range(cfg.num_hosts) if h not in set(chosen)] \
        if congestion else []
    rcfg = dataclasses.replace(cfg, seed=cfg.seed + rep)
    return Simulator(rcfg, jobs, algo=algo, n_trees=n_trees,
                     noise_hosts=noise)


def run_allreduce(cfg: SimConfig,
                  algo: Algo,
                  num_allreduce_hosts: int,
                  data_bytes: int,
                  *,
                  n_trees: int = 1,
                  congestion: bool = False,
                  num_apps: int = 1,
                  reps: int = 1,
                  rep0: int = 0,
                  partition_hosts: bool = True) -> ExperimentResult:
    """Run ``num_apps`` concurrent allreduces over ``num_allreduce_hosts`` total
    hosts (equally partitioned), optionally with all remaining hosts generating
    random-uniform congestion traffic (§5.2).

    ``rep0`` offsets the repetition index: ``reps=1, rep0=r`` reproduces rep
    ``r`` of a ``reps=r+1`` call exactly, which is how the parallel sweep
    runner (``benchmarks/sweep.py``) splits an experiment into independent
    per-rep work items without changing its results."""
    results: List[SimResult] = []
    for rep in range(rep0, rep0 + reps):
        sim = build_cell_simulator(cfg, algo, num_allreduce_hosts, data_bytes,
                                   n_trees=n_trees, congestion=congestion,
                                   num_apps=num_apps, rep=rep)
        results.append(sim.run())
    gp = [statistics.mean(r.goodput_gbps.values()) for r in results]
    rt = [r.duration_ns / 1e3 for r in results]
    return ExperimentResult(
        algo=str(algo),
        n_trees=n_trees,
        goodput_gbps_mean=statistics.mean(gp),
        goodput_gbps_min=min(gp),
        goodput_gbps_max=max(gp),
        runtime_us_mean=statistics.mean(rt),
        avg_utilization=statistics.mean(r.avg_utilization for r in results),
        link_utilization=results[-1].link_utilization,
        correct=all(r.correct for r in results),
        reps=results,
    )


def compare_algorithms(cfg: SimConfig, num_allreduce_hosts: int,
                       data_bytes: int, *, congestion: bool,
                       static_trees: Sequence[int] = (1, 4),
                       reps: int = 1) -> Dict[str, ExperimentResult]:
    """The paper's core comparison: ring vs N static trees vs Canary."""
    out: Dict[str, ExperimentResult] = {}
    out["ring"] = run_allreduce(cfg, Algo.RING, num_allreduce_hosts, data_bytes,
                                congestion=congestion, reps=reps)
    for n in static_trees:
        out[f"static_{n}"] = run_allreduce(cfg, Algo.STATIC_TREE,
                                           num_allreduce_hosts, data_bytes,
                                           n_trees=n, congestion=congestion,
                                           reps=reps)
    out["canary"] = run_allreduce(cfg, Algo.CANARY, num_allreduce_hosts,
                                  data_bytes, congestion=congestion, reps=reps)
    return out
