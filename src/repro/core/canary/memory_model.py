"""Switch memory occupancy model (§3.2.2).

A block descriptor lives from the arrival of the block's first packet until
the broadcast sweep deallocates it: ``2 d (l + t) + r`` where ``d`` is the
network diameter, ``l`` the 1-hop delay, ``t`` the aggregation timeout and
``r`` the leader-side processing time. By Little's law, with MTU-sized packets
injected at bandwidth ``b`` the descriptor bytes per switch are::

    (b / m) * (2 d (l + t) + r) * m  =  b * (2 d (l + t) + r)

independent of both the reduced-data size and the number of hosts. The
paper's example (100 Gb/s, d=5, l=300 ns, t=1 us, r=1 us) gives ~175 KiB.
"""
from __future__ import annotations

from dataclasses import dataclass

from .types import SimConfig


@dataclass(frozen=True)
class OccupancyModel:
    bandwidth_gbps: float = 100.0
    diameter: int = 5
    hop_latency_ns: float = 300.0
    timeout_ns: float = 1000.0
    leader_ns: float = 1000.0

    @property
    def descriptor_lifetime_ns(self) -> float:
        return 2 * self.diameter * (self.hop_latency_ns + self.timeout_ns) \
            + self.leader_ns

    @property
    def occupancy_bytes(self) -> float:
        bytes_per_ns = self.bandwidth_gbps / 8.0
        return bytes_per_ns * self.descriptor_lifetime_ns

    @property
    def occupancy_kib(self) -> float:
        return self.occupancy_bytes / 1024.0


def paper_example() -> OccupancyModel:
    """§3.2.2's worked example: ≈175 KiB per switch per allreduce."""
    return OccupancyModel()


def model_for(cfg: SimConfig, diameter: int = 2) -> OccupancyModel:
    """Occupancy model matching a simulator configuration.

    A two-level fat tree has diameter 2 (host->leaf->spine->leaf->host is
    4 hops but the *switch* depth relevant to descriptor lifetime is 2-3);
    callers may override.
    """
    return OccupancyModel(
        bandwidth_gbps=cfg.link_gbps,
        diameter=diameter,
        hop_latency_ns=cfg.hop_latency_ns,
        timeout_ns=cfg.timeout_ns,
        leader_ns=cfg.leader_aggregate_ns,
    )
