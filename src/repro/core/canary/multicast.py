"""Shard-encoded multicast groups (§4.2).

A switch must multicast broadcast-phase packets to the exact set of ports the
reduce-phase packets came from. Pre-installing one multicast group per port
subset needs ``2^p`` entries; the paper instead splits the ``p``-bit children
bitmap into ``s`` shards of ``p/s`` bits, prefixes each shard with its index,
and installs ``s * 2^(p/s)`` rules — e.g. 64 ports / 4 shards = 256 Ki rules.

This module implements that encoding/decoding exactly, and is unit/property
tested for round-trip correctness; the simulator uses the decoded port lists
for its broadcast fan-out.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple


def ports_to_bitmap(ports: Sequence[int], num_ports: int) -> int:
    bm = 0
    for p in ports:
        if not 0 <= p < num_ports:
            raise ValueError(f"port {p} out of range 0..{num_ports - 1}")
        bm |= 1 << p
    return bm


def bitmap_to_ports(bitmap: int) -> List[int]:
    out, p = [], 0
    while bitmap:
        if bitmap & 1:
            out.append(p)
        bitmap >>= 1
        p += 1
    return out


def shard_bitmap(bitmap: int, num_ports: int, shards: int) -> List[Tuple[int, int]]:
    """Split a children bitmap into ``shards`` (index, bits) entries (§4.2).

    Entry ``(i, bits)`` covers ports ``[i*w, (i+1)*w)`` with ``w = p/s``.
    Zero shards are skipped (no packet needs to be sent for them).
    """
    if num_ports % shards != 0:
        raise ValueError("num_ports must be divisible by shards")
    w = num_ports // shards
    mask = (1 << w) - 1
    out = []
    for i in range(shards):
        bits = (bitmap >> (i * w)) & mask
        if bits:
            out.append((i, bits))
    return out


def shard_to_ports(shard_index: int, bits: int, num_ports: int,
                   shards: int) -> List[int]:
    """Decode one shard entry back to absolute port numbers."""
    w = num_ports // shards
    return [shard_index * w + p for p in bitmap_to_ports(bits)]


def build_rule_table(num_ports: int, shards: int) -> Dict[Tuple[int, int], List[int]]:
    """Materialize the full (shard index, shard bits) -> ports rule table.

    Size is ``s * 2^(p/s)`` entries as derived in §4.2 — practical only for
    the small/medium port counts used in tests; production switches install
    these rules via the control plane.
    """
    w = num_ports // shards
    table: Dict[Tuple[int, int], List[int]] = {}
    for i in range(shards):
        for bits in range(1, 1 << w):
            table[(i, bits)] = shard_to_ports(i, bits, num_ports, shards)
    return table


def num_rules(num_ports: int, shards: int) -> int:
    """§4.2: rules drop from ``2^p`` to ``s * 2^(p/s)``."""
    return shards * (1 << (num_ports // shards))


def multicast_ports(bitmap: int, num_ports: int, shards: int) -> List[int]:
    """End-to-end: encode a children bitmap into shard entries and decode the
    union of ports, exactly as the broadcast data plane would."""
    out: List[int] = []
    for i, bits in shard_bitmap(bitmap, num_ports, shards):
        out.extend(shard_to_ports(i, bits, num_ports, shards))
    return sorted(out)
