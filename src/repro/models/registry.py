"""Architecture registry: name -> ModelConfig (full / smoke)."""
from __future__ import annotations

from typing import List

from repro.configs import ARCH_MODULES, ARCH_NAMES
from .config import ModelConfig


def list_archs() -> List[str]:
    return list(ARCH_NAMES)


def get_config(name: str, variant: str = "full") -> ModelConfig:
    key = name.lower()
    if key not in ARCH_MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {ARCH_NAMES}")
    mod = ARCH_MODULES[key]
    if variant == "full":
        return mod.full()
    if variant == "smoke":
        return mod.smoke()
    raise ValueError(f"unknown variant '{variant}' (full|smoke)")
