"""Architecture registry: name -> ModelConfig (full / smoke).

``repro.configs`` modules import ``repro.models.config`` (which triggers
this package's ``__init__``), so the configs import lives inside the
functions — importing ``repro.configs`` first must not deadlock on a
partially initialized module.
"""
from __future__ import annotations

from typing import List

from .config import ModelConfig


def list_archs() -> List[str]:
    from repro.configs import ARCH_NAMES
    return list(ARCH_NAMES)


def get_config(name: str, variant: str = "full") -> ModelConfig:
    from repro.configs import ARCH_MODULES, ARCH_NAMES
    key = name.lower()
    if key not in ARCH_MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {ARCH_NAMES}")
    mod = ARCH_MODULES[key]
    if variant == "full":
        return mod.full()
    if variant == "smoke":
        return mod.smoke()
    raise ValueError(f"unknown variant '{variant}' (full|smoke)")
