"""Model configuration.

One frozen dataclass drives every architecture in the zoo (dense / MoE / SSM /
hybrid / VLM / audio). Each assigned architecture has a module in
``repro.configs`` that instantiates this with the exact published sizes and a
``smoke()`` reduced variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int

    # ---- attention -----------------------------------------------------------
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_mode: str = "standard"    # standard | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # pairs per t/h/w
    sliding_window: int = 0        # 0 = full attention; >0 = window size
    attn_chunk: int = 1024         # online-softmax block size for long seqs
    attn_chunk_threshold: int = 4096  # use chunked attention when S >= this

    # ---- feed-forward ----------------------------------------------------------
    d_ff: int = 0                  # dense MLP / shared-expert hidden size
    activation: str = "swiglu"     # swiglu | squared_relu | gelu

    # ---- MoE -------------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_d_ff: int = 0              # routed-expert hidden size
    moe_every: int = 1             # MoE on layers with i % moe_every == moe_offset
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_coef: float = 0.01
    moe_impl: str = "auto"         # auto | dense | ep  (ep = shard_map expert parallel)

    # ---- SSM (Mamba-2 / SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    attn_every: int = 0            # hybrid: attention on i % attn_every == attn_offset
    attn_offset: int = 0

    # ---- encoder-decoder / multimodal stubs --------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0           # whisper: 1500 post-conv frames
    frontend: str = "none"         # none | audio_stub | vision_stub
    num_patches: int = 0           # VLM: stub patch-embedding prefix length

    # ---- numerics / compilation ---------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True

    # ---- provenance ---------------------------------------------------------------
    source: str = ""               # paper / model-card citation

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def layer_kind(self, i: int) -> str:
        """Mixer kind of decoder layer ``i``: 'attn' or 'ssm'."""
        if self.arch_type == "ssm":
            return "ssm"
        if self.arch_type == "hybrid" and self.attn_every > 0:
            return "attn" if i % self.attn_every == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.moe_experts <= 0:
            return False
        return i % self.moe_every == self.moe_offset

    def supports_decode(self) -> bool:
        return True  # every zoo member is (or contains) a decoder

    def supports_long_decode(self) -> bool:
        """long_500k eligibility (see DESIGN.md §5)."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        if self.is_encoder_decoder:
            return False  # whisper: out of design envelope — skip, documented
        return True       # dense/vlm archs run it via the sliding-window variant

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def long_context_variant(self, window: int = 8192) -> "ModelConfig":
        """Sliding-window variant used for long_500k on full-attention archs."""
        if self.arch_type in ("ssm", "hybrid") or self.sliding_window:
            return self
        return self.with_(sliding_window=window,
                          name=f"{self.name}-sw{window}")

    # parameter-count estimate (embedding + per-layer), used for 6ND roofline
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        enc_layers = self.encoder_layers
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                qkv = d * (self.num_heads + 2 * self.num_kv_heads) * hd
                total += qkv + self.num_heads * hd * d
            else:
                di, n = self.ssm_d_inner, self.ssm_state
                total += d * (2 * di + 2 * n * (di // self.ssm_head_dim) * 0 + 2 * di) \
                    + 2 * di * n + di * d  # in/out proj + B/C/dt params (approx)
            if self.layer_is_moe(i):
                total += self.moe_experts * 3 * d * self.moe_d_ff
                total += self.moe_shared_experts * 3 * d * self.moe_d_ff \
                    if not self.d_ff else 3 * d * self.d_ff
                total += d * self.moe_experts  # router
            else:
                mult = 3 if self.activation == "swiglu" else 2
                total += mult * d * self.d_ff
            total += 2 * d  # norms
        for _ in range(enc_layers):
            qkv = d * (self.num_heads + 2 * self.num_kv_heads) * hd
            total += qkv + self.num_heads * hd * d
            mult = 3 if self.activation == "swiglu" else 2
            total += mult * d * self.d_ff + 2 * d
            if self.is_encoder_decoder:  # decoder cross-attention
                total += qkv + self.num_heads * hd * d
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k + shared only)."""
        if self.moe_experts <= 0:
            return self.param_count()
        full = self.param_count()
        inactive_frac_layers = 0
        for i in range(self.num_layers):
            if self.layer_is_moe(i):
                inactive = (self.moe_experts - self.moe_top_k) * 3 \
                    * self.d_model * self.moe_d_ff
                inactive_frac_layers += inactive
        return full - inactive_frac_layers
