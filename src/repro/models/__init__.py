from ..compat import patch_jax as _patch_jax

_patch_jax()

from .config import ModelConfig
from .registry import get_config, list_archs
from .transformer import (decode_step, forward, init_cache, init_params,
                          layer_period, prepare_cross_cache)

__all__ = ["ModelConfig", "decode_step", "forward", "get_config",
           "init_cache", "init_params", "layer_period", "list_archs",
           "prepare_cross_cache"]
