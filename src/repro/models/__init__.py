"""Model zoo package.

``ModelConfig`` / ``get_config`` / ``list_archs`` are pure-Python (config
dataclasses + registry) and import eagerly. The jax-backed model functions
(``forward``, ``init_params``, ...) load lazily on first attribute access
(PEP 562) so that config-only consumers — notably the simulator-side
workload compiler (``repro.core.workload``), which turns ``ModelConfig``s
into gradient traffic — never pull jax into the process. The
``repro.compat`` jax shims install right before the first lazy load (and at
``repro.models.transformer`` import, for direct imports), preserving the
patch-before-use ordering the eager ``__init__`` used to provide.
"""
from .config import ModelConfig
from .registry import get_config, list_archs

_LAZY_TRANSFORMER = ("decode_step", "forward", "init_cache", "init_params",
                     "layer_period", "prepare_cross_cache")

__all__ = ["ModelConfig", "decode_step", "forward", "get_config",
           "init_cache", "init_params", "layer_period", "list_archs",
           "prepare_cross_cache"]


def __getattr__(name: str):
    if name in _LAZY_TRANSFORMER:
        from ..compat import patch_jax
        patch_jax()
        from . import transformer
        return getattr(transformer, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
