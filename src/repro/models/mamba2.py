"""Mamba-2 layer via the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060, Listing 1), adapted to JAX with (B, S, H, P) heads.

Training/prefill uses the quadratic-within-chunk + recurrent-across-chunk
formulation; decode uses the O(1) per-token state recurrence. Group count is
fixed at 1 (B/C shared across heads), matching Mamba-2's default.
"""
from __future__ import annotations

from repro.compat import patch_jax as _patch_jax

_patch_jax()  # repro.models.__init__ is lazy; direct imports land here first

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * n  # x + B + C go through the causal conv
    ks = jax.random.split(key, 6)
    return {
        # in_proj produces [z (di), xBC (di + 2n), dt (h)]
        "w_in": (jax.random.normal(ks[0], (d, 2 * di + 2 * n + h))
                 * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch))
                   * cfg.ssm_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "norm_scale": jnp.ones((di,), dtype=jnp.float32),
        "w_out": (jax.random.normal(ks[2], (di, d)) * di ** -0.5).astype(dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., T) -> (..., T, T): cumulative segment sums, -inf above diagonal."""
    T = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD forward.

    x:  (b, s, h, p)   head inputs
    dt: (b, s, h)      positive step sizes
    A:  (h,)           negative per-head decay rates
    Bm: (b, s, n)      input projection (group-shared)
    Cm: (b, s, n)      output projection (group-shared)
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    f32 = jnp.float32
    xd = (x.astype(f32) * dt.astype(f32)[..., None])             # dt-weighted
    dA = dt.astype(f32) * A.astype(f32)[None, None, :]           # (b, s, h)

    # chunked views
    xc = xd.reshape(b, c, chunk, h, p)
    dAc = dA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)       # (b,h,c,q)
    Bc = Bm.astype(f32).reshape(b, c, chunk, n)
    Cc = Cm.astype(f32).reshape(b, c, chunk, n)

    # 1) intra-chunk (quadratic) term
    L = jnp.exp(_segsum(dAc))                                    # (b,h,c,q,q)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2) chunk end-states
    A_cum = jnp.cumsum(dAc, axis=-1)                             # (b,h,c,q)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)              # (b,h,c,q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(A_cum[..., -1])                        # (b,h,c)

    def step(carry, inp):
        st_in = carry                                            # (b,h,p,n)
        dec, st_chunk = inp                                      # (b,h), (b,h,p,n)
        st_out = st_in * dec[..., None, None] + st_chunk
        return st_out, st_in

    init = jnp.zeros((b, h, p, n), f32) if init_state is None \
        else init_state.astype(f32)
    final_state, states_in = lax.scan(
        step, init,
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)               # (b,c,h,p,n)

    # 4) state -> output term
    state_decay = jnp.exp(A_cum)                                 # (b,h,c,q)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_in, state_decay)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, final_state


def mamba2_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig
                   ) -> jnp.ndarray:
    """Full-sequence forward (training / prefill). x: (B, S, d)."""
    B, S, d = x.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = x @ p["w_in"]                                         # (B,S,...)
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    # causal depthwise conv over (x,B,C)
    w = p["conv_w"]                                              # (K, ch)
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    xBC = jax.nn.silu(conv + p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [di, di + n], axis=-1)
    xs = xs.reshape(B, S, h, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,h)
    A = -jnp.exp(p["A_log"])                                     # (h,)
    pad = (-S) % cfg.ssm_chunk
    if pad:
        # dt=0 padding is state-neutral: decay exp(0*A)=1, input weight 0
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        C_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, _ = ssd_chunked(xs_p, dt_p, A, B_p, C_p, cfg.ssm_chunk)
        y = y[:, :S]
    else:
        y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm (Mamba-2)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]
    return (g.astype(x.dtype)) @ p["w_out"]


def mamba2_init_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    return {
        "state": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
    }


def mamba2_decode_step(p: Params, x1: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                       cfg: ModelConfig
                       ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token recurrent step. x1: (B, 1, d)."""
    B = x1.shape[0]
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = x1[:, 0, :] @ p["w_in"]                               # (B, ...)
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    # conv ring: previous K-1 inputs + current
    hist = cache["conv"]                                         # (B, K-1, ch)
    w = p["conv_w"]
    K = w.shape[0]
    window = jnp.concatenate([hist, xBC[:, None, :].astype(hist.dtype)], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, w.astype(hist.dtype)) + p["conv_b"]
    xBC_a = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(xBC_a, [di, di + n], axis=-1)
    xs = xs.reshape(B, h, cfg.ssm_head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, h)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A[None, :])                               # (B, h)
    st = cache["state"]                                          # (B,h,p,n)
    xdt = xs * dt[..., None]                                     # (B,h,p)
    st_new = st * dec[..., None, None] + \
        jnp.einsum("bhp,bn->bhpn", xdt, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", st_new, Cm.astype(jnp.float32))
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, di)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]
    out = (g.astype(x1.dtype)) @ p["w_out"]
    new_cache = {"state": st_new,
                 "conv": window[:, 1:, :]}
    return out[:, None, :], new_cache
