"""Mixture-of-Experts layer: shared + routed experts, top-k routing with
fixed capacity, sort+scatter dispatch (no O(tokens^2) one-hot einsums).

Two execution paths:

* ``dense``  — single-program dispatch with GSPMD sharding constraints
               (experts sharded over the ``model`` mesh axis). Default; also
               the single-device smoke-test path.
* ``ep``     — explicit expert parallelism under ``shard_map``: every model
               shard routes its (replicated) token set to its *local* experts
               and the partial outputs are combined with one ``psum`` over the
               model axis. This is the paper-faithful "switch aggregation"
               analogue (partial sums combined in the fabric) and the baseline
               that the §Perf all-to-all iteration improves on.

Routing follows DeepSeekMoE / Qwen2-MoE: softmax -> top-k -> renormalize,
plus a Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from repro.compat import patch_jax as _patch_jax

_patch_jax()  # repro.models.__init__ is lazy; direct imports land here first

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import init_mlp, mlp_forward
from repro.parallel.context import get_parallel_context

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dtype),
    }
    if cfg.d_ff > 0:  # shared expert(s), fused into one MLP of width d_ff
        p["shared"] = init_mlp(ks[4], d, cfg.d_ff, "swiglu", dtype)
    return p


def _route(p: Params, x2d: jnp.ndarray, cfg: ModelConfig
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return (weights (N,k), expert ids (N,k), aux loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, cfg.moe_top_k)              # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e fraction_e * prob_e
    e = cfg.moe_experts
    frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    frac = frac / top_e.size
    pmean = probs.mean(axis=0)
    aux = e * jnp.sum(frac * pmean)
    return top_w, top_e, aux


def _dispatch_indices(top_e: jnp.ndarray, k: int, num_experts: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort slots by expert; return (sorted expert id, position-in-expert,
    source slot order). Cheap O(Nk log Nk) — no one-hot matmuls."""
    flat_e = top_e.reshape(-1)                                   # (N*k,)
    order = jnp.argsort(flat_e)                                  # stable
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(sorted_e.shape[0]) - first
    return sorted_e, pos_in_e, order


def _expert_ffn(p: Params, buf: jnp.ndarray) -> jnp.ndarray:
    """buf: (E, C, d) -> (E, C, d) through each expert's SwiGLU FFN."""
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor
            / cfg.moe_experts) + 1
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8 for TPU layouts


def _moe_dense(p: Params, x2d: jnp.ndarray, cfg: ModelConfig,
               model_axis: Optional[str]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n, d = x2d.shape
    k = cfg.moe_top_k
    top_w, top_e, aux = _route(p, x2d, cfg)
    sorted_e, pos_in_e, order = _dispatch_indices(top_e, k, cfg.moe_experts)
    cap = _capacity(n, cfg)
    keep = pos_in_e < cap
    src_tok = order // k
    buf = jnp.zeros((cfg.moe_experts, cap, d), dtype=x2d.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, pos_in_e, cap)].set(
        x2d[src_tok], mode="drop")

    def _constrain(t):
        ctx = get_parallel_context()
        if model_axis is None or ctx is None:
            return t
        tp = ctx.mesh.shape[model_axis]
        # shard experts over the model axis when divisible, else the
        # capacity dim (qwen2-moe's 60 experts on a 16-way axis)
        from jax.sharding import NamedSharding
        if t.shape[0] % tp == 0:
            spec = P(model_axis, None, None)
        elif t.shape[1] % tp == 0:
            spec = P(None, model_axis, None)
        else:
            return t
        return lax.with_sharding_constraint(t, NamedSharding(ctx.mesh, spec))

    buf = _constrain(buf)
    out = _expert_ffn(p, buf)
    out = _constrain(out)
    vals = out[sorted_e, jnp.minimum(pos_in_e, cap - 1)]
    vals = jnp.where(keep[:, None], vals, 0.0)
    w_sorted = top_w.reshape(-1)[order].astype(vals.dtype)
    y = jnp.zeros((n, d), dtype=x2d.dtype)
    y = y.at[src_tok].add(vals * w_sorted[:, None])
    return y, aux


def _moe_ep_shardmap(p: Params, x: jnp.ndarray, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel path: local-expert dispatch + psum combine.

    Tokens are replicated along the model axis; each shard serves only its
    E/tp local experts and contributes a partial output, summed with one
    ``psum`` — the direct analogue of Canary's in-fabric partial aggregation.
    """
    ctx = get_parallel_context()
    mesh, maxis = ctx.mesh, ctx.model_axis
    tp = mesh.shape[maxis]
    e_loc = cfg.moe_experts // tp
    B, S, d = x.shape
    # decode batches can be smaller than the data-parallel degree (e.g.
    # long_500k has batch 1): replicate tokens over the data axes then
    dp_spec = ctx.data_spec if B % ctx.dp_size == 0 else None

    def local(px, xx):
        n = xx.shape[0] * xx.shape[1]
        x2d = xx.reshape(n, d)
        k = cfg.moe_top_k
        top_w, top_e, aux = _route(px, x2d, cfg)
        shard = lax.axis_index(maxis)
        lo = shard * e_loc
        sorted_e, pos_in_e, order = _dispatch_indices(top_e, k, cfg.moe_experts)
        cap = _capacity(n, cfg)
        local_ok = (sorted_e >= lo) & (sorted_e < lo + e_loc) & (pos_in_e < cap)
        src_tok = order // k
        buf = jnp.zeros((e_loc, cap, d), dtype=x2d.dtype)
        buf = buf.at[jnp.where(local_ok, sorted_e - lo, e_loc),
                     jnp.where(local_ok, pos_in_e, cap)].set(
            x2d[src_tok], mode="drop")
        # local experts only: slice the (already sharded) weights arrive whole
        out = _expert_ffn(px, buf)
        vals = out[jnp.clip(sorted_e - lo, 0, e_loc - 1),
                   jnp.minimum(pos_in_e, cap - 1)]
        vals = jnp.where(local_ok[:, None], vals, 0.0)
        w_sorted = top_w.reshape(-1)[order].astype(vals.dtype)
        y = jnp.zeros((n, d), dtype=x2d.dtype)
        y = y.at[src_tok].add(vals * w_sorted[:, None])
        y = lax.psum(y, maxis)                      # combine expert partials
        aux = lax.pmean(aux, maxis)
        return y.reshape(xx.shape), aux

    pspec_params = {
        "router": P(),
        "w_up": P(maxis, None, None),
        "w_gate": P(maxis, None, None),
        "w_down": P(maxis, None, None),
    }
    in_specs = ({k: pspec_params.get(k, P()) for k in p if k != "shared"},
                P(dp_spec, None, None))
    out_specs = (P(dp_spec, None, None), P())
    routed = {k: v for k, v in p.items() if k != "shared"}
    y, aux = jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)(routed, x)
    return y, aux


def _dp_size(mesh, dp_spec) -> int:
    if isinstance(dp_spec, str):
        return mesh.shape[dp_spec]
    return int(jnp.prod(jnp.array([mesh.shape[a] for a in dp_spec])))


def _moe_ep_a2a_shardmap(p: Params, x: jnp.ndarray, cfg: ModelConfig
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-to-all expert parallelism (beyond the psum baseline, §Perf-2).

    Tokens are *sequence-sharded* over the model axis; each shard routes its
    own tokens, packs them per destination shard, and two ``all_to_all``s
    carry them to the expert owners and back. Per-device link bytes are
    ~2·k/tp of the token stream vs ~2x for the psum combine — the classic
    DeepSpeed-MoE/Switch schedule, and the same "send only what must move"
    idea Canary applies to reduction traffic.
    """
    ctx = get_parallel_context()
    mesh, maxis = ctx.mesh, ctx.model_axis
    tp = mesh.shape[maxis]
    e_loc = cfg.moe_experts // tp
    B, S, d = x.shape
    dp_spec = ctx.data_spec if B % ctx.dp_size == 0 else None

    def local(px, xx):
        b_loc, s_loc, _ = xx.shape
        n = b_loc * s_loc
        x2d = xx.reshape(n, d)
        k = cfg.moe_top_k
        top_w, top_e, aux = _route(px, x2d, cfg)
        flat_e = top_e.reshape(-1)                       # (n*k,)
        flat_w = top_w.reshape(-1)
        dest = flat_e // e_loc                           # destination shard
        order = jnp.argsort(dest)
        sd = dest[order]
        first = jnp.searchsorted(sd, sd, side="left")
        pos = jnp.arange(sd.shape[0]) - first            # rank within dest
        cap = max(8, -(-int(n * k / tp * cfg.moe_capacity_factor) // 8) * 8)
        ok = pos < cap
        src_slot = order                                  # (n*k,) originating slot
        send_x = jnp.zeros((tp, cap, d), x2d.dtype).at[
            jnp.where(ok, sd, tp), jnp.where(ok, pos, cap)].set(
            x2d[src_slot // k], mode="drop")
        send_e = jnp.full((tp, cap), cfg.moe_experts, jnp.int32).at[
            jnp.where(ok, sd, tp), jnp.where(ok, pos, cap)].set(
            flat_e[order], mode="drop")
        # ship to expert owners
        recv_x = lax.all_to_all(send_x, maxis, split_axis=0, concat_axis=0,
                                tiled=True)              # (tp*cap, d)? tiled
        recv_e = lax.all_to_all(send_e, maxis, split_axis=0, concat_axis=0,
                                tiled=True)
        recv_x = recv_x.reshape(tp * cap, d)
        recv_e = recv_e.reshape(tp * cap)
        shard = lax.axis_index(maxis)
        le = recv_e - shard * e_loc                      # local expert id
        valid = (le >= 0) & (le < e_loc)
        order2 = jnp.argsort(jnp.where(valid, le, e_loc))
        se2 = jnp.where(valid, le, e_loc)[order2]
        first2 = jnp.searchsorted(se2, se2, side="left")
        pos2 = jnp.arange(se2.shape[0]) - first2
        cap2 = max(8, -(-int(tp * cap / e_loc
                             * cfg.moe_capacity_factor) // 8) * 8)
        ok2 = (pos2 < cap2) & (se2 < e_loc)
        buf = jnp.zeros((e_loc, cap2, d), x2d.dtype).at[
            jnp.where(ok2, se2, e_loc), jnp.where(ok2, pos2, cap2)].set(
            recv_x[order2], mode="drop")
        out = _expert_ffn(px, buf)
        # inverse local permutation back to (tp*cap, d)
        vals2 = out[jnp.clip(se2, 0, e_loc - 1), jnp.minimum(pos2, cap2 - 1)]
        vals2 = jnp.where(ok2[:, None], vals2, 0.0)
        back_flat = jnp.zeros((tp * cap, d), x2d.dtype).at[order2].set(vals2)
        back = lax.all_to_all(back_flat.reshape(tp, cap, d), maxis,
                              split_axis=0, concat_axis=0, tiled=True)
        back = back.reshape(tp, cap, d)
        # combine at source: slot (dest, pos) -> original token
        got = back[jnp.minimum(sd, tp - 1), jnp.minimum(pos, cap - 1)]
        got = jnp.where(ok[:, None], got, 0.0)
        w_sorted = flat_w[order].astype(got.dtype)
        y = jnp.zeros((n, d), x2d.dtype).at[src_slot // k].add(
            got * w_sorted[:, None])
        aux = lax.pmean(aux, maxis)
        return y.reshape(xx.shape), aux

    pspec_params = {
        "router": P(),
        "w_up": P(maxis, None, None),
        "w_gate": P(maxis, None, None),
        "w_down": P(maxis, None, None),
    }
    routed = {kk: v for kk, v in p.items() if kk != "shared"}
    in_specs = ({kk: pspec_params.get(kk, P()) for kk in routed},
                P(dp_spec, maxis, None))
    out_specs = (P(dp_spec, maxis, None), P())
    y, aux = jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)(routed, x)
    return y, aux


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Shared experts always run densely."""
    B, S, d = x.shape
    ctx = get_parallel_context()
    impl = cfg.moe_impl
    if impl == "auto":
        use_ep = (ctx is not None and ctx.allow_shardmap_layers
                  and ctx.mesh.shape[ctx.model_axis] > 1
                  and cfg.moe_experts % ctx.mesh.shape[ctx.model_axis] == 0)
        impl = "ep" if use_ep else "dense"
    if impl == "ep_a2a" and ctx is not None and ctx.allow_shardmap_layers:
        tp = ctx.mesh.shape[ctx.model_axis]
        if S % tp == 0 and cfg.moe_experts % tp == 0:
            y, aux = _moe_ep_a2a_shardmap(p, x, cfg)
        else:  # decode (S=1) or non-divisible: fall back to psum combine
            y, aux = _moe_ep_shardmap(p, x, cfg)
    elif impl == "ep" and ctx is not None and ctx.allow_shardmap_layers:
        y, aux = _moe_ep_shardmap(p, x, cfg)
    else:
        maxis = ctx.model_axis if ctx is not None else None
        y2d, aux = _moe_dense(p, x.reshape(B * S, d), cfg, maxis)
        y = y2d.reshape(B, S, d)
    if "shared" in p:
        y = y + mlp_forward(p["shared"], x, "swiglu")
    return y, aux
