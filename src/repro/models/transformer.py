"""Unified decoder stack covering every zoo architecture.

Features, all driven by ``ModelConfig``:

* dense / MoE / SSM (Mamba-2) / hybrid (Jamba-style interleave) mixers,
* GQA attention with RoPE / M-RoPE / none, optional QKV bias, sliding window,
* encoder-decoder (Whisper) with cross-attention,
* stub modality frontends (VLM patch prefix, audio frame encoder input),
* scan-over-layers with per-period parameter stacking so compile time is
  depth-independent (heterogeneous hybrids scan over their repeat period),
* KV / SSM-state caches with single-token ``decode_step`` (ring-buffer cache
  for sliding-window serving).

Everything is pure-functional: ``init_params`` builds the pytree,
``forward`` / ``decode_step`` consume it.
"""
from __future__ import annotations

from repro.compat import patch_jax as _patch_jax

_patch_jax()  # repro.models.__init__ is lazy; direct imports land here first

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (Params, apply_mrope, apply_rope, attention_forward,
                     decode_attention, embed, init_attention, init_embeddings,
                     init_mlp, init_rmsnorm, mlp_forward, rmsnorm, unembed)
from .mamba2 import (init_mamba2, mamba2_decode_step, mamba2_forward,
                     mamba2_init_cache)
from .moe import init_moe, moe_forward


# --------------------------------------------------------------------- period
def layer_period(cfg: ModelConfig) -> int:
    """Smallest repeating pattern of (mixer kind, moe-ness) over layers."""
    per = 1
    if cfg.arch_type == "hybrid" and cfg.attn_every > 0:
        per = cfg.attn_every
    if cfg.moe_experts > 0 and cfg.moe_every > 1:
        per = _lcm(per, cfg.moe_every)
    if cfg.num_layers % per != 0:
        raise ValueError(f"{cfg.name}: num_layers={cfg.num_layers} not divisible "
                         f"by layer period {per}")
    return per


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------- layer init
def _init_decoder_sublayer(key, cfg: ModelConfig, j: int, dtype) -> Params:
    ks = jax.random.split(key, 6)
    kind = cfg.layer_kind(j)
    p: Params = {"norm1": init_rmsnorm(cfg.d_model)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg, dtype)
    else:
        p["ssm"] = init_mamba2(ks[0], cfg, dtype)
    if cfg.is_encoder_decoder and kind == "attn":
        p["norm_cross"] = init_rmsnorm(cfg.d_model)
        p["cross"] = init_attention(ks[1], cfg, dtype)
    if cfg.layer_is_moe(j):
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["moe"] = init_moe(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def _init_encoder_layer(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": init_rmsnorm(cfg.d_model),
        "attn": init_attention(ks[0], cfg, dtype),
        "norm2": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff or 4 * cfg.d_model,
                        cfg.activation, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    per = layer_period(cfg)
    n_per = cfg.num_layers // per
    layers: List[Params] = []
    for j in range(per):
        jkeys = jax.random.split(jax.random.fold_in(keys[0], j), n_per)
        layers.append(jax.vmap(
            lambda k: _init_decoder_sublayer(k, cfg, j, dtype))(jkeys))
    params: Params = {
        "embed": init_embeddings(keys[1], cfg, dtype),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if cfg.is_encoder_decoder:
        ekeys = jax.random.split(keys[2], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_encoder_layer(k, cfg, dtype))(ekeys)
        params["enc_norm"] = init_rmsnorm(cfg.d_model)
    return params


# ------------------------------------------------------------------- forward
def _default_positions(cfg: ModelConfig, B: int, S: int, offset: int = 0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.rope_mode == "mrope":
        return jnp.repeat(pos[..., None], 3, axis=-1)  # text: t==h==w
    return pos


def _decoder_sublayer(p: Params, x, positions, cfg: ModelConfig, j: int,
                      enc_out) -> Tuple[jnp.ndarray, jnp.ndarray]:
    kind = cfg.layer_kind(j)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        x = x + attention_forward(p["attn"], h, positions, cfg, causal=True)
    else:
        x = x + mamba2_forward(p["ssm"], h, cfg)
    if enc_out is not None and kind == "attn":
        hc = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        ck = jnp.einsum("bsd,dhx->bshx", enc_out, p["cross"]["wk"])
        cv = jnp.einsum("bsd,dhx->bshx", enc_out, p["cross"]["wv"])
        x = x + attention_forward(p["cross"], hc, positions, cfg,
                                  causal=False, kv_override=(ck, cv))
    if "moe" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = moe_forward(p["moe"], h2, cfg)
        x = x + y
    elif "mlp" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp_forward(p["mlp"], h2, cfg.activation)
    return x, aux


def _activation_constraint(x: jnp.ndarray) -> jnp.ndarray:
    """Pin (B, S, d) activations to batch-over-data sharding (see
    ParallelContext.constrain_activations)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.context import get_parallel_context
    ctx = get_parallel_context()
    if ctx is None or not ctx.constrain_activations or x.ndim != 3:
        return x
    seq = None
    if ctx.sequence_parallel and x.shape[1] % ctx.tp_size == 0:
        seq = ctx.model_axis
    return lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(ctx.data_spec, seq, None)))


def _run_decoder_stack(params: Params, x, positions, cfg: ModelConfig,
                       enc_out=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    per = layer_period(cfg)

    def period_body(carry, per_params):
        h, aux = carry
        h = _activation_constraint(h)
        for j in range(per):
            h, a = _decoder_sublayer(per_params[j], h, positions, cfg, j,
                                     enc_out)
            aux = aux + a
        return (h, aux), None

    body = period_body
    if cfg.remat:
        body = jax.checkpoint(period_body)
    if cfg.scan_layers:
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        n_per = cfg.num_layers // per
        for i in range(n_per):
            sl = jax.tree.map(lambda v: v[i], params["layers"])
            (x, aux), _ = body((x, aux), sl)
    return x, aux


def _sinusoidal(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Whisper-style encoder over stub conv-frontend frames (B, T, d)."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)
    ecfg = cfg.with_(rope_mode="none", sliding_window=0)

    def layer(h, p):
        h = _activation_constraint(h)
        a = rmsnorm(p["norm1"], h, cfg.norm_eps)
        h = h + attention_forward(p["attn"], a, None, ecfg, causal=False)
        m = rmsnorm(p["norm2"], h, cfg.norm_eps)
        h = h + mlp_forward(p["mlp"], m, cfg.activation)
        return h, None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = lax.scan(body, x, params["encoder"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
            positions=None, extra_embeds: Optional[jnp.ndarray] = None,
            frames: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training / prefill forward.

    tokens: (B, S) int32. ``extra_embeds`` (VLM): (B, P, d) patch embeddings
    prepended to the token embeddings. ``frames`` (audio): (B, T, d) stub
    frame embeddings consumed by the encoder.
    Returns (logits (B, S_total, vocab), moe_aux_loss).
    """
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        S = x.shape[1]
    if positions is None:
        positions = _default_positions(cfg, B, S)
    enc_out = None
    if cfg.is_encoder_decoder:
        if frames is None:
            raise ValueError("encoder-decoder model needs `frames`")
        enc_out = encode(params, frames, cfg)
    x, aux = _run_decoder_stack(params, x, positions, cfg, enc_out)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x), aux


# --------------------------------------------------------------------- cache
def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, Any]:
    """Decode cache pytree (zeros); shape-compatible with decode_step."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    per = layer_period(cfg)
    n_per = cfg.num_layers // per
    C = cache_len(cfg, max_len)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    layers = []
    for j in range(per):
        kind = cfg.layer_kind(j)
        if kind == "attn":
            entry = {"k": jnp.zeros((n_per, batch, C, kv, hd), dtype),
                     "v": jnp.zeros((n_per, batch, C, kv, hd), dtype)}
        else:
            mc = mamba2_init_cache(cfg, batch)
            entry = {k: jnp.broadcast_to(v, (n_per,) + v.shape).copy()
                     for k, v in mc.items()}
        layers.append(entry)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32), "layers": layers}
    if cfg.is_encoder_decoder:
        cache["cross"] = {
            "k": jnp.zeros((n_per, batch, cfg.encoder_seq, kv, hd), dtype),
            "v": jnp.zeros((n_per, batch, cfg.encoder_seq, kv, hd), dtype),
        }
    return cache


def prepare_cross_cache(params: Params, frames: jnp.ndarray, cfg: ModelConfig
                        ) -> Dict[str, jnp.ndarray]:
    """Whisper: run the encoder once and project per-layer cross K/V."""
    enc = encode(params, frames, cfg)

    per = layer_period(cfg)
    assert per == 1, "enc-dec archs use homogeneous decoder stacks"
    cross = params["layers"][0]["cross"]
    k = jnp.einsum("bsd,ndhx->nbshx", enc, cross["wk"])
    v = jnp.einsum("bsd,ndhx->nbshx", enc, cross["wv"])
    return {"k": k.astype(enc.dtype), "v": v.astype(enc.dtype)}


def _attn_decode_sublayer(p: Params, x1, pos, cache_kv, cfg: ModelConfig,
                          cross_kv=None):
    """x1: (B, 1, d); cache_kv: {'k': (B,C,KV,hd), 'v': ...}."""
    B = x1.shape[0]
    C = cache_kv["k"].shape[1]
    h = rmsnorm(p["norm1"], x1, cfg.norm_eps)
    q = jnp.einsum("bsd,dhx->bshx", h, p["attn"]["wq"])
    k1 = jnp.einsum("bsd,dhx->bshx", h, p["attn"]["wk"])
    v1 = jnp.einsum("bsd,dhx->bshx", h, p["attn"]["wv"])
    if "bq" in p["attn"]:
        q, k1, v1 = q + p["attn"]["bq"], k1 + p["attn"]["bk"], v1 + p["attn"]["bv"]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (B, 1))
    if cfg.rope_mode == "standard":
        q = apply_rope(q, posb, cfg.rope_theta)
        k1 = apply_rope(k1, posb, cfg.rope_theta)
    elif cfg.rope_mode == "mrope":
        p3 = jnp.repeat(posb[..., None], 3, axis=-1)
        q = apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k1 = apply_mrope(k1, p3, cfg.rope_theta, cfg.mrope_sections)
    write = pos % C if cfg.sliding_window > 0 else pos
    kc = lax.dynamic_update_slice(cache_kv["k"], k1.astype(cache_kv["k"].dtype),
                                  (0, write, 0, 0))
    vc = lax.dynamic_update_slice(cache_kv["v"], v1.astype(cache_kv["v"].dtype),
                                  (0, write, 0, 0))
    valid = jnp.minimum(pos + 1, C)
    att = decode_attention(q, kc, vc, valid)
    x1 = x1 + jnp.einsum("bshx,hxd->bsd", att, p["attn"]["wo"])
    if cross_kv is not None and "cross" in p:
        hc = rmsnorm(p["norm_cross"], x1, cfg.norm_eps)
        qc = jnp.einsum("bsd,dhx->bshx", hc, p["cross"]["wq"])
        catt = decode_attention(qc, cross_kv["k"], cross_kv["v"],
                                cross_kv["k"].shape[1])
        x1 = x1 + jnp.einsum("bshx,hxd->bsd", catt, p["cross"]["wo"])
    return x1, {"k": kc, "v": vc}


def decode_step(params: Params, cache: Dict[str, Any], tokens1: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step. tokens1: (B, 1) -> logits (B, 1, vocab), new cache."""
    pos = cache["pos"]
    x = embed(params["embed"], tokens1)
    per = layer_period(cfg)

    cross_all = cache.get("cross")

    def period_body(x1, scanned):
        per_params, per_cache, cross_kv = scanned
        ckv = cross_kv if isinstance(cross_kv, dict) else None
        new_cache = []
        for j in range(per):
            p = per_params[j]
            kind = cfg.layer_kind(j)
            if kind == "attn":
                x1, nkv = _attn_decode_sublayer(p, x1, pos, per_cache[j], cfg,
                                                cross_kv=ckv)
                new_cache.append(nkv)
            else:
                h = rmsnorm(p["norm1"], x1, cfg.norm_eps)
                y, nc = mamba2_decode_step(p["ssm"], h, per_cache[j], cfg)
                x1 = x1 + y
                new_cache.append(nc)
            if "moe" in p:
                h2 = rmsnorm(p["norm2"], x1, cfg.norm_eps)
                ym, _ = moe_forward(p["moe"], h2, cfg)
                x1 = x1 + ym
            elif "mlp" in p:
                h2 = rmsnorm(p["norm2"], x1, cfg.norm_eps)
                x1 = x1 + mlp_forward(p["mlp"], h2, cfg.activation)
        return x1, new_cache

    n_per = cfg.num_layers // per
    if cross_all is not None:
        xs = (params["layers"], cache["layers"], cross_all)
    else:
        # scan needs a uniform pytree; dummy empty leaf stands in for cross
        xs = (params["layers"], cache["layers"], jnp.zeros((n_per, 0)))
    x, new_layers = lax.scan(period_body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + 1
    return logits, new_cache
