"""Core neural layers: RMSNorm, RoPE / M-RoPE, GQA attention (full, chunked
online-softmax, sliding-window decode), and dense MLPs.

All functions are pure; parameters are plain pytrees created by the ``init_*``
helpers. Shapes follow the (batch, seq, heads, head_dim) convention.
"""
from __future__ import annotations

from repro.compat import patch_jax as _patch_jax

_patch_jax()  # repro.models.__init__ is lazy; direct imports land here first

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard rotary embedding. x: (B, S, H, D); positions: (B, S)."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191 §3.1).

    The head_dim/2 frequency slots are split into (t, h, w) sections; each
    section rotates by its own position stream. ``positions3``: (B, S, 3).
    For pure text all three streams are equal and M-RoPE == RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                           # (half,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                          # (B, S, 3)
        jnp.broadcast_to(sec_ids[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1)                                                 # (B, S, half)
    ang = pos * freqs                                            # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention
def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, h, hd)) * scale).astype(dtype),
        "wk": (jax.random.normal(k2, (d, kv, hd)) * scale).astype(dtype),
        "wv": (jax.random.normal(k3, (d, kv, hd)) * scale).astype(dtype),
        "wo": (jax.random.normal(k4, (h, hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype=dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype=dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype=dtype)
    return p


def _gqa_logits(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (B, Sq, H, D), k: (B, Sk, KV, D) -> logits (B, KV, G, Sq, Sk)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(D).astype(q.dtype)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs: (B, KV, G, Sq, Sk), v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    B, KV, G, Sq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, KV * G, -1)


def full_attention(q, k, v, *, causal: bool, sliding_window: int = 0,
                   q_offset: int = 0) -> jnp.ndarray:
    """Materialized-logits attention (short sequences)."""
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    logits = _gqa_logits(q, k).astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window > 0:
        mask &= kpos > qpos - sliding_window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return _gqa_out(probs, v)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                      sliding_window: int = 0) -> jnp.ndarray:
    """Online-softmax attention, O(chunk^2) memory (FlashAttention recurrence).

    Scans over query blocks (outer) and key/value blocks (inner), carrying the
    (max, sum, acc) online-softmax state. Block-level causal masking is
    applied inside the scan; fully-masked blocks still issue their matmuls
    (a known ~2x score-FLOP overhead vs. a triangular kernel — the Pallas
    flash kernel in ``repro.kernels.flash_attention`` skips them on TPU; see
    EXPERIMENTS.md §Roofline for the accounting).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    G = H // KV
    qb = q.reshape(B, n, chunk, H, D).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, n, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n, chunk, KV, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, kblk, vblk = kj_blk
            logits = _gqa_logits(qblk, kblk).astype(jnp.float32)
            qpos = qi * chunk + jnp.arange(chunk)[:, None]
            kpos = kj * chunk + jnp.arange(chunk)[None, :]
            mask = jnp.ones((chunk, chunk), dtype=bool)
            if causal:
                mask &= kpos <= qpos
            if sliding_window > 0:
                mask &= kpos > qpos - sliding_window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(qblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, chunk), -1e30, dtype=jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk), dtype=jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk, D), dtype=qblk.dtype)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(n), kb, vb))
        out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
        # (B, KV, G, chunk, D) -> (B, chunk, H, D)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, chunk, H, D)
        return None, out

    _, outs = lax.scan(q_step, None, (jnp.arange(n), qb))
    # (n, B, chunk, H, D) -> (B, S, H, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def decode_attention(q1, k_cache, v_cache, valid_len, *,
                     ring: bool = False, window: int = 0,
                     write_pos: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-query attention against a KV cache.

    q1: (B, 1, H, D); caches: (B, C, KV, D). ``valid_len`` (scalar or (B,))
    marks how many slots are populated. For sliding-window serving the cache
    is a ring buffer of size ``window`` — every populated slot is in-window
    by construction, so only validity masking is required.
    """
    B, C = k_cache.shape[0], k_cache.shape[1]
    logits = _gqa_logits(q1, k_cache).astype(jnp.float32)  # (B,KV,G,1,C)
    slot = jnp.arange(C)[None, :]                          # (1, C)
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = jnp.broadcast_to(vl, (B,))
    mask = slot < vl[:, None]                              # (B, C)
    logits = jnp.where(mask[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q1.dtype)
    return _gqa_out(probs, v_cache)


def attention_forward(p: Params, x: jnp.ndarray, positions, cfg: ModelConfig,
                      *, causal: bool = True,
                      kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
                      ) -> jnp.ndarray:
    """Projection + RoPE + attention for training / prefill.

    ``kv_override`` supplies externally-computed K/V (cross-attention)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if kv_override is None:
        k = jnp.einsum("bsd,dhx->bshx", x, p["wk"])
        v = jnp.einsum("bsd,dhx->bshx", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        if cfg.rope_mode == "standard":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        elif cfg.rope_mode == "mrope":
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        k, v = kv_override
        # cross-attention: rotary on neither side (whisper convention)

    if S >= cfg.attn_chunk_threshold and S % cfg.attn_chunk == 0 \
            and kv_override is None:
        out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                                sliding_window=cfg.sliding_window)
    else:
        out = full_attention(q, k, v, causal=causal,
                             sliding_window=cfg.sliding_window)
    return jnp.einsum("bshx,hxd->bsd", out, p["wo"])


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d: int, f: int, activation: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[2], (d, f)) * d ** -0.5).astype(dtype)
    return p


def mlp_forward(p: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    up = x @ p["w_up"]
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif activation == "squared_relu":          # Nemotron-4 (arXiv:2402.16819)
        h = jnp.square(jax.nn.relu(up))
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(f"unknown activation {activation}")
    return h @ p["w_down"]


# ----------------------------------------------------------------- embeddings
def init_embeddings(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                 * cfg.d_model ** -0.5).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        * cfg.d_model ** -0.5).astype(dtype)
    return p


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in p:
        return x @ p["unembed"]
    return x @ p["tok"].T
