"""repro — production-grade JAX reproduction of
"Canary: Congestion-Aware In-Network Allreduce Using Dynamic Trees"
(De Sensi et al., 2023), plus its TPU-native adaptation and a multi-arch
training/serving framework around it.
"""
__version__ = "1.0.0"
