"""repro — production-grade JAX reproduction of
"Canary: Congestion-Aware In-Network Allreduce Using Dynamic Trees"
(De Sensi et al., 2023), plus its TPU-native adaptation and a multi-arch
training/serving framework around it.
"""
__version__ = "1.0.0"

# NOTE: jax compat shims (repro/compat.py) are installed by the jax-facing
# subpackages' __init__ modules, not here — importing the simulator core
# (repro.core.canary) must stay jax-free and fast.
