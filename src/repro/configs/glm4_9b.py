"""GLM-4 9B — dense decoder with extreme GQA (kv=2) and RoPE
[hf:THUDM/glm-4-9b]. 40 layers, d_model 4096, 32 heads, d_ff 13696,
vocab 151552.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        arch_type="dense",
        num_layers=40,
        d_model=4096,
        vocab_size=151552,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        activation="swiglu",
        rope_theta=10000.0,
        source="hf:THUDM/glm-4-9b",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="glm4-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, remat=False,
    )
