"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].

32 layers; one attention layer per 8 (the rest Mamba); MoE (16 experts,
top-2) on every other layer. GQA kv=8, d_ff 14336, vocab 65536.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        num_layers=32,
        d_model=4096,
        vocab_size=65536,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        activation="swiglu",
        rope_mode="none",          # Jamba uses no positional embeddings
        moe_experts=16,
        moe_top_k=2,
        moe_d_ff=14336,
        moe_every=2,
        moe_offset=1,
        attn_every=8,
        attn_offset=4,             # attention mid-period, as in the paper
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        source="arXiv:2403.19887",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="jamba-smoke", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        moe_experts=4, moe_top_k=2, moe_d_ff=512, moe_every=2, moe_offset=1,
        attn_every=2, attn_offset=1, ssm_state=16, ssm_chunk=16,
        remat=False,
    )
