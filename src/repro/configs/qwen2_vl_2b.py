"""Qwen2-VL 2B — VLM language decoder with M-RoPE [arXiv:2409.12191].

28 layers, d_model 1536, 12 heads (kv 2), d_ff 8960, vocab 151936. The
vision encoder is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, num_patches, d_model) that are prepended to
the token embeddings; M-RoPE handles the 3-D (t, h, w) positions.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        num_layers=28,
        d_model=1536,
        vocab_size=151936,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        activation="swiglu",
        qkv_bias=True,
        rope_mode="mrope",
        mrope_sections=(16, 24, 24),
        frontend="vision_stub",
        num_patches=256,
        source="arXiv:2409.12191",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="qwen2-vl-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        mrope_sections=(8, 12, 12), num_patches=8, remat=False,
    )
