"""DeepSeekMoE 16B — fine-grained expert segmentation with shared experts
[arXiv:2401.06066]. 28 layers, d_model 2048, MHA 16 heads, 64 routed experts
top-6 + 2 shared experts (expert hidden 1408), vocab 102400.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        arch_type="moe",
        num_layers=28,
        d_model=2048,
        vocab_size=102400,
        num_heads=16,
        num_kv_heads=16,          # MHA
        head_dim=128,
        d_ff=2816,                # 2 shared experts x 1408, fused
        activation="swiglu",
        moe_experts=64,
        moe_top_k=6,
        moe_shared_experts=2,
        moe_d_ff=1408,
        moe_every=1,
        source="arXiv:2401.06066",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="deepseek-moe-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=256, vocab_size=512,
        moe_experts=4, moe_top_k=2, moe_shared_experts=1, moe_d_ff=128,
        remat=False,
    )
