"""Whisper large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA), d_ff 5120,
vocab 51866. The mel-spectrogram + conv frontend is a STUB per the
assignment: ``input_specs`` provides 1500 precomputed frame embeddings
(B, 1500, d_model) consumed by the encoder. long_500k is skipped for this
arch (enc-dec full attention; see DESIGN.md §5).
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        arch_type="audio",
        num_layers=32,             # decoder layers
        encoder_layers=32,
        encoder_seq=1500,
        d_model=1280,
        vocab_size=51866,
        num_heads=20,
        num_kv_heads=20,           # MHA
        head_dim=64,
        d_ff=5120,
        activation="gelu",
        rope_mode="standard",      # adaptation: RoPE replaces learned abs-pos
        frontend="audio_stub",
        source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="whisper-smoke", num_layers=2, encoder_layers=2, encoder_seq=32,
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512,
        vocab_size=512, remat=False,
    )
