"""Qwen2 7B — dense GQA decoder with QKV bias [arXiv:2407.10671].

28 layers, d_model 3584, 28 heads (kv 4), d_ff 18944, vocab 152064.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        arch_type="dense",
        num_layers=28,
        d_model=3584,
        vocab_size=152064,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        activation="swiglu",
        qkv_bias=True,
        rope_theta=1000000.0,
        source="arXiv:2407.10671",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="qwen2-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        qkv_bias=True, remat=False,
    )
