"""Mamba-2 130M — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060]. 24 layers, d_model 768, state 128, expand 2,
head_dim 64, vocab 50280. No FFN blocks (pure Mamba stack).
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        arch_type="ssm",
        num_layers=24,
        d_model=768,
        vocab_size=50280,
        d_ff=0,                  # mamba2 stacks have no MLP blocks
        rope_mode="none",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="mamba2-smoke", num_layers=2, d_model=256, vocab_size=512,
        ssm_state=32, ssm_chunk=16, remat=False,
    )
