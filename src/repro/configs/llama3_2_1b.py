"""Llama 3.2 1B — small dense GQA decoder [hf:meta-llama/Llama-3.2-1B].

16 layers, d_model 2048, 32 heads (kv 8, head_dim 64), d_ff 8192,
vocab 128256, RoPE theta 500000.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        arch_type="dense",
        num_layers=16,
        d_model=2048,
        vocab_size=128256,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        activation="swiglu",
        rope_theta=500000.0,
        tie_embeddings=True,
        source="hf:meta-llama/Llama-3.2-1B",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="llama3.2-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, remat=False,
    )
