"""Nemotron-4 340B — dense GQA decoder with squared-ReLU MLPs
[arXiv:2402.16819]. 96 layers, d_model 18432, 96 heads (kv 8), d_ff 73728,
vocab 256000.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        arch_type="dense",
        num_layers=96,
        d_model=18432,
        vocab_size=256000,
        num_heads=96,
        num_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        activation="squared_relu",
        rope_theta=10000.0,
        source="arXiv:2402.16819",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="nemotron-smoke", num_layers=2, d_model=384, num_heads=6,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=512, remat=False,
    )
