"""Architecture configs (one module per assigned architecture).

Each module exposes ``full()`` — the exact published configuration — and
``smoke()`` — a reduced same-family variant (<=2 layers, d_model<=512,
<=4 experts) for CPU tests. ``repro.models.registry`` indexes them.
"""
from . import (deepseek_moe_16b, glm4_9b, jamba_v0_1_52b, llama3_2_1b,
               mamba2_130m, nemotron_4_340b, qwen2_7b, qwen2_moe_a2_7b,
               qwen2_vl_2b, whisper_large_v3)

ARCH_MODULES = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "nemotron-4-340b": nemotron_4_340b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "glm4-9b": glm4_9b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "mamba2-130m": mamba2_130m,
    "whisper-large-v3": whisper_large_v3,
    "llama3.2-1b": llama3_2_1b,
    "qwen2-7b": qwen2_7b,
}

ARCH_NAMES = list(ARCH_MODULES)
