"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B]. 24 layers, d_model 2048, MHA 16 heads,
expert hidden 1408, shared hidden 5632, vocab 151936.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        arch_type="moe",
        num_layers=24,
        d_model=2048,
        vocab_size=151936,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=5632,                # shared-expert hidden (4 shared, fused)
        activation="swiglu",
        qkv_bias=True,
        moe_experts=60,
        moe_top_k=4,
        moe_shared_experts=4,
        moe_d_ff=1408,
        moe_every=1,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="qwen2-moe-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=256, vocab_size=512,
        moe_experts=4, moe_top_k=2, moe_shared_experts=1, moe_d_ff=128,
        remat=False,
    )
