"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       min_ratio: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        prog = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps),
                        0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return sched


def linear_warmup_constant(peak_lr: float, warmup_steps: int):
    def sched(step):
        s = step.astype(jnp.float32)
        return peak_lr * jnp.minimum(1.0, s / max(1, warmup_steps))
    return sched
