"""AdamW with decoupled weight decay, global-norm clipping and configurable
state dtype (fp32 default; bf16 moments for memory-tight configs — the
340B single-pod memory analysis depends on this knob).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # moments dtype; bf16 halves optimizer HBM
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params: Any, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig
           ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    lr = cfg.lr if cfg.schedule is None else cfg.schedule(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    sdt = jnp.dtype(cfg.state_dtype)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # no decay on norms/bias
            delta = delta + cfg.weight_decay * p32
        new_p = (p32 - lr * delta).astype(p.dtype)
        return new_p, m32.astype(sdt), v32.astype(sdt)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
