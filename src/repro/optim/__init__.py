from ..compat import patch_jax as _patch_jax

_patch_jax()

from .adamw import AdamWConfig, AdamWState, global_norm, init, update
from .schedules import cosine_with_warmup, linear_warmup_constant

__all__ = ["AdamWConfig", "AdamWState", "cosine_with_warmup", "global_norm",
           "init", "linear_warmup_constant", "update"]
