"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True so every entry point runs (and is tested) on
CPU; on real TPU hardware pass ``interpret=False`` (the launcher does this
automatically via ``on_tpu()``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .fixedpoint import dequantize, quantize
from .flash_attention import flash_attention
from .packet_accum import packet_accumulate


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def quantize_op(x, scale, interpret: bool = True):
    return quantize(x, scale, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def dequantize_op(q, scale, interpret: bool = True):
    return dequantize(q, scale, interpret=interpret)


@partial(jax.jit, static_argnames=("num_slots", "interpret"))
def packet_accumulate_op(slot_ids, payloads, num_slots: int,
                         interpret: bool = True):
    return packet_accumulate(slot_ids, payloads, num_slots,
                             interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention_op(q, k, v, causal: bool = True, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, interpret=interpret)


def fixed_point_scale(gmax, *, bits: int, world: int):
    """Shared quantization scale for fixed-point reduction paths: ``gmax``
    is the global max |x| across participants (every device must use the
    same scale); headroom for ``world`` summands prevents int32 overflow."""
    return (2.0 ** bits - 1.0) / (gmax * world + 1e-30)


def fixed_point_allreduce_wrap(x: jnp.ndarray,
                               reduce_fn: Callable[[jnp.ndarray], jnp.ndarray],
                               gmax: jnp.ndarray, bits: int, world: int
                               ) -> jnp.ndarray:
    """Quantize -> integer reduce -> dequantize (paper §6 switch arithmetic).

    Integer addition is associative, so the result is bit-identical for any
    dynamic tree shape.
    """
    scale = fixed_point_scale(gmax, bits=bits, world=world)
    q = quantize(x, scale, interpret=not on_tpu())
    r = reduce_fn(q)
    return dequantize(r, scale, interpret=not on_tpu()).astype(x.dtype)
