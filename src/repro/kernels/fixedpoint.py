"""Pallas TPU kernels: fixed-point quantize / dequantize.

The paper (§6) notes programmable switches have no FPUs, so in-network
allreduce payloads are converted to fixed point before hitting the fabric.
On TPU we keep the same trick for a different prize: integer accumulation is
associative, so a Canary-style *dynamic* tree produces bit-identical sums no
matter which tree shape each block took.

VMEM tiling: elementwise over (8k, 128)-aligned tiles; the scalar scale rides
in SMEM. Kernels are validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_ROWS = 256
TILE_COLS = 128


def _quant_kernel(scale_ref, x_ref, o_ref):
    o_ref[...] = jnp.round(
        x_ref[...].astype(jnp.float32) * scale_ref[0]).astype(jnp.int32)


def _dequant_kernel(scale_ref, q_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) / scale_ref[0]


def quantize(x: jnp.ndarray, scale, *, interpret: bool = True) -> jnp.ndarray:
    """Elementwise fixed-point quantization via a tiled Pallas kernel."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = TILE_COLS
    rows = max(1, -(-n // cols))
    grid_rows = -(-rows // TILE_ROWS)
    padded_rows = grid_rows * TILE_ROWS
    pad = padded_rows * cols - n
    x2 = jnp.pad(flat, (0, pad)).reshape(padded_rows, cols)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _quant_kernel,
        grid=(grid_rows,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((TILE_ROWS, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, cols), jnp.int32),
        interpret=interpret,
    )(scale_arr, x2)
    return out.reshape(-1)[:n].reshape(orig_shape)


def dequantize(q: jnp.ndarray, scale, *, interpret: bool = True) -> jnp.ndarray:
    orig_shape = q.shape
    flat = q.reshape(-1)
    n = flat.shape[0]
    cols = TILE_COLS
    rows = max(1, -(-n // cols))
    grid_rows = -(-rows // TILE_ROWS)
    padded_rows = grid_rows * TILE_ROWS
    pad = padded_rows * cols - n
    q2 = jnp.pad(flat, (0, pad)).reshape(padded_rows, cols)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(grid_rows,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((TILE_ROWS, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, cols), jnp.float32),
        interpret=interpret,
    )(scale_arr, q2)
    return out.reshape(-1)[:n].reshape(orig_shape)
