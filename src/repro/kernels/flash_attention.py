"""Pallas TPU kernel: causal GQA flash attention.

The serving/training compute hot-spot of every attention architecture in the
zoo. Classic online-softmax blocking: grid (batch, q-head, q-block, kv-block)
with the innermost kv dimension revisiting a VMEM scratch carrying the
(running max, running sum, accumulator). Fully-masked kv blocks (kv start
beyond the causal frontier) skip their matmuls via ``pl.when`` — unlike the
jnp chunked fallback, the kernel does *not* pay the 2x wasted-FLOP tax.

Validated in interpret mode against ``ref.flash_attention_ref`` across
shape/dtype sweeps (tests/kernels/).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -1e30)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip fully-masked kv blocks entirely (no wasted MXU work)
        pl.when(kj * bk <= qi * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = True
                    ) -> jnp.ndarray:
    """q: (B, H, S, D); k/v: (B, KV, S, D) with H % KV == 0 -> (B, H, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    assert H % KV == 0
    group = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               causal=causal, scale=scale)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
