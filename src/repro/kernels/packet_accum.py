"""Pallas TPU kernel: packet -> descriptor accumulation (switch aggregation).

The hot loop of the paper's data plane (§3.1.1): every arriving packet's
payload is summed into the descriptor slot its block id hashes to. As a
TPU kernel this is a segment-sum; the TPU-native formulation is a one-hot
matmul per packet tile — the MXU performs the scatter-accumulate at full
throughput, and the (slots, payload) accumulator block is revisited across
grid steps (a standard Pallas accumulation pattern).

Accumulation dtype follows the payload: int32 payloads accumulate (and
return) int32 — the associative fixed-point path (§6: switch ALUs are
integer-only) that makes dynamic-tree replay bit-deterministic — while float
payloads accumulate in float32 as before.

Used by the software switch emulation benchmarks (Fig. 6), the trace-replay
executor (``repro.core.trace.executor``) and validated against
``ref.packet_accumulate_ref`` over shape/dtype sweeps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PKT_TILE = 128   # packets per grid step
PAY_TILE = 128   # payload lanes


def _accum_kernel(ids_ref, x_ref, o_ref, *, num_slots: int, acc_dtype):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                                   # (PKT_TILE,)
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], num_slots), 1)).astype(acc_dtype)
    # MXU scatter-accumulate: (slots, pkts) @ (pkts, pay)
    o_ref[...] += jnp.dot(onehot.T, x_ref[...].astype(acc_dtype),
                          preferred_element_type=acc_dtype)


def accumulate_dtype(payload_dtype) -> jnp.dtype:
    """int32 payloads accumulate in int32 (associative); floats in float32.

    Other integer dtypes are rejected: casting them to int32 would silently
    wrap (int64/uint32) and the fixed-point contract is int32-exact.
    """
    if jnp.issubdtype(payload_dtype, jnp.integer):
        if jnp.dtype(payload_dtype) != jnp.dtype(jnp.int32):
            raise TypeError(f"integer payloads must be int32 (got "
                            f"{jnp.dtype(payload_dtype).name}); quantize via "
                            f"repro.kernels.fixedpoint first")
        return jnp.int32
    return jnp.float32


def packet_accumulate(slot_ids: jnp.ndarray, payloads: jnp.ndarray,
                      num_slots: int, *, interpret: bool = True
                      ) -> jnp.ndarray:
    """slot_ids: (N,) int32; payloads: (N, D) -> (num_slots, D).

    Output dtype is :func:`accumulate_dtype` of the payload dtype: int32 for
    integer payloads, float32 otherwise.
    """
    n, d = payloads.shape
    acc_dtype = accumulate_dtype(payloads.dtype)
    grid = -(-n // PKT_TILE)
    pad_n = grid * PKT_TILE - n
    ids = jnp.pad(slot_ids.astype(jnp.int32), (0, pad_n),
                  constant_values=num_slots)  # padded ids match no slot
    pay = jnp.pad(payloads, ((0, pad_n), (0, 0)))
    pad_d = (-d) % PAY_TILE
    if pad_d:
        pay = jnp.pad(pay, ((0, 0), (0, pad_d)))
    out = pl.pallas_call(
        partial(_accum_kernel, num_slots=num_slots, acc_dtype=acc_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((PKT_TILE,), lambda i: (i,)),
            pl.BlockSpec((PKT_TILE, pay.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_slots, pay.shape[1]), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_slots, pay.shape[1]), acc_dtype),
        interpret=interpret,
    )(ids, pay)
    return out[:, :d]
