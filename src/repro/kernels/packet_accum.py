"""Pallas TPU kernel: packet -> descriptor accumulation (switch aggregation).

The hot loop of the paper's data plane (§3.1.1): every arriving packet's
payload is summed into the descriptor slot its block id hashes to. As a
TPU kernel this is a segment-sum; the TPU-native formulation is a one-hot
matmul per packet tile — the MXU performs the scatter-accumulate at full
throughput, and the (slots, payload) accumulator block is revisited across
grid steps (a standard Pallas accumulation pattern).

Used by the software switch emulation benchmarks (Fig. 6) and validated
against ``ref.packet_accumulate_ref`` over shape/dtype sweeps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PKT_TILE = 128   # packets per grid step
PAY_TILE = 128   # payload lanes


def _accum_kernel(ids_ref, x_ref, o_ref, *, num_slots: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                                   # (PKT_TILE,)
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], num_slots), 1)).astype(jnp.float32)
    # MXU scatter-accumulate: (slots, pkts) @ (pkts, pay)
    o_ref[...] += jnp.dot(onehot.T, x_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def packet_accumulate(slot_ids: jnp.ndarray, payloads: jnp.ndarray,
                      num_slots: int, *, interpret: bool = True
                      ) -> jnp.ndarray:
    """slot_ids: (N,) int32; payloads: (N, D) -> (num_slots, D) float32."""
    n, d = payloads.shape
    grid = -(-n // PKT_TILE)
    pad_n = grid * PKT_TILE - n
    ids = jnp.pad(slot_ids.astype(jnp.int32), (0, pad_n),
                  constant_values=num_slots)  # padded ids match no slot
    pay = jnp.pad(payloads, ((0, pad_n), (0, 0)))
    pad_d = (-d) % PAY_TILE
    if pad_d:
        pay = jnp.pad(pay, ((0, 0), (0, pad_d)))
    out = pl.pallas_call(
        partial(_accum_kernel, num_slots=num_slots),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((PKT_TILE,), lambda i: (i,)),
            pl.BlockSpec((PKT_TILE, pay.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_slots, pay.shape[1]), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_slots, pay.shape[1]), jnp.float32),
        interpret=interpret,
    )(ids, pay)
    return out[:, :d]
