"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Float -> fixed-point int32 (paper §6: switch ALUs are integer-only)."""
    return jnp.round(x.astype(jnp.float32) * scale).astype(jnp.int32)


def dequantize_ref(q: jnp.ndarray, scale: float) -> jnp.ndarray:
    return q.astype(jnp.float32) / scale


def packet_accumulate_ref(slot_ids: jnp.ndarray, payloads: jnp.ndarray,
                          num_slots: int) -> jnp.ndarray:
    """Switch descriptor accumulation (paper §3.1.1): scatter-add each
    packet's payload into its descriptor slot.

    slot_ids: (N,) int32 in [0, num_slots); payloads: (N, D).
    Returns (num_slots, D) accumulators — int32 for int32 payloads (the
    associative fixed-point path), float32 otherwise. The dtype policy is
    API contract, not math, so it is shared with the kernel.
    """
    from .packet_accum import accumulate_dtype
    return jax.ops.segment_sum(payloads.astype(accumulate_dtype(payloads.dtype)),
                               slot_ids, num_segments=num_slots)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """GQA attention oracle. q: (B, H, S, D); k/v: (B, KV, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) / jnp.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, D).astype(q.dtype)
