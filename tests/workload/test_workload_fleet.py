"""Workload traffic through the simulator: exactness and inertness.

* Staggered bucket arrivals (the fleet subsystem's ``EV_JOB_ARRIVE`` path)
  keep every reduction exact for every algorithm on both registered
  fabrics — via the full ``FleetDriver`` stack, and property-tested over
  bucket sizes / DP degrees / seeds with hypothesis.
* All 15 golden scenarios replay bit-for-bit with the workload subsystem
  imported: the compiler is pure analysis + simulator *consumer*; importing
  it must not perturb the dataplane.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "core"))

from golden_cases import (CASES, build_simulator, load_goldens,  # noqa: E402
                          result_to_jsonable)

import repro.core.workload  # noqa: E402,F401  (the import IS the point)
from repro.core.canary import (Algo, TenantSpec, scaled_config,  # noqa: E402
                               three_tier_config)
from repro.core.fleet import FleetDriver, FleetScenario  # noqa: E402
from repro.core.workload import (build_timeline, compile_jobs,  # noqa: E402
                                 get_model_config, pack_buckets,
                                 pick_participants)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def _workload_jobs(sim_cfg, *, arch="deepseek-moe-16b", dp_hosts=6,
                   bucket_bytes=1 << 17, bytes_scale=0.03, seed=None,
                   expert_sharding=True):
    cfg = get_model_config(arch, "smoke")
    plan = pack_buckets(cfg, bucket_bytes=bucket_bytes,
                        expert_sharding=expert_sharding)
    tl = build_timeline(cfg, plan, seq=128, global_batch=8,
                        dp_hosts=dp_hosts)
    parts = pick_participants(sim_cfg, dp_hosts, seed=seed)
    return compile_jobs(plan, tl, parts, bytes_scale=bytes_scale)


TOPOLOGIES = {
    "fat_tree": lambda: scaled_config(4, seed=3),
    "three_tier": lambda: three_tier_config(seed=3),
}


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE, Algo.RING])
def test_staggered_buckets_exact_through_fleet_path(topo, algo):
    """Compiler-derived staggered arrivals ride the fleet stack (admission
    attached, EV_JOB_ARRIVE activations) and every reduction stays exact."""
    sim_cfg = TOPOLOGIES[topo]()
    jobs = _workload_jobs(sim_cfg)
    arrivals = sorted(j.arrival_ns for j in jobs)
    assert arrivals[0] > 0.0                     # released after forward
    assert len(set(arrivals)) > 1                # genuinely staggered
    scenario = FleetScenario(cfg=sim_cfg, tenants=[TenantSpec(0)], jobs=jobs,
                             algo=algo, quota_policy="none", baselines=False)
    fr = FleetDriver(scenario).run()
    assert fr.correct
    assert len(fr.jobs) == len(jobs)
    for rec in fr.jobs:                          # nothing finishes pre-submit
        assert rec.jct_ns >= 0.0


if HAVE_HYP:
    @given(
        bucket_kib=st.integers(16, 256),
        dp_hosts=st.integers(2, 8),
        seed=st.integers(0, 200),
        algo=st.sampled_from([Algo.CANARY, Algo.STATIC_TREE, Algo.RING]),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_workload_reductions_always_exact(bucket_kib, dp_hosts,
                                                       seed, algo):
        """Invariant: any (bucket size, DP degree, placement, algorithm)
        yields exact sums for every staggered bucket."""
        sim_cfg = scaled_config(4, seed=seed)
        jobs = _workload_jobs(sim_cfg, dp_hosts=dp_hosts,
                              bucket_bytes=bucket_kib << 10, seed=seed)
        scenario = FleetScenario(cfg=sim_cfg, tenants=[TenantSpec(0)],
                                 jobs=jobs, algo=algo, quota_policy="none",
                                 baselines=False)
        assert FleetDriver(scenario).run().correct


@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_goldens_bit_for_bit_with_workload_imported(name, goldens):
    """repro.core.workload was imported at module top; the pinned goldens
    must still replay bit-for-bit."""
    result = build_simulator(name).run()
    assert result_to_jsonable(result) == goldens[name]
