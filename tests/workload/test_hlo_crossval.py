"""Cross-validation: the workload compiler's analytic gradient bytes vs the
*real* trainer's optimized HLO.

The workload compiler predicts the DP allreduce traffic from ``ModelConfig``
arithmetic alone. Here we compile the actual train step (8 CPU devices,
batch sharded over ``data``, params replicated so GSPMD inserts plain
gradient all-reduces) and parse the collective bytes out of the optimized
HLO with ``parse_collective_bytes`` — the two must agree within a
documented tolerance.

Documented discrepancies (why the ratio is not exactly 1.0):

* XLA sinks the optimizer's f32 cast *below* the collective: gradient
  all-reduces run in f32 even for bf16 params, so the analytic side is
  evaluated with ``grad_dtype="float32"``.
* tied embeddings produce one all-reduce per use (input embed + LM head)
  on current XLA instead of accumulating first: +1 extra embedding-sized
  all-reduce (~10% for the smoke config).
* the analytic ``param_count()`` omits the final norm (+256 params here)
  and the HLO adds scalar metric all-reduces (loss/accuracy, ~bytes).
* ``scan_layers=False`` in the probe: HLO text contains a ``while`` body
  once regardless of trip count (same pitfall ``repro.launch.dryrun``
  documents), so the probe unrolls the 2-layer smoke stack.

Tolerance: HLO bytes / analytic f32 bytes in [0.98, 1.15].
"""
import json
import os
import subprocess
import sys

from repro.core.workload import get_model_config, total_dp_grad_bytes

XVAL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.analysis import parse_collective_bytes
from repro.models import get_config, init_params
from repro.optim import AdamWConfig, AdamWState
from repro.optim import init as adamw_init
from repro.parallel.sharding import batch_spec, param_specs
from repro.train import TrainConfig, make_train_step

cfg = get_config("llama3.2-1b", "smoke").with_(scan_layers=False, remat=False)
mesh = jax.make_mesh((8, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
step = make_train_step(TrainConfig(model=cfg, optimizer=AdamWConfig()))

def sds(shape, dtype, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))

params_shapes = jax.eval_shape(partial(init_params, cfg),
                               jax.random.PRNGKey(0))
p_specs = param_specs(params_shapes, mesh, fsdp="data", model="model",
                      use_fsdp=False)           # replicated -> all-reduce
params_sds = jax.tree.map(lambda s, sp: sds(s.shape, s.dtype, sp),
                          params_shapes, p_specs)
opt_shapes = jax.eval_shape(lambda p: adamw_init(p, AdamWConfig()),
                            params_shapes)
opt_sds = AdamWState(
    step=sds((), jnp.int32, P()),
    m=jax.tree.map(lambda s, sp: sds(s.shape, s.dtype, sp), opt_shapes.m,
                   p_specs),
    v=jax.tree.map(lambda s, sp: sds(s.shape, s.dtype, sp), opt_shapes.v,
                   p_specs))
bspec = batch_spec(mesh, 8, "data")
batch = {"tokens": sds((8, 64), jnp.int32, bspec),
         "labels": sds((8, 64), jnp.int32, bspec)}
coll = parse_collective_bytes(
    jax.jit(step).lower(params_sds, opt_sds, batch).compile().as_text())
print("XVAL_JSON " + json.dumps({
    "ar_bytes": coll["per_op_bytes"]["all-reduce"],
    "ar_count": coll["per_op_count"]["all-reduce"],
    "unknown_dtypes": coll["unknown_dtypes"],
    "actual_params": int(sum(x.size for x in
                             jax.tree.leaves(params_shapes)))}))
"""


def test_compiler_grad_bytes_match_trainer_hlo():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    proc = subprocess.run([sys.executable, "-c", XVAL_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=root)
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("XVAL_JSON ")]
    assert lines, proc.stdout + "\n" + proc.stderr
    got = json.loads(lines[0][len("XVAL_JSON "):])
    assert got["unknown_dtypes"] == {}

    cfg = get_model_config("llama3.2-1b", "smoke")
    analytic = total_dp_grad_bytes(cfg, grad_dtype="float32")
    # the analytic estimate tracks the real model closely (final norm only)
    assert abs(cfg.param_count() - got["actual_params"]) \
        <= 0.01 * got["actual_params"]
    ratio = got["ar_bytes"] / analytic
    assert 0.98 <= ratio <= 1.15, (
        f"trainer HLO all-reduces {got['ar_bytes']} B vs analytic "
        f"{analytic} B (ratio {ratio:.3f}) — outside the documented "
        "tolerance (see module docstring)")
    # one all-reduce per gradient tensor (+ tied-embed extra + 2 metric
    # scalars): far more than one, far fewer than params
    assert 10 <= got["ar_count"] <= 40
