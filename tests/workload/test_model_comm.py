"""Unit tests for the workload compiler's analytic side: per-layer gradient
decomposition, DDP bucket packing, and the backward-pass timeline."""
import pytest

from repro.configs import ARCH_NAMES
from repro.core.workload import (HostSpec, build_timeline, get_model_config,
                                 grad_dtype_bytes, grad_segments,
                                 pack_buckets, total_dp_grad_bytes)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("variant", ["full", "smoke"])
def test_segments_mirror_param_count_exactly(arch, variant):
    """The per-segment decomposition must sum to ModelConfig.param_count()
    (and active_param_count()) term-for-term, for every registered arch."""
    cfg = get_model_config(arch, variant)
    segs = grad_segments(cfg)
    assert sum(s.total_params for s in segs) == cfg.param_count()
    assert sum(s.active_params for s in segs) == cfg.active_param_count()
    # backward completion order: contiguous, head (untied) first, embed last
    assert [s.order for s in segs] == list(range(len(segs)))
    assert segs[-1].name == "embed"
    if not cfg.tie_embeddings:
        assert segs[0].name == "head"
    else:
        assert segs[0].name == f"layer{cfg.num_layers - 1}"


def test_get_model_config_matches_registry():
    """get_model_config delegates to the registry (smoke default)."""
    from repro.models.registry import get_config
    for arch in ARCH_NAMES:
        assert get_model_config(arch) == get_config(arch, "smoke")
        assert get_model_config(arch, "full") == get_config(arch, "full")
    with pytest.raises(KeyError):
        get_model_config("not-a-model")


def test_workload_imports_jax_free():
    """The whole workload package — including the registry path it uses for
    model configs — must import without pulling jax (repro.models.__init__
    is lazy for exactly this). Subprocess: sys.modules is shared in-session."""
    import os
    import subprocess
    import sys
    script = (
        "import sys\n"
        "import repro.core.workload as w\n"
        "from repro.models.registry import get_config\n"
        "assert w.get_model_config('deepseek-moe-16b') == "
        "get_config('deepseek-moe-16b', 'smoke')\n"
        "assert 'jax' not in sys.modules, 'workload import pulled jax'\n"
        "print('JAXFREE_OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    proc = subprocess.run([sys.executable, "-c", script], env=env, cwd=root,
                          capture_output=True, text=True, timeout=120)
    assert "JAXFREE_OK" in proc.stdout, proc.stdout + "\n" + proc.stderr


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-moe-16b",
                                  "whisper-large-v3"])
@pytest.mark.parametrize("bucket_bytes", [1 << 15, 1 << 17, 1 << 22])
def test_bucket_packing_invariants(arch, bucket_bytes):
    cfg = get_model_config(arch, "smoke")
    plan = pack_buckets(cfg, bucket_bytes=bucket_bytes)
    assert plan.total_grad_bytes == total_dp_grad_bytes(cfg)
    assert plan.total_grad_bytes == sum(b.bytes for b in plan.buckets)
    assert sum(b.params for b in plan.buckets) == cfg.param_count()
    # DDP close-on-exceed: every bucket but the last is at least the cap
    for b in plan.buckets[:-1]:
        assert b.bytes >= bucket_bytes
    # buckets launch in backward order
    orders = [b.last_order for b in plan.buckets]
    assert orders == sorted(orders)
    assert [b.index for b in plan.buckets] == list(range(len(plan.buckets)))


def test_dtype_awareness():
    cfg = get_model_config("llama3.2-1b", "smoke")     # bfloat16 compute
    assert grad_dtype_bytes(cfg) == 2
    bf16 = pack_buckets(cfg, bucket_bytes=1 << 17)
    f32 = pack_buckets(cfg, bucket_bytes=1 << 17, grad_dtype="float32")
    assert f32.total_grad_bytes == 2 * bf16.total_grad_bytes
    with pytest.raises(ValueError):
        grad_dtype_bytes(cfg, "int7")


def test_expert_sharding_excludes_routed_expert_grads():
    cfg = get_model_config("deepseek-moe-16b", "smoke")
    ddp = pack_buckets(cfg, bucket_bytes=1 << 17)
    ep = pack_buckets(cfg, bucket_bytes=1 << 17, expert_sharding=True)
    assert ddp.expert_grad_bytes == 0
    assert ep.expert_grad_bytes > 0
    # conservation: EP moves the expert bytes out of the DP allreduce
    assert ep.total_grad_bytes + ep.expert_grad_bytes == ddp.total_grad_bytes
    db = grad_dtype_bytes(cfg)
    want = sum(s.expert_params for s in ep.segments) * db
    assert ep.expert_grad_bytes == want
    # a dense model is unaffected by the flag
    dense = get_model_config("llama3.2-1b", "smoke")
    a = pack_buckets(dense, bucket_bytes=1 << 17)
    b = pack_buckets(dense, bucket_bytes=1 << 17, expert_sharding=True)
    assert a.total_grad_bytes == b.total_grad_bytes


def test_timeline_releases_buckets_in_backward_order():
    cfg = get_model_config("whisper-large-v3", "smoke")   # enc-dec: most segs
    plan = pack_buckets(cfg, bucket_bytes=1 << 17)
    tl = build_timeline(cfg, plan, seq=128, global_batch=8, dp_hosts=8)
    assert tl.forward_ns > 0 and tl.backward_ns > 0
    assert tl.compute_ns == tl.forward_ns + tl.backward_ns
    assert len(tl.bucket_release_ns) == len(plan.buckets)
    # releases are staggered through (forward, forward + backward]
    assert list(tl.bucket_release_ns) == sorted(tl.bucket_release_ns)
    for r in tl.bucket_release_ns:
        assert tl.forward_ns < r <= tl.compute_ns + 1e-6
    assert len(set(tl.bucket_release_ns)) > 1
    # backward segments tile [0, backward_ns] without gaps
    t = 0.0
    for seg in tl.segments:
        assert seg.start_ns == pytest.approx(t)
        assert seg.end_ns >= seg.start_ns
        t = seg.end_ns
    assert t == pytest.approx(tl.backward_ns)


def test_timeline_hardware_constants_match_launch_mesh():
    """HostSpec defaults are a jax-free copy of repro.launch.mesh's TPU v5e
    constants; keep them pinned equal."""
    mesh = pytest.importorskip("repro.launch.mesh")
    spec = HostSpec()
    assert spec.peak_flops == mesh.PEAK_FLOPS_BF16
    assert spec.hbm_bw == mesh.HBM_BW


def test_timeline_flops_consistent_with_launch_analysis():
    """Per-segment backward FLOPs must sum to the 4ND share of the same
    6ND accounting ``repro.launch.analysis.model_flops_per_step`` uses."""
    from repro.launch.analysis import model_flops_per_step
    cfg = get_model_config("deepseek-moe-16b", "smoke")
    plan = pack_buckets(cfg, bucket_bytes=1 << 17)
    seq, gb, dp = 128, 8, 8
    tl = build_timeline(cfg, plan, seq=seq, global_batch=gb, dp_hosts=dp)
    bwd_flops = sum(s.flops for s in tl.segments)
    total = model_flops_per_step(cfg, "train", seq, gb)
    assert bwd_flops == pytest.approx((4.0 / 6.0) * total / dp)


def test_timeline_memory_bound_segments():
    """With tiny token counts the roofline must go memory-bound (duration
    set by bytes/hbm_bw, independent of further token reduction)."""
    cfg = get_model_config("llama3.2-1b", "smoke")
    plan = pack_buckets(cfg, bucket_bytes=1 << 20)
    slow_hbm = HostSpec(hbm_bw=1e6)
    t1 = build_timeline(cfg, plan, seq=2, global_batch=2, dp_hosts=2,
                        host=slow_hbm)
    t2 = build_timeline(cfg, plan, seq=2, global_batch=2, dp_hosts=2)
    assert t1.backward_ns > t2.backward_ns
