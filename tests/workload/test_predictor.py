"""Predictor tests, including the PR's pinned acceptance scenario."""
import statistics

import pytest

from repro.core.canary import Algo, scaled_config
from repro.core.workload import (HostSpec, get_scenario, list_scenarios,
                                 predict_iteration, predict_scenario,
                                 get_model_config, scaling_curves)


def test_acceptance_deepseek_moe_congested_canary_beats_static():
    """Acceptance scenario (ISSUE 4): config-derived deepseek-moe-16b smoke
    workload on a congested fat tree. CANARY's predicted iteration time
    beats STATIC_TREE's (mean over three pinned placements, the paper's
    reporting style), the exposed-communication fraction is reported, and
    every reduction is exact.

    Buckets are packed at 1 MiB — a full-scale ~16 MiB DDP bucket at the
    fabric's 1/16 scale — which is the regime the paper evaluates (Fig. 9:
    Canary's advantage grows with message size; at KiB-scale buckets the
    dynamic-tree setup cost is not amortized and STATIC_TREE can win, which
    benchmarks/workload.py measures rather than hides).
    """
    iters = {}
    for algo in (Algo.CANARY, Algo.STATIC_TREE):
        preds = [predict_scenario("deepseek-moe/fat_tree", algo=algo,
                                  congestion=True,
                                  sim_cfg=scaled_config(4, seed=seed),
                                  bucket_bytes=1 << 20, bytes_scale=1.0)
                 for seed in (0, 1, 2)]
        for p in preds:
            assert p.correct, f"{algo}: inexact reduction"
            assert 0.0 < p.exposed_comm_frac < 1.0
            assert p.exposed_comm_ns == pytest.approx(
                p.iteration_ns - p.compute_ns)
        iters[str(algo)] = statistics.mean(p.iteration_ns for p in preds)
    assert iters["canary"] < iters["static_tree"], iters


def test_scenarios_registered_for_all_models_and_fabrics():
    names = list_scenarios()
    assert len(names) == 8
    for model in ("llama3-dense", "deepseek-moe", "mamba2", "whisper"):
        for topo in ("fat_tree", "three_tier"):
            assert f"{model}/{topo}" in names
    s = get_scenario("deepseek-moe/fat_tree")
    assert s.expert_sharding
    with pytest.raises(KeyError):
        get_scenario("gpt5/fat_tree")


def test_prediction_reports_overlap_accounting():
    p = predict_scenario("llama3-dense/fat_tree", bytes_scale=0.03)
    assert p.correct
    assert p.iteration_ns >= p.compute_ns
    assert p.iteration_ns >= p.comm_last_finish_ns
    assert len(p.buckets) == len(p.plan.buckets)
    for b in p.buckets:
        assert b.finish_ns > b.release_ns          # allreduce takes >0 time
    # jobs arrived staggered through the backward pass
    releases = [b.release_ns for b in p.buckets]
    assert releases == sorted(releases) and len(set(releases)) > 1


def test_compute_bound_workload_exposes_almost_no_comm():
    """A slow device under tiny traffic hides (nearly) all communication:
    iteration time collapses to the compute roofline. Not exactly zero —
    the final bucket releases at the very end of the backward pass, so its
    allreduce is always exposed (as in real DDP)."""
    slow = HostSpec(peak_flops=1e9, hbm_bw=1e9, mfu=1.0)
    p = predict_scenario("mamba2/fat_tree", bytes_scale=0.01, host=slow)
    assert p.correct
    assert p.iteration_ns == pytest.approx(p.compute_ns, rel=1e-3)
    assert p.exposed_comm_frac < 1e-3


def test_scaling_curves_rows_and_fixed_placement():
    model = get_model_config("llama3.2-1b", "smoke")
    cfg = scaled_config(4, seed=5)
    rows = scaling_curves(model, cfg, hosts_list=(4, 8),
                          algos=((Algo.CANARY, 1), (Algo.RING, 1)),
                          congestion_levels=(False,),
                          bytes_scale=0.03, bucket_bytes=1 << 17)
    assert len(rows) == 4
    for r in rows:
        assert r["correct"]
        assert set(r) >= {"model", "hosts", "algo", "congestion",
                          "iteration_ns", "exposed_comm_frac", "buckets"}
    by_hosts = {(r["hosts"], r["algo"]): r for r in rows}
    assert by_hosts[(4, "canary")]["iteration_ns"] > 0
    # same hosts -> same compute roofline across algos (placement fixed)
    assert by_hosts[(8, "canary")]["compute_ns"] == \
        by_hosts[(8, "ring")]["compute_ns"]


def test_predict_iteration_validates_inputs():
    model = get_model_config("llama3.2-1b", "smoke")
    cfg = scaled_config(4)
    with pytest.raises(ValueError, match="participants or dp_hosts"):
        predict_iteration(model, cfg)
    with pytest.raises(ValueError, match="bytes_scale"):
        predict_iteration(model, cfg, dp_hosts=4, bytes_scale=0.0)
