"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes and dtypes (pl.pallas_call + BlockSpec run on CPU via
interpret=True; the kernel bodies are identical on TPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fixedpoint import dequantize, quantize
from repro.kernels.flash_attention import flash_attention
from repro.kernels.packet_accum import packet_accumulate
from repro.kernels.ref import (dequantize_ref, flash_attention_ref,
                               packet_accumulate_ref, quantize_ref)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


# ------------------------------------------------------------- fixed point
@pytest.mark.parametrize("shape", [(16,), (100,), (257,), (8, 128), (3, 5, 7),
                                   (1024, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 5).astype(dtype)
    scale = 2.0 ** 16
    got = quantize(x, scale)
    want = quantize_ref(x, scale)
    assert got.dtype == jnp.int32 and got.shape == shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(64,), (300,), (16, 16)])
def test_dequantize_roundtrip(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    scale = 2.0 ** 20
    d = dequantize(quantize(x, scale), scale)
    np.testing.assert_allclose(np.asarray(d), np.asarray(x), atol=2 / scale)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(dequantize_ref(quantize_ref(x, scale), scale)),
                               atol=0)


def test_fixed_point_sum_order_independent():
    """The determinism guarantee behind fixed-point dynamic trees: integer
    partial sums are identical under any association order."""
    xs = [jax.random.normal(jax.random.PRNGKey(i), (256,)) for i in range(8)]
    scale = 2.0 ** 18
    qs = [np.asarray(quantize(x, scale)) for x in xs]
    import itertools, random
    ref_sum = sum(qs)
    rng = random.Random(0)
    for _ in range(5):
        order = list(range(8))
        rng.shuffle(order)
        acc = np.zeros_like(qs[0])
        for i in order:
            acc = acc + qs[i]
        np.testing.assert_array_equal(acc, ref_sum)


# --------------------------------------------------------- packet accumulate
@pytest.mark.parametrize("n,d,slots", [(10, 8, 4), (128, 128, 16),
                                       (1000, 64, 32), (77, 200, 7)])
def test_packet_accumulate_matches_ref(n, d, slots):
    key = jax.random.PRNGKey(2)
    ids = jax.random.randint(key, (n,), 0, slots)
    pay = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    got = packet_accumulate(ids, pay, slots)
    want = packet_accumulate_ref(ids, pay, slots)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d,slots", [(10, 8, 4), (128, 128, 16),
                                       (1000, 64, 32), (77, 200, 7)])
def test_packet_accumulate_int32_matches_ref(n, d, slots):
    """Fixed-point payloads keep their dtype: int32 in, int32 accumulators
    out, bit-exact against the segment-sum oracle."""
    ids = jax.random.randint(jax.random.PRNGKey(8), (n,), 0, slots)
    pay = jax.random.randint(jax.random.PRNGKey(9), (n, d),
                             -1_000_000, 1_000_000, dtype=jnp.int32)
    got = packet_accumulate(ids, pay, slots)
    want = packet_accumulate_ref(ids, pay, slots)
    assert got.dtype == jnp.int32 and want.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_packet_accumulate_rejects_wrapping_int_dtypes():
    """Non-int32 integer payloads would silently wrap if cast — reject."""
    ids = jnp.zeros(4, jnp.int32)
    pay = jnp.ones((4, 8), jnp.uint32)
    with pytest.raises(TypeError):
        packet_accumulate(ids, pay, 2)
    with pytest.raises(TypeError):
        packet_accumulate_ref(ids, pay, 2)


def test_packet_accumulate_int32_associative():
    """Accumulating the same int32 packets under any slot grouping gives
    totals identical to a direct integer sum (the §6 associativity prize)."""
    pay = jax.random.randint(jax.random.PRNGKey(10), (64, 16),
                             -1_000_000, 1_000_000, dtype=jnp.int32)
    ids_one = jnp.zeros(64, jnp.int32)
    out = packet_accumulate(ids_one, pay, 1)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray(jnp.sum(pay, axis=0)))


def test_packet_accumulate_empty_slots_zero():
    ids = jnp.array([1, 1, 1], jnp.int32)
    pay = jnp.ones((3, 4))
    out = packet_accumulate(ids, pay, 8)
    assert float(out[0].sum()) == 0.0
    np.testing.assert_allclose(np.asarray(out[1]), 3.0)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA 2:1
    (1, 8, 2, 128, 128),     # GQA 4:1
    (1, 2, 1, 512, 64),      # MQA-ish
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = (jax.random.normal(ks[0], (B, H, S, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, KV, S, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, KV, S, D)) * 0.5).astype(dtype)
    got = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    want = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, 2, 128, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, 128, 64)) * 0.5
    got = flash_attention(q, k, v, causal=False)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_chunked_path():
    """Cross-check the Pallas kernel against the model's jnp chunked
    attention (two independent implementations of the same math)."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, H, KV, S, D = 1, 4, 2, 256, 64
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, D)) * 0.5
    got_model = chunked_attention(q, k, v, causal=True, chunk=128)
    got_kernel = flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(got_model),
                               np.asarray(got_kernel.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)


if HAVE_HYP:
    @given(st.integers(1, 300), st.integers(1, 64), st.integers(1, 16))
    @settings(max_examples=25, deadline=None)
    def test_packet_accumulate_property(n, d, slots):
        ids = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, slots)
        pay = jax.random.normal(jax.random.PRNGKey(n + 1), (n, d))
        got = packet_accumulate(ids, pay, slots)
        want = packet_accumulate_ref(ids, pay, slots)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
