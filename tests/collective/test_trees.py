"""Canary TPU-collective correctness on a multi-device (simulated) mesh.

This file re-executes itself in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single-device view (per the dry-run isolation rule).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.collective import (canary_allreduce_tree,
                                   hierarchical_allreduce,
                                   multi_root_tree_allreduce, ring_allreduce,
                                   tree_reduce_broadcast)

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
N = 8

def run(fn, x):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("data"),
                                 out_specs=P("data"),
                                 check_vma=False))(x)

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 64)).astype(jnp.float32)
want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (8, 64))

# 1) single binomial tree, every root
for root in range(N):
    got = run(lambda v, r=root: tree_reduce_broadcast(v, "data", N, r), x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
print("tree roots ok")

# 2) multi-root blockwise
for roots in ([0] * 4, list(range(4)), [3, 1, 4, 1, 5, 0, 2, 6]):
    got = run(lambda v, rr=tuple(roots): multi_root_tree_allreduce(
        v, "data", N, rr), x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
print("multi-root ok")

# 3) ring reduce-scatter/all-gather
got = run(lambda v: ring_allreduce(v, "data"), x)
np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
print("ring ok")

# 4) odd sizes / padding
x3 = jax.random.normal(key, (8, 37))
want3 = np.broadcast_to(np.asarray(x3).sum(0, keepdims=True), (8, 37))
got = run(lambda v: multi_root_tree_allreduce(v, "data", N, (0, 3, 5)), x3)
np.testing.assert_allclose(np.asarray(got), want3, rtol=1e-5, atol=1e-5)
print("padding ok")

# 5) pytree API + fixed point determinism
tree = {"a": x, "b": x3}
got = jax.jit(jax.shard_map(
    lambda t: canary_allreduce_tree(t, axis_name="data", axis_size=N,
                                    num_blocks=4),
    mesh=mesh, in_specs=({"a": P("data"), "b": P("data")},),
    out_specs={"a": P("data"), "b": P("data")}, check_vma=False))(tree)
np.testing.assert_allclose(np.asarray(got["a"]), want, rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(got["b"]), want3, rtol=1e-5, atol=1e-5)
print("pytree ok")

# 6) fixed-point canary: equal across different root assignments (bitwise)
outs = []
for roots in (tuple(range(8)), (7, 6, 5, 4, 3, 2, 1, 0)):
    got = jax.jit(jax.shard_map(
        lambda t, rr=roots: canary_allreduce_tree(
            t, axis_name="data", axis_size=N, roots=rr, fixed_point=True),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))(x)
    outs.append(np.asarray(got))
np.testing.assert_array_equal(outs[0], outs[1])
np.testing.assert_allclose(outs[0], want, rtol=1e-3, atol=1e-3)
print("fixed-point deterministic ok")

# 7) hierarchical on a 2x4 mesh
mesh2 = jax.make_mesh((2, 4), ("pod", "data"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
xx = jax.random.normal(key, (8, 32))
want2 = np.broadcast_to(np.asarray(xx).sum(0, keepdims=True), (8, 32))
got = jax.jit(jax.shard_map(
    lambda v: hierarchical_allreduce(v, "data", "pod"), mesh=mesh2,
    in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    check_vma=False))(xx)
np.testing.assert_allclose(np.asarray(got), want2, rtol=1e-5, atol=1e-5)
print("hierarchical ok")
print("ALL_OK")
"""


def test_collectives_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__)))))
    assert "ALL_OK" in proc.stdout, proc.stdout + "\n" + proc.stderr


def test_link_load_model_properties():
    from repro.core.collective import tree_link_load
    for n in (4, 8, 16):
        total_per_root = [tree_link_load(r, n).sum() for r in range(n)]
        # total traffic is root-invariant (same tree, rotated)
        assert max(total_per_root) - min(total_per_root) < 1e-9
        # rotating the root rotates the load vector
        l0 = tree_link_load(0, n)
        l3 = tree_link_load(3, n)
        np.testing.assert_allclose(np.roll(l0, 3), l3)


def test_oracle_round_robin_matches_paper_policy():
    from repro.core.collective import CongestionOracle, round_robin_roots
    rr = round_robin_roots(10, 4)
    assert rr == [0, 1, 2, 3, 0, 1, 2, 3, 0, 1]
    o = CongestionOracle(axis_size=4, num_blocks=10, policy="round_robin")
    assert o.plan() == rr


def test_oracle_balanced_avoids_hotspot():
    import numpy as np
    from repro.core.collective import CongestionOracle, tree_link_load
    n, blocks = 8, 32
    ext = np.zeros(n)
    ext[0:2] = 1000.0  # another tenant hammering links 0-1
    hot = CongestionOracle(axis_size=n, num_blocks=blocks, policy="balanced",
                           external_load=ext)
    plan = hot.plan()
    load = ext.copy()
    for r in plan:
        load += tree_link_load(r, n)
    rr_load = ext.copy()
    from repro.core.collective import round_robin_roots
    for r in round_robin_roots(blocks, n):
        rr_load += tree_link_load(r, n)
    assert load.max() <= rr_load.max()


def test_oracle_feedback_updates_weights():
    from repro.core.collective import CongestionOracle
    o = CongestionOracle(axis_size=4, num_blocks=8)
    for t in (0.1, 0.1, 0.1, 0.5):
        o.feedback(t)
    assert o.plan()  # still plans after feedback
