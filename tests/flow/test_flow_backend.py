"""Contracts for the flow-level fast path (``repro.core.flow``).

Four layers of guarantees, mirroring ARCHITECTURE.md §Backends:

* **Model properties** — runtime strictly increases with message size and
  never improves under congestion, on both fabrics and both algorithm
  families. Pure-Python ``solve_cell`` path: no jax needed.
* **Batching contract** — the whole sweep matrix is ONE jitted dispatch:
  the first ``run_batch`` of a given shape costs exactly one trace, a
  repeat costs zero, and the jitted numbers match the pure-Python solver.
* **Isolation contract** — importing the flow package (and resolving the
  backend registry) leaves the packet engine untouched: all goldens stay
  bit-for-bit, and ``repro.core.canary`` / ``repro.core.flow`` import
  without pulling jax (only instantiating the flow *backend* may).
* **Divergence contract** — flow vs packet on the pinned fig7 grid stays
  within the documented tolerance (FAST smoke here; the ±15% acceptance
  bound is checked at mid scale by ``python -m repro.core.flow.validate``).
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(ROOT, "tests", "core"))

KiB = 1024


def _item(topology="fat_tree", algo="canary", n_trees=1, congestion=False,
          data_bytes=128 * KiB, rep=0):
    """A hand-built sweep work item at FAST-ish scale (independent of the
    BENCH_* env, unlike ``benchmarks.sweep.expand_suite``)."""
    from repro.core.canary import scaled_config, three_tier_config
    if topology == "fat_tree":
        cfg = scaled_config(4)
    else:
        cfg = three_tier_config(num_pods=4, leaves_per_pod=2,
                                hosts_per_leaf=4, aggs_per_pod=2, num_cores=4)
    n = max(2, cfg.num_hosts // 2)
    return dict(label=f"{algo}{n_trees}/cong={int(congestion)}", algo=algo,
                n_trees=n_trees, congestion=congestion, num_hosts=n,
                data_bytes=data_bytes, rep=rep, topology=topology,
                cfg=dataclasses.asdict(cfg))


def _grid(data_bytes=128 * KiB):
    items = []
    for topo in ("fat_tree", "three_tier"):
        for cong in (False, True):
            for algo, nt in (("canary", 1), ("static_tree", 1),
                             ("static_tree", 4)):
                items.append(_item(topo, algo, nt, cong, data_bytes))
    return items


def _solve(item):
    from repro.core.flow.model import lower_item, solve_cell
    return solve_cell(lower_item(item))


# --------------------------------------------------------------------------
# Model properties (pure Python, no jax)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("topology", ["fat_tree", "three_tier"])
@pytest.mark.parametrize("algo", ["canary", "static_tree"])
@pytest.mark.parametrize("congestion", [False, True])
def test_runtime_monotone_in_data_bytes(topology, algo, congestion):
    sizes = [64 * KiB, 128 * KiB, 512 * KiB, 2048 * KiB]
    runtimes = [_solve(_item(topology, algo, 1, congestion, s))[0]
                for s in sizes]
    for a, b in zip(runtimes, runtimes[1:]):
        assert b > a, f"runtime not increasing in data_bytes: {runtimes}"


@pytest.mark.parametrize("topology", ["fat_tree", "three_tier"])
@pytest.mark.parametrize("algo", ["canary", "static_tree"])
@pytest.mark.parametrize("n_trees", [1, 4])
def test_congestion_never_helps(topology, algo, n_trees):
    quiet, _ = _solve(_item(topology, algo, n_trees, congestion=False))
    noisy, _ = _solve(_item(topology, algo, n_trees, congestion=True))
    assert noisy >= quiet


@pytest.mark.parametrize("topology", ["fat_tree", "three_tier"])
def test_goodput_is_data_over_runtime(topology):
    item = _item(topology, "canary", 1, True)
    from repro.core.flow.model import lower_item, solve_cell
    cell = lower_item(item)
    t_ns, gp = solve_cell(cell)
    assert gp == pytest.approx(cell.data_bits / t_ns)  # bits/ns == Gbps


@pytest.mark.parametrize("transport", ["gbn", "dcqcn"])
def test_flow_backend_refuses_non_default_transport(transport):
    """Flow-backend honesty: the analytic model has no notion of ECN, PFC or
    per-flow retransmission, so lowering a cell whose config asks for a real
    transport policy must fail loudly instead of silently ignoring it."""
    from repro.core.flow.model import lower_item
    item = _item()
    item["cfg"]["transport"] = transport
    with pytest.raises(ValueError, match="transport"):
        lower_item(item)


def test_flow_backend_refuses_telemetry():
    """Same honesty rule for the telemetry hub: the flow model has no
    packets, descriptors or probe events to observe."""
    from repro.core.flow.model import lower_item
    item = _item()
    item["cfg"]["telemetry"] = True
    with pytest.raises(ValueError, match="telemetry"):
        lower_item(item)


def test_flow_backend_refuses_fault_schedules():
    """Same honesty rule for fault injection: the closed-form solver has no
    event stream to inject EV_FAULT/EV_HEAL into, so a non-empty schedule
    must fail loudly instead of faking survivability results."""
    from repro.core.flow.model import lower_item
    item = _item()
    item["cfg"]["faults"] = [{"kind": "switch_crash", "target": 1,
                              "at_ns": 1000.0, "heal_ns": 5000.0}]
    with pytest.raises(ValueError, match="fault"):
        lower_item(item)


# --------------------------------------------------------------------------
# Batching contract (jax)
# --------------------------------------------------------------------------
def test_one_trace_per_matrix_and_python_parity():
    jax = pytest.importorskip("jax")  # noqa: F841  (flow batch needs jax)
    from repro.core.flow import batch
    from repro.core.flow.model import lower_item, solve_cell
    cells = [lower_item(it) for it in _grid()]
    # unique shape for this test so the jit cache state is deterministic
    before = batch.trace_count()
    t_jit, gp_jit = batch.run_batch(cells)
    assert batch.trace_count() - before == 1, \
        "a whole matrix must compile exactly once"
    again = batch.run_batch(cells)
    assert batch.trace_count() - before == 1, \
        "re-running the same matrix must not retrace"
    assert again[0] == t_jit
    for cell, t, gp in zip(cells, t_jit, gp_jit):
        t_py, gp_py = solve_cell(cell)
        assert t == pytest.approx(t_py, rel=1e-4)
        assert gp == pytest.approx(gp_py, rel=1e-4)


def test_flow_backend_cell_schema_and_single_dispatch():
    pytest.importorskip("jax")
    from repro.core.canary import get_backend
    bk = get_backend("flow")
    items = _grid()
    cells = bk.run_cells(items)
    assert bk.jit_calls == 1
    assert len(cells) == len(items)
    for item, c in zip(items, cells):
        assert c["label"] == item["label"] and c["rep"] == item["rep"]
        assert c["runtime_us"] > 0 and c["goodput_gbps"] > 0
        assert c["correct"] is True and c["backend"] == "flow"
        assert c["bound"] in ("bw", "mix")
        assert c["jit_traces"] <= 1


def test_sweep_doc_flow_backend_shape(tmp_path):
    pytest.importorskip("jax")
    from benchmarks.sweep import run_sweep
    doc = run_sweep("fig7", "fat_tree", reps=1, backend="flow")
    assert doc["backend"] == "flow"
    assert doc["jit_traces"] <= 1
    assert "provenance" in doc and "python" in doc["provenance"]
    assert "items" in doc and len(doc["items"]) == len(doc["results"])
    assert set(doc["aggregates"]) == {
        f"{l}/cong={c}" for l in ("static1", "static2", "static4",
                                  "static8", "canary") for c in (0, 1)}


# --------------------------------------------------------------------------
# Isolation contract
# --------------------------------------------------------------------------
def test_flow_import_leaves_goldens_bit_identical():
    """Resolving the flow backend must not perturb the packet engine: replay
    every golden with repro.core.flow fully imported."""
    pytest.importorskip("jax")
    from repro.core.canary import get_backend
    get_backend("flow")  # force the jax-importing modules in
    from golden_cases import (CASES, build_simulator, load_goldens,
                              result_to_jsonable)
    goldens = load_goldens()
    for name in sorted(CASES):
        got = result_to_jsonable(build_simulator(name).run())
        assert got == goldens[name], \
            f"golden {name!r} diverged with flow backend imported"


def test_canary_and_flow_import_jax_free():
    """The core simulator and the flow package (model/calibration) must
    import without jax — only the flow *backend* (batch.py) may pull it.
    Subprocess: sys.modules is shared in-session."""
    script = (
        "import sys\n"
        "import repro.core.canary as c\n"
        "import repro.core.flow as f\n"
        "import repro.core.transport as t\n"
        "import repro.core.telemetry as tm\n"
        "from repro.core.flow.model import lower_item, solve_cell\n"
        "from repro.core.canary import BACKENDS, get_backend\n"
        "from repro.core.transport import TRANSPORTS, make_transport\n"
        "from repro.core.telemetry import Telemetry, to_perfetto\n"
        "assert 'flow' in BACKENDS and 'packet' in BACKENDS\n"
        "assert 'gbn' in TRANSPORTS and 'dcqcn' in TRANSPORTS\n"
        "get_backend('packet')\n"
        "assert 'jax' not in sys.modules, 'core import pulled jax'\n"
        "print('JAXFREE_OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=120)
    assert "JAXFREE_OK" in proc.stdout, proc.stdout + "\n" + proc.stderr


# --------------------------------------------------------------------------
# Divergence contract (FAST smoke; mid-scale run is the acceptance gate)
# --------------------------------------------------------------------------
def test_flow_vs_packet_pinned_grid_fast(tmp_path):
    pytest.importorskip("jax")
    out = tmp_path / "flow_validation.json"
    env = dict(os.environ)
    env["BENCH_FAST"] = "1"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.flow.validate", "--out", str(out)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    report = json.loads(out.read_text())
    assert report["ok"] and report["fast"]
    assert report["tolerance"] == pytest.approx(0.60)
    assert {g["topology"] for g in report["grids"]} == \
        {"fat_tree", "three_tier"}
