"""Unit tests: optimizer, schedules, data pipeline, losses, checkpointing,
serving engine, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, batch_at
from repro.models import get_config, init_params
from repro.optim import (AdamWConfig, cosine_with_warmup, global_norm, init,
                         update)
from repro.train.losses import cross_entropy


# ----------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([[3.0, -2.0]])}
    state = init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, _ = update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_grad_clip_and_metrics():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0)
    params = {"w": jnp.ones((4, 4))}
    state = init(params, cfg)
    grads = {"w": 1e6 * jnp.ones((4, 4))}
    new_params, state, m = update(grads, state, params, cfg)
    assert m["grad_norm"] > 1e6
    # clipped: the step must be bounded
    assert float(jnp.abs(new_params["w"] - params["w"]).max()) < 0.1


def test_adamw_bf16_states():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    state = init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    _, state2, _ = update(grads, state, params, cfg)
    assert state2.v["w"].dtype == jnp.bfloat16


def test_cosine_schedule():
    s = cosine_with_warmup(1.0, warmup_steps=10, total_steps=100)
    assert float(s(jnp.array(0))) == 0.0
    assert abs(float(s(jnp.array(10))) - 1.0) < 1e-6
    assert float(s(jnp.array(100))) <= 0.11
    assert float(s(jnp.array(55))) < float(s(jnp.array(20)))


# ----------------------------------------------------------------------- data
def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, global_batch=8, seq_len=32, seed=3)
    b1 = batch_at(cfg, 5)
    b2 = batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(cfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1000
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_shard_slices_compose():
    cfg = DataConfig(vocab_size=512, global_batch=8, seq_len=16)
    full = batch_at(cfg, 0)["tokens"]
    lo = batch_at(cfg, 0, batch_slice=(0, 4))["tokens"]
    hi = batch_at(cfg, 0, batch_slice=(4, 8))["tokens"]
    np.testing.assert_array_equal(np.concatenate([lo, hi]), full)


# --------------------------------------------------------------------- losses
def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 16)
    loss, metrics = cross_entropy(logits, labels)
    lp = jax.nn.log_softmax(logits, axis=-1)
    want = -np.take_along_axis(np.asarray(lp), np.asarray(labels)[..., None],
                               axis=-1).mean()
    np.testing.assert_allclose(float(loss), want, rtol=1e-5)
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


def test_cross_entropy_uniform_is_logV():
    logits = jnp.zeros((1, 4, 128))
    labels = jnp.zeros((1, 4), jnp.int32)
    loss, _ = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(128), rtol=1e-5)


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
    cfg = get_config("llama3.2-1b", "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig()
    opt = init(params, ocfg)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, params, opt)
    assert latest_step(d) == 7
    like_p = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    like_o = jax.tree.map(lambda x: jnp.zeros_like(x), opt)
    rp, ro, step = restore_checkpoint(d, 7, like_p, like_o)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rp)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, {"w": jnp.ones((5,))})


# -------------------------------------------------------------------- serving
def test_engine_generate_and_determinism():
    from repro.serving import Engine, ServeConfig
    cfg = get_config("llama3.2-1b", "smoke")
    eng = Engine(ServeConfig(model=cfg, batch=2, max_len=64))
    prompts = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    toks, stats = eng.generate(prompts, new_tokens=8)
    assert toks.shape == (2, 8)
    eng2 = Engine(ServeConfig(model=cfg, batch=2, max_len=64))
    toks2, _ = eng2.generate(prompts, new_tokens=8)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


# ------------------------------------------------------------------- sharding
def test_param_specs_divisibility_guards():
    from jax.sharding import PartitionSpec as P
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    from repro.parallel.sharding import leaf_spec
    # divisible: heads go to model
    s = leaf_spec("wq", (2, 2048, 32, 64), stacked=True, mesh=mesh,
                  fsdp="data", model="model")
    assert s == P(None, "data", "model", None)
    # mesh=1 always divides; simulate non-divisible by a fake mesh via shape
    mesh16 = jax.make_mesh((1, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # rule sanity: norm scales replicate
    s = leaf_spec("scale", (2, 256), stacked=True, mesh=mesh, fsdp="data",
                  model="model")
    assert s == P(None)


def test_batch_spec_fallbacks():
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import batch_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    assert batch_spec(mesh, 8, "data") == P("data")
    assert batch_spec(mesh, 1, "data") == P("data")  # 1 % 1 == 0
