"""Trainer integration: end-to-end loops, checkpoint-resume determinism,
and the congestion-oracle replan path (subprocess with 8 devices)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.data import DataConfig
from repro.models import get_config
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer, TrainerConfig


def _trainer(steps=6, ckpt=None, every=0):
    cfg = get_config("llama3.2-1b", "smoke")
    tc = TrainConfig(model=cfg, optimizer=AdamWConfig(lr=1e-3))
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32)
    return Trainer(TrainerConfig(train=tc, data=data, steps=steps,
                                 log_every=0, checkpoint_dir=ckpt,
                                 checkpoint_every=every))


def test_trainer_runs_and_learns():
    t = _trainer(steps=8)
    hist = t.run()
    assert len(hist) == 8
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_checkpoint_resume_exact(tmp_path):
    """Deterministic data + checkpointing => resumed run matches unbroken."""
    d = str(tmp_path / "ck")
    t1 = _trainer(steps=6, ckpt=d, every=3)
    h1 = t1.run()

    # resume from step 3 and replay steps 3..5
    from repro.checkpoint import restore_checkpoint
    t2 = _trainer(steps=6)
    like_p = t2.params
    like_o = t2.opt_state
    params, opt, step = restore_checkpoint(d, 3, like_p, like_o)
    t2.params, t2.opt_state = params, opt
    from repro.data import batch_at
    import jax.numpy as jnp
    losses = []
    for s in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in
                 batch_at(t2.cfg.data, s).items()}
        t2.params, t2.opt_state, m = t2.step_fn(t2.params, t2.opt_state,
                                                batch)
        losses.append(float(m["loss"]))
    want = [h["loss"] for h in h1[3:6]]
    np.testing.assert_allclose(losses, want, rtol=1e-4, atol=1e-5)


def test_microbatched_step_matches_full_batch():
    """k microbatches must produce the same update as one full batch."""
    import jax.numpy as jnp
    from repro.optim import init as adamw_init
    from repro.train import make_train_step
    from repro.models import init_params
    cfg = get_config("llama3.2-1b", "smoke").with_(dtype="float32")
    oc = AdamWConfig(lr=1e-3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, oc)
    from repro.data import batch_at
    batch = {k: jnp.asarray(v) for k, v in batch_at(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=16),
        0).items()}
    s1 = jax.jit(make_train_step(TrainConfig(model=cfg, optimizer=oc)))
    s4 = jax.jit(make_train_step(TrainConfig(model=cfg, optimizer=oc,
                                             microbatches=4)))
    p1, o1, m1 = s1(params, opt, batch)
    p4, o4, m4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        # fp32 accumulation order differs: allow reassociation-level noise
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


REPLAN_SCRIPT = r"""
import os
import jax
from repro.data import DataConfig
from repro.models import get_config
from repro.optim import AdamWConfig
from repro.parallel.context import ParallelContext, parallel_context
from repro.train import TrainConfig, Trainer, TrainerConfig

cfg = get_config("llama3.2-1b", "smoke")
mesh = jax.make_mesh((8, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
tc = TrainConfig(model=cfg, optimizer=AdamWConfig(lr=1e-3),
                 grad_sync="canary", canary_blocks=8)
data = DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=32)
ctx = ParallelContext(mesh=mesh, data_axes=("data",), model_axis="model")
with parallel_context(ctx):
    t = Trainer(TrainerConfig(train=tc, data=data, steps=8, log_every=0,
                              replan_every=3), mesh=mesh)
    hist = t.run()
assert t.oracle is not None and len(t.oracle._history) > 0
assert all(h["loss"] == h["loss"] for h in hist)
print("REPLAN_OK", hist[0]["loss"], "->", hist[-1]["loss"])
"""


def test_canary_trainer_with_oracle_replan():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    proc = subprocess.run([sys.executable, "-c", REPLAN_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=root)
    assert "REPLAN_OK" in proc.stdout, proc.stdout + "\n" + proc.stderr
