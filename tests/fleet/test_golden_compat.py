"""Backward compatibility: the fleet layer is pay-for-what-you-use.

Running every golden-replay scenario *through the fleet path* — an
``AdmissionController`` attached with ``quota_policy="none"``, all jobs at
t=0 — must reproduce the pinned goldens bit-for-bit. This pins that the
arrival machinery, admission hooks and lifecycle accounting are inert when
unused: the fleet subsystem costs existing users nothing.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "core"))

from golden_cases import CASES, _cfg, _jobs, load_goldens, result_to_jsonable  # noqa: E402

from repro.core.canary import TenantSpec  # noqa: E402
from repro.core.fleet import FleetDriver, FleetScenario  # noqa: E402


@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_fleet_path_replays_golden_bit_for_bit(name, goldens):
    cfg_kw, jobs_spec, algo, n_trees, noise = CASES[name]
    scenario = FleetScenario(
        cfg=_cfg(**cfg_kw),
        tenants=[TenantSpec(0)],
        jobs=_jobs(jobs_spec),
        algo=algo,
        n_trees=n_trees,
        noise_hosts=noise,
        quota_policy="none",
        baselines=False,
    )
    fr = FleetDriver(scenario).run()
    assert result_to_jsonable(fr.sim) == goldens[name]
    # the controller was attached but inert
    assert fr.degraded_jobs == 0 and fr.deferred_jobs == 0
    assert not fr.admission.regions


def test_no_admission_equals_none_policy():
    """admission=None and policy='none' produce identical results on an
    open-loop scenario (same events, same timings, same counters)."""
    from repro.core.canary import AllreduceJob, SimConfig, Simulator
    cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                    table_size=4096, seed=11)
    jobs = [AllreduceJob(0, list(range(8)), 16384),
            AllreduceJob(1, list(range(8, 16)), 16384, arrival_ns=4000.0,
                         tenant=0)]
    plain = Simulator(cfg, jobs).run()
    from repro.core.fleet import AdmissionController
    adm = AdmissionController([TenantSpec(0)], policy="none")
    fleet = Simulator(cfg, jobs, admission=adm).run()
    assert result_to_jsonable(plain) == result_to_jsonable(fleet)
    assert plain.job_finish_ns == fleet.job_finish_ns
