"""Open-loop arrival generators: determinism, ordering, validation."""
import random

import pytest

from repro.core.canary import TenantSpec
from repro.core.fleet import (bursty_arrivals, make_jobs, periodic_arrivals,
                              poisson_arrivals, trace_arrivals)


def test_poisson_deterministic_and_sorted():
    a = poisson_arrivals(50, 1000.0, rng=random.Random(7))
    b = poisson_arrivals(50, 1000.0, rng=random.Random(7))
    assert a == b
    assert a == sorted(a)
    assert len(a) == 50
    assert all(t > 0 for t in a)
    # mean interarrival roughly matches (memoryless process, 50 samples)
    mean = a[-1] / 50
    assert 500.0 < mean < 2000.0


def test_poisson_validates_inputs():
    with pytest.raises(ValueError):
        poisson_arrivals(3, 0.0, rng=random.Random(0))


def test_periodic_training_iterations():
    a = periodic_arrivals(4, 5000.0, start_ns=1000.0)
    assert a == [1000.0, 6000.0, 11000.0, 16000.0]
    j = periodic_arrivals(4, 5000.0, jitter_ns=100.0, rng=random.Random(3))
    assert j == sorted(j)
    base = [0.0, 5000.0, 10000.0, 15000.0]
    assert all(0.0 <= x - b < 100.0 for x, b in zip(j, base))
    with pytest.raises(ValueError):
        periodic_arrivals(2, 1000.0, jitter_ns=10.0)  # jitter needs an rng


def test_bursty_arrivals_shape():
    a = bursty_arrivals(3, 4, 10_000.0, intra_burst_ns=10.0)
    assert len(a) == 12
    assert a[0] == 0.0 and a[3] == 30.0
    assert a[4] == 10_000.0


def test_trace_arrivals_sorts_and_validates():
    assert trace_arrivals([30.0, 10.0, 20.0]) == [10.0, 20.0, 30.0]
    with pytest.raises(ValueError):
        trace_arrivals([-1.0, 5.0])


def test_make_jobs_fixed_vs_resampled_placement():
    tenant = TenantSpec(3, weight=2.0)
    arr = [100.0, 200.0, 300.0]
    fixed = make_jobs(tenant, arr, range(32), 8, 4096,
                      rng=random.Random(1), app_base=10)
    assert [j.app for j in fixed] == [10, 11, 12]
    assert [j.arrival_ns for j in fixed] == arr
    assert all(j.tenant == 3 for j in fixed)
    # training tenant: identical placement every iteration
    assert len({tuple(j.participants) for j in fixed}) == 1
    moved = make_jobs(tenant, arr, range(32), 8, 4096,
                      rng=random.Random(1), app_base=0,
                      fixed_placement=False)
    assert len({tuple(j.participants) for j in moved}) > 1
    with pytest.raises(ValueError):
        make_jobs(tenant, arr, range(4), 8, 4096, rng=random.Random(0),
                  app_base=0)
