"""Fleet subsystem behaviour: open-loop arrivals, enforced quotas, QoS.

The acceptance scenario from the issue lives here: ≥ 8 tenants arriving over
time under descriptor quotas, every admitted job's allreduce exact, a
constrained tenant measurably degraded while a priority tenant is not.
"""
import random

import pytest

from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               TenantSpec, three_tier_config)
from repro.core.fleet import (AdmissionController, FleetDriver, FleetScenario,
                              demand_slots, jain_index, make_jobs,
                              poisson_arrivals, run_fleet)


def tiny_cfg(**kw):
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                table_size=4096, seed=11, max_events=20_000_000)
    base.update(kw)
    return SimConfig(**base)


# --------------------------------------------------------------- arrivals
def test_open_loop_arrival_matches_t0_jct():
    """A job arriving mid-run on an idle fabric costs the same JCT as the
    identical job at t=0 (the clock shifts; the protocol does not)."""
    cfg = tiny_cfg()
    r0 = Simulator(cfg, [AllreduceJob(0, list(range(8)), 32768)]).run()
    rl = Simulator(cfg, [AllreduceJob(0, list(range(8)), 32768,
                                      arrival_ns=50_000.0)]).run()
    assert r0.correct and rl.correct
    assert rl.job_submit_ns[0] == 50_000.0
    assert rl.job_start_ns[0] == 50_000.0
    assert rl.job_finish_ns[0] > 50_000.0
    assert rl.jct_ns(0) == pytest.approx(r0.jct_ns(0), rel=1e-6)


def test_staggered_arrivals_all_complete_exactly():
    cfg = tiny_cfg()
    jobs = [AllreduceJob(a, list(range(a * 4, a * 4 + 4)), 16384,
                         arrival_ns=a * 3000.0, tenant=a)
            for a in range(4)]
    r = Simulator(cfg, jobs).run()
    assert r.correct
    for a in range(4):
        assert r.job_finish_ns[a] >= r.job_submit_ns[a] == a * 3000.0
    # duration spans to the last arrival's completion
    assert r.duration_ns >= 9000.0


# ----------------------------------------------------------------- quotas
def test_quota_region_is_physically_enforced():
    """An admitted tenant's descriptors are confined to its slot region:
    the per-switch high-water can never exceed the quota, however much the
    tenant offers (overflow collides + bypasses instead)."""
    cfg = tiny_cfg(table_size=64)
    jobs = [AllreduceJob(0, list(range(8)), 65536, tenant=0),
            AllreduceJob(1, list(range(8, 16)), 65536, tenant=0)]
    # without quotas the two 64-block jobs overrun 32 descriptors per switch
    free = Simulator(cfg, [AllreduceJob(**{**j.__dict__}) for j in jobs]).run()
    assert free.correct
    assert free.max_descriptors_per_switch > 32
    # equal split over two tenants -> tenant 0 owns a 32-slot region
    adm = AdmissionController([TenantSpec(0), TenantSpec(1)], policy="equal",
                              demand=8)
    quota = Simulator(cfg, jobs, admission=adm).run()
    assert quota.correct
    assert quota.max_descriptors_per_switch <= 32
    assert quota.job_admitted == {0: True, 1: True}


def test_constrained_tenant_degrades_priority_does_not():
    """Weighted sharing: a tenant whose region is below one job's demand is
    degraded to the §3.3 host-based path; the priority tenant never is."""
    cfg = tiny_cfg()
    tenants = [TenantSpec(0, weight=8.0, name="prio"),
               TenantSpec(1, weight=0.01, name="constrained")]
    jobs = [AllreduceJob(0, list(range(8)), 16384, tenant=0),
            AllreduceJob(1, list(range(8, 16)), 16384, tenant=1)]
    adm = AdmissionController(tenants, policy="weighted")
    assert adm  # demand derived from the occupancy model at attach()
    r = Simulator(cfg, jobs, admission=adm).run()
    assert r.correct  # degraded jobs still reduce exactly
    assert r.job_admitted[0] is True
    assert r.job_admitted[1] is False
    assert r.app_fallback_blocks.get(0, 0) == 0
    assert r.app_fallback_blocks[1] == 16  # every block rode the host path
    assert adm.caps[1] == 0 and adm.caps[0] >= 1


@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE])
def test_degraded_job_exact_under_both_in_network_algos(algo):
    cfg = tiny_cfg()
    tenants = [TenantSpec(0, weight=1.0), TenantSpec(1, weight=0.001)]
    jobs = [AllreduceJob(0, list(range(6)), 8192, tenant=0),
            AllreduceJob(1, [8, 9, 10, 11, 12], 8192, tenant=1)]
    adm = AdmissionController(tenants, policy="weighted")
    r = Simulator(cfg, jobs, algo=algo, admission=adm).run()
    assert r.correct
    assert not r.job_admitted[1]
    assert r.app_fallback_blocks[1] == 8


def test_degraded_fallback_count_capped_under_loss():
    """Regression: a degraded app whose blocks *also* exhaust
    max_generations must not double-count — fallback blocks never exceed
    the job's block count."""
    cfg = tiny_cfg(drop_prob=0.1, max_generations=2, retx_timeout_ns=3e4,
                   seed=9)
    tenants = [TenantSpec(0, weight=1.0), TenantSpec(1, weight=0.001)]
    jobs = [AllreduceJob(0, list(range(6)), 8192, tenant=0),
            AllreduceJob(1, [8, 9, 10, 11, 12], 8192, tenant=1)]
    adm = AdmissionController(tenants, policy="weighted")
    r = Simulator(cfg, jobs, admission=adm).run()
    assert r.correct
    assert not r.job_admitted[1]
    assert r.app_fallback_blocks[1] == 8  # == the job's block count, exactly
    assert r.app_fallback_blocks.get(0, 0) <= 8


def test_ring_is_never_degraded():
    """Host-based strategies consume no switch memory: always admitted."""
    cfg = tiny_cfg()
    tenants = [TenantSpec(0, weight=0.001), TenantSpec(1, weight=1.0)]
    jobs = [AllreduceJob(0, list(range(6)), 8192, tenant=0)]
    adm = AdmissionController(tenants, policy="weighted")
    r = Simulator(cfg, jobs, algo=Algo.RING, admission=adm).run()
    assert r.correct and r.job_admitted[0] is True
    assert not r.app_fallback_blocks


def test_defer_overflow_queues_until_capacity_frees():
    """overflow='defer': the second job of a capacity-1 tenant waits for the
    first to finish instead of degrading."""
    cfg = tiny_cfg()
    tenants = [TenantSpec(0)]
    jobs = [AllreduceJob(0, list(range(8)), 16384, tenant=0),
            AllreduceJob(1, list(range(8, 16)), 16384, tenant=0,
                         arrival_ns=100.0)]
    adm = AdmissionController(tenants, policy="weighted", overflow="defer",
                              demand=cfg.table_size)  # cap = 1
    r = Simulator(cfg, jobs, admission=adm).run()
    assert r.correct
    assert r.job_admitted == {0: True, 1: True}  # both ran in-network
    assert adm.deferrals == {1: 1}
    # queueing delay: job 1 started only when job 0 finished
    assert r.job_start_ns[1] == r.job_finish_ns[0]
    assert r.job_start_ns[1] > r.job_submit_ns[1]
    assert r.jct_ns(1) > r.jct_ns(0)


def test_unknown_tenant_rejected_and_bad_policy():
    with pytest.raises(ValueError):
        AdmissionController([TenantSpec(0)], policy="bogus")
    with pytest.raises(ValueError):
        AdmissionController([TenantSpec(0)], overflow="bogus")
    with pytest.raises(ValueError):
        AdmissionController([TenantSpec(0), TenantSpec(0)])
    adm = AdmissionController([TenantSpec(0)], policy="weighted")
    cfg = tiny_cfg()
    with pytest.raises(ValueError):
        Simulator(cfg, [AllreduceJob(5, [0, 1], 1024, tenant=5)],
                  admission=adm).run()


def test_demand_slots_tracks_occupancy_model():
    cfg = tiny_cfg()
    d = demand_slots(cfg)
    assert d >= 1
    # doubling the aggregation timeout lengthens descriptor lifetime and
    # therefore the per-job demand (Little's law)
    assert demand_slots(tiny_cfg(timeout_ns=4000.0)) > d


# ------------------------------------------------------------- acceptance
def test_acceptance_eight_tenant_fleet_under_quotas():
    """≥ 8 tenants arriving over time under enforced descriptor quotas:
    every job completes exactly, the constrained tenant is measurably
    degraded, the priority tenant is untouched, and the QoS metrics are
    well-formed."""
    cfg = tiny_cfg(seed=5)
    rng = random.Random(42)
    # tenant 0 is priority (big weight); tenant 7 is constrained to below
    # one job's slot demand; the middle tenants share modest quotas
    tenants = [TenantSpec(0, weight=6.0, name="priority")] + \
        [TenantSpec(t, weight=1.0) for t in range(1, 7)] + \
        [TenantSpec(7, weight=0.02, name="constrained")]
    jobs = []
    for t in tenants:
        arr = poisson_arrivals(2, 15_000.0, rng=rng)
        pool = range(cfg.num_hosts)
        jobs += make_jobs(t, arr, pool, 5, 16384, rng=rng,
                          app_base=t.tenant * 10)
    assert len(tenants) == 8 and len(jobs) == 16
    scenario = FleetScenario(cfg=cfg, tenants=tenants, jobs=jobs,
                             algo=Algo.CANARY, quota_policy="weighted")
    fr = FleetDriver(scenario).run()
    # correctness: every job's allreduce is exact (SimResult.correct checks
    # every participant got the true sum for every block)
    assert fr.correct
    assert len(fr.jobs) == 16
    for rec in fr.jobs:
        assert rec.finish_ns >= rec.submit_ns
        assert rec.jct_ns > 0
        assert rec.slowdown is not None and rec.slowdown > 0
    # quota enforcement visible in the metrics
    constrained = fr.per_tenant[7]
    priority = fr.per_tenant[0]
    assert constrained["degraded_jobs"] == 2
    assert constrained["fallback_blocks"] > 0
    assert priority["degraded_jobs"] == 0
    assert priority["fallback_blocks"] == 0
    # fairness index over 8 tenants is in (1/8, 1]
    assert 0.125 < fr.jain_fairness <= 1.0
    assert fr.degraded_jobs == 2


def test_fleet_on_three_tier_topology():
    cfg = three_tier_config(seed=3)
    tenants = [TenantSpec(0, weight=4.0), TenantSpec(1, weight=1.0)]
    rng = random.Random(9)
    jobs = make_jobs(tenants[0], [0.0, 5000.0], range(16), 6, 16384,
                     rng=rng, app_base=0) + \
        make_jobs(tenants[1], [2000.0], range(16, 32), 6, 16384,
                  rng=rng, app_base=10)
    fr = run_fleet(FleetScenario(cfg=cfg, tenants=tenants, jobs=jobs,
                                 quota_policy="weighted"))
    assert fr.correct
    assert all(r.finish_ns >= r.submit_ns for r in fr.jobs)


# ---------------------------------------------------------------- metrics
def test_percentile_linear_interpolation_pinned():
    """Pins the numpy-default linear-interpolation method (ISSUE satellite:
    per-tenant p50/p99)."""
    from repro.core.fleet.metrics import percentile
    assert percentile([5.0], 99.0) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50.0) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.0) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 100.0) == 4.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 25.0) == pytest.approx(1.75)
    # p99 of 1..100 lands between the 99th and 100th order statistic
    assert percentile([float(i) for i in range(1, 101)], 99.0) == \
        pytest.approx(99.01)
    with pytest.raises(ValueError):
        percentile([], 50.0)
    with pytest.raises(ValueError):
        percentile([1.0], 150.0)


def test_per_tenant_percentiles_skip_unusable_jobs():
    from repro.core.fleet.metrics import JobRecord, per_tenant_percentiles
    recs = [JobRecord(app=a, tenant=a % 2, submit_ns=0.0, start_ns=0.0,
                      finish_ns=float(a), jct_ns=float(a + 1),
                      admitted=True, fallback_blocks=0)
            for a in range(6)]
    recs.append(JobRecord(app=9, tenant=0, submit_ns=0.0, start_ns=0.0,
                          finish_ns=float("nan"), jct_ns=float("nan"),
                          admitted=True, fallback_blocks=0))
    pct = per_tenant_percentiles(recs, "jct_ns")
    assert set(pct) == {0, 1}
    assert pct[0]["p50"] == pytest.approx(3.0)   # jcts 1, 3, 5 (NaN skipped)
    assert pct[1]["p50"] == pytest.approx(4.0)   # jcts 2, 4, 6
    assert pct[0]["p99"] <= 5.0 and pct[1]["p99"] <= 6.0
    # no baselines -> no slowdowns -> empty mapping, not a crash
    assert per_tenant_percentiles(recs, "slowdown") == {}


def test_fleet_result_surfaces_jct_percentiles():
    cfg = tiny_cfg()
    tenants = [TenantSpec(0, weight=2.0), TenantSpec(1, weight=1.0)]
    rng = random.Random(3)
    jobs = make_jobs(tenants[0], [0.0, 2000.0, 4000.0], range(8), 4, 16384,
                     rng=rng, app_base=0) + \
        make_jobs(tenants[1], [1000.0], range(8, 16), 4, 16384,
                  rng=rng, app_base=10)
    fr = run_fleet(FleetScenario(cfg=cfg, tenants=tenants, jobs=jobs,
                                 quota_policy="weighted"))
    assert fr.correct
    jcts = sorted(r.jct_ns for r in fr.jobs)
    assert jcts[0] <= fr.p50_jct_ns <= fr.p99_jct_ns <= fr.max_jct_ns
    s = fr.summary()
    assert f"p50={fr.p50_jct_ns/1e3:.1f}us" in s
    assert f"p99={fr.p99_jct_ns/1e3:.1f}us" in s
    for t, d in fr.per_tenant.items():
        assert d["p50_jct_ns"] <= d["p99_jct_ns"]
        assert d["p50_slowdown"] is not None    # baselines were on
        assert d["p50_slowdown"] <= d["p99_slowdown"]
    # single-job tenant: every percentile is that one job's value
    solo = [r for r in fr.jobs if r.tenant == 1]
    assert len(solo) == 1
    assert fr.per_tenant[1]["p50_jct_ns"] == solo[0].jct_ns
    assert fr.per_tenant[1]["p99_jct_ns"] == solo[0].jct_ns


def test_fleet_diagnosis_attached_only_with_telemetry():
    cfg = tiny_cfg()
    tenants = [TenantSpec(0), TenantSpec(1)]
    jobs = [AllreduceJob(0, [0, 1, 2, 3], 16384, tenant=0),
            AllreduceJob(1, [8, 9, 10, 11], 16384, tenant=1,
                         arrival_ns=2000.0)]
    off = run_fleet(FleetScenario(cfg=cfg, tenants=tenants, jobs=jobs,
                                  quota_policy="none", baselines=False))
    assert off.diagnosis is None
    on = run_fleet(FleetScenario(cfg=tiny_cfg(telemetry=True),
                                 tenants=tenants, jobs=jobs,
                                 quota_policy="none", baselines=False))
    assert on.diagnosis is not None
    assert set(on.diagnosis.per_tenant) == {0, 1}
    assert sum(on.diagnosis.totals.values()) > 0.0
    # the report renders the per-tenant section for a multi-tenant run
    assert "per-tenant attribution:" in on.diagnosis.to_text()


def test_jain_index_bounds():
    assert jain_index([]) == 1.0
    assert jain_index([3.0, 3.0, 3.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    v = jain_index([1.0, 2.0, 3.0])
    assert 1 / 3 < v < 1.0


def test_summary_includes_per_app_completion_and_fallbacks():
    """Pins the extended one-line summary format (per-app completion time +
    fallback counts) so multi-job runs are diagnosable at a glance."""
    cfg = tiny_cfg()
    jobs = [AllreduceJob(0, [0, 1, 2, 3], 8192),
            AllreduceJob(1, [4, 5, 6, 7], 8192)]
    r = Simulator(cfg, jobs).run()
    s = r.summary()
    assert f"app0[done={r.job_finish_ns[0]/1e3:.1f}us fb=0]" in s
    assert f"app1[done={r.job_finish_ns[1]/1e3:.1f}us fb=0]" in s
    assert "correct=True" in s
